"""Data-plane regressions: batched multi-get, per-shard KV notification,
heap-indexed lease expiry, and per-job GC.

Pins the PR-2 contract:
  * ``ObjectStore.get_many`` — missing keys omitted (or error), interleaved
    puts stay whole-object atomic, and the whole batch is charged one
    amortized round-trip (a single ``mget`` ledger record);
  * ``KVStore.mget`` — order-preserving, defaults for missing keys, one
    charged op per shard touched rather than one per key;
  * per-shard watch conditions — ``blpop`` consumers wake on a producer's
    ``rpush`` promptly and concurrently, ``wait_key`` cannot miss a write
    landing between the sequence snapshot and the wait;
  * heap-indexed leases — ``reap`` requeues expired leases in expiry order
    without scanning live ones, heartbeat-extended leases survive;
  * ``finish_job`` — scheduler maps, KV attempt/duration keys, and
    result/input objects are all freed;
  * ``wait_keys`` fallback tick — dropped for in-process backends (purely
    event-driven); PR 4 drops it for ``FileBackend`` too (the backend's
    own watch thread covers cross-process writers).
"""

import threading
import time

from repro.core import (
    FunctionSpec,
    ParameterServer,
    PSConfig,
    ResultFuture,
    Scheduler,
    SchedulerConfig,
    TaskSpec,
    WrenExecutor,
    stage_input,
)
from repro.storage import FileBackend, KVStore, ObjectStore

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# ObjectStore.get_many
# ---------------------------------------------------------------------------

def test_get_many_missing_keys_omitted_or_error():
    store = ObjectStore()
    store.put("a", 1)
    store.put("b", [2, 3])
    got = store.get_many(["a", "b", "nope"])
    assert got == {"a": 1, "b": [2, 3]}
    with pytest.raises(KeyError):
        store.get_many(["a", "nope"], missing="error")
    # multi_get is the same call
    assert store.multi_get(["a"]) == {"a": 1}


def test_get_many_single_amortized_round_trip():
    """N keys must cost one request latency + transfer, not N latencies."""
    store = ObjectStore()
    n = 32
    for i in range(n):
        store.put(f"k/{i}", i, worker="w")
    store.ledger.clear()
    got = store.get_many([f"k/{i}" for i in range(n)], worker="w")
    assert len(got) == n
    recs = [r for r in store.ledger.records() if r.op == "mget"]
    assert len(recs) == 1
    # amortized: far cheaper than n independent gets would have been
    per_get_latency = store.profile.read_latency_s
    assert recs[0].vtime_s < n * per_get_latency / 2


def test_get_many_interleaved_puts_are_atomic():
    """A reader batching over keys while a writer lands them sees only
    whole objects — never partial state — and converges to all present."""
    store = ObjectStore()
    keys = [f"iv/{i}" for i in range(50)]
    stop = threading.Event()

    def writer():
        for i, k in enumerate(keys):
            store.put(k, {"i": i, "payload": "x" * 64})
        stop.set()

    t = threading.Thread(target=writer)
    t.start()
    seen = {}
    deadline = time.monotonic() + 10
    while len(seen) < len(keys) and time.monotonic() < deadline:
        got = store.get_many(keys)
        for k, v in got.items():
            # every observed value is a complete object
            assert v == {"i": int(k.split("/")[1]), "payload": "x" * 64}
        seen.update(got)
    t.join()
    assert len(seen) == len(keys)


# ---------------------------------------------------------------------------
# KVStore.mget + per-shard notification
# ---------------------------------------------------------------------------

def test_kv_mget_order_defaults_and_per_shard_charging():
    kv = KVStore(num_shards=4)
    kv.set("a", 1)
    kv.set("b", 2)
    before = kv.total_ops()
    out = kv.mget(["b", "missing", "a"], default="absent")
    assert out == [2, "absent", 1]
    # one charged op per shard touched, not one per key
    shards_touched = len({kv.shard_of(k) for k in ["b", "missing", "a"]})
    assert kv.total_ops() - before == shards_touched <= 3


def test_blpop_wakes_on_rpush():
    kv = KVStore(num_shards=2)
    got = []

    def consumer():
        got.append(kv.blpop("q", timeout_s=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    kv.rpush("q", "payload")
    t.join(timeout=5.0)
    assert got == ["payload"]
    # woken by the push, not by a poll tick
    assert time.monotonic() - t0 < 0.2


def test_blpop_concurrent_pullers_each_get_one():
    kv = KVStore(num_shards=4)
    results = []
    lock = threading.Lock()

    def consumer():
        v = kv.blpop("jobs", timeout_s=5.0)
        with lock:
            results.append(v)

    threads = [threading.Thread(target=consumer) for _ in range(8)]
    for t in threads:
        t.start()
    for i in range(8):
        kv.rpush("jobs", i)
    for t in threads:
        t.join(timeout=5.0)
    assert sorted(results) == list(range(8))


def test_wait_key_snapshot_cannot_miss_write():
    """A write landing after the snapshot makes the wait return immediately."""
    kv = KVStore(num_shards=2)
    seq = kv.shard_seq("k")
    kv.set("k", 1)  # lands before the wait
    t0 = time.monotonic()
    new_seq = kv.wait_key("k", seq, timeout_s=2.0)
    assert time.monotonic() - t0 < 0.1
    assert new_seq > seq


def _same_shard_sibling(kv, key):
    """A different key on ``key``'s shard — the noisy neighbour."""
    i = 0
    while True:
        other = f"noise/{i}"
        if other != key and kv.shard_of(other) == kv.shard_of(key):
            return other
        i += 1


def test_keyed_wakes_absorb_foreign_key_writes():
    """Wakes are *keyed*: a waiter on key B sleeps through N writes to key
    A sharing B's shard — each shard wake whose touch named only A is
    absorbed inside ``wait_key`` (counted in ``foreign_wake_skips``), not
    bounced to the caller as a futile predicate re-check."""
    kv = KVStore(num_shards=2)
    target = "watched/b"
    noisy = _same_shard_sibling(kv, target)
    seq = kv.shard_seq(target)
    woke = []

    def waiter():
        woke.append(kv.wait_key(target, seq, timeout_s=1.0))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    n = 25
    for i in range(n):
        kv.set(noisy, i)
        time.sleep(0.002)  # let the waiter absorb each wake individually
    time.sleep(0.1)
    assert not woke, "foreign-key writes must not complete the wait"
    # every absorption is a wake the caller was spared (rapid writes may
    # coalesce into one wake, so >= 1, not == n)
    assert kv.foreign_wake_skips() >= 1
    t0 = time.monotonic()
    kv.set(target, "now")
    t.join(timeout=5.0)
    assert woke and woke[0] > seq
    assert time.monotonic() - t0 < 0.2  # the keyed wake itself is prompt


def test_keyed_wakes_blpop_ignores_sibling_queue_churn():
    """Same pin through ``blpop``: churn on a sibling queue in the same
    shard neither wakes nor starves a consumer blocked on its own queue."""
    kv = KVStore(num_shards=2)
    target = "q/mine"
    noisy = _same_shard_sibling(kv, target)
    got = []

    def consumer():
        got.append(kv.blpop(target, timeout_s=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    for i in range(20):
        kv.rpush(noisy, i)
        time.sleep(0.002)
    t0 = time.monotonic()
    kv.rpush(target, "payload")
    t.join(timeout=5.0)
    assert got == ["payload"]
    assert time.monotonic() - t0 < 0.2
    # the consumer took exactly its own element; the sibling queue is whole
    assert kv.lrange(noisy) == list(range(20))


def test_blpop_timeout_returns_none():
    kv = KVStore()
    t0 = time.monotonic()
    assert kv.blpop("empty", timeout_s=0.1) is None
    assert 0.05 < time.monotonic() - t0 < 1.0


# ---------------------------------------------------------------------------
# heap-indexed lease expiry
# ---------------------------------------------------------------------------

def _mk_sched(**cfg):
    store = ObjectStore()
    kv = KVStore(num_shards=2)
    sched = Scheduler(kv, store, SchedulerConfig(**cfg))
    func = FunctionSpec.register(store, lambda x: x)
    return store, kv, sched, func


def test_reap_requeues_expired_in_expiry_order():
    store, kv, sched, func = _mk_sched(lease_timeout_s=0.05)
    tasks = [
        TaskSpec.make("job", func, stage_input(store, "job", i), i) for i in range(2)
    ]
    sched.submit_many(tasks)
    first = sched.lease_next("w0")
    time.sleep(0.03)  # stagger the expiries
    second = sched.lease_next("w1")
    assert first is not None and second is not None
    time.sleep(0.1)  # both leases expire, in lease order
    assert sched.reap() == 2
    requeued = kv.lrange("sched/queue")
    assert [t.task_id for t in requeued] == [first.task_id, second.task_id]


def test_reap_spares_heartbeat_extended_lease():
    store, kv, sched, func = _mk_sched(lease_timeout_s=0.1)
    task = TaskSpec.make("hb", func, stage_input(store, "hb", 0), 0)
    sched.submit(task)
    leased = sched.lease_next("w0")
    assert leased is not None
    # keep the lease alive past its original expiry
    for _ in range(4):
        time.sleep(0.05)
        sched.heartbeat(leased, "w0")
    assert sched.reap() == 0  # hint re-validated against the extended record
    assert kv.get("sched/lease/" + task.task_id) is not None
    # stop heartbeating: now it really expires and is reaped
    time.sleep(0.15)
    assert sched.reap() == 1


def test_next_wakeup_tracks_earliest_lease_expiry():
    store, kv, sched, func = _mk_sched(lease_timeout_s=5.0, heartbeat_interval_s=10.0)
    task = TaskSpec.make("nw", func, stage_input(store, "nw", 0), 0)
    sched.submit(task)
    assert sched.lease_next("w0") is not None
    # earliest expiry (~5 s out) bounds the tick; heartbeat would allow 10 s
    assert sched.next_wakeup_s() <= 5.0 + 0.01


def test_speculation_uses_per_job_durations():
    """A straggler is judged against its own job's median, and the
    speculative duplicate still resolves correctly (first writer wins)."""
    from repro.core import FaultPlan, get_all

    cfg = SchedulerConfig(
        lease_timeout_s=5.0, speculation_factor=3.0, min_completed_for_speculation=3
    )
    fp = FaultPlan(slowdown={"w0000": 400.0})
    wex = WrenExecutor(num_workers=4, scheduler_config=cfg, fault_plan=fp, seed=0)
    try:
        futs = wex.map(lambda x: x, list(range(12)), job_id="specjob")
        assert get_all(futs, timeout_s=60) == list(range(12))
        assert wex.kv.llen("sched/durations/specjob") > 0
    finally:
        wex.shutdown()


# ---------------------------------------------------------------------------
# per-job GC
# ---------------------------------------------------------------------------

def test_finish_job_frees_scheduler_and_storage_state():
    with WrenExecutor(num_workers=4) as wex:
        job = "gcjob"
        futs = wex.map(lambda x: x * 2, list(range(8)), job_id=job)
        from repro.core import get_all

        assert get_all(futs, timeout_s=30) == [x * 2 for x in range(8)]
        task_ids = [f.task.task_id for f in futs]
        assert len(wex.store.list(f"result/{job}/")) == 8
        assert any(
            wex.kv.get("sched/attempts/" + tid) is not None for tid in task_ids
        )
        freed = wex.finish_job(job)
        assert freed == 8
        # scheduler maps emptied
        assert all(tid not in wex.scheduler._specs for tid in task_ids)
        assert job not in wex.scheduler._jobs
        # KV bookkeeping gone
        assert all(wex.kv.get("sched/attempts/" + tid) is None for tid in task_ids)
        assert wex.kv.get("sched/durations/" + job) is None
        # result + staged input objects gone
        assert wex.store.list(f"result/{job}/") == []
        assert wex.store.list(f"input/{job}") == []
        # double-finish is a no-op
        assert wex.finish_job(job) == 0


def test_finish_job_keeps_other_jobs_intact():
    with WrenExecutor(num_workers=2) as wex:
        a = wex.map(lambda x: x, [1, 2], job_id="job-a")
        b = wex.map(lambda x: x, [3, 4], job_id="job-b")
        from repro.core import get_all

        assert get_all(a, timeout_s=30) == [1, 2]
        assert get_all(b, timeout_s=30) == [3, 4]
        wex.finish_job("job-a")
        # job-b futures still resolve from storage
        fresh = [ResultFuture(wex.store, f.task) for f in b]
        assert [f.result(timeout_s=5) for f in fresh] == [3, 4]


# ---------------------------------------------------------------------------
# parameter-server batching + per-shard wait
# ---------------------------------------------------------------------------

def test_ps_pull_is_batched_mget():
    kv = KVStore(num_shards=4)
    ps = ParameterServer(kv, np.zeros(64, np.float32), PSConfig(num_blocks=8))
    kv.ledger.clear()
    params, vers = ps.pull(worker="puller")
    assert params.shape == (64,)
    assert vers == [0] * 8
    ops = [r.op for r in kv.ledger.records() if r.worker == "puller"]
    assert set(ops) == {"mget"}
    assert len(ops) <= 4  # at most one round-trip per shard, never per key


def test_ps_wait_fresh_wakes_on_push():
    kv = KVStore(num_shards=2)
    ps = ParameterServer(kv, np.zeros(8, np.float32), PSConfig(num_blocks=2))

    def pusher():
        time.sleep(0.05)
        ps.push_delta(np.ones(8, np.float32))

    t = threading.Thread(target=pusher)
    t.start()
    t0 = time.monotonic()
    ver = ps.wait_fresh(0, seen_version=0, timeout_s=5.0)
    elapsed = time.monotonic() - t0
    t.join()
    assert ver >= 1
    assert elapsed < 1.0  # woken by the push, not the timeout


# ---------------------------------------------------------------------------
# wait_keys fallback tick: event-driven in-process, tick only cross-process
# ---------------------------------------------------------------------------

def test_watch_tick_gone_for_all_builtin_backends(tmp_path):
    """PR 4: FileBackend runs its own cross-process watcher, so no built-in
    backend needs the fallback re-check tick anymore; only an explicit
    poll_s forces one."""
    assert ObjectStore().watch_tick_s() is None
    assert ObjectStore(backend=FileBackend(str(tmp_path))).watch_tick_s() is None
    assert ObjectStore().watch_tick_s(poll_s=0.01) == 0.01


def test_shared_backend_cross_handle_wakeup():
    """Watch state lives on the backend: a put through one store handle must
    wake a waiter on a *different* handle sharing the same in-memory backend
    — with no fallback tick to paper over a miss."""
    from repro.storage import InMemoryBackend

    be = InMemoryBackend()
    waiter = ObjectStore(backend=be)
    writer = ObjectStore(backend=be)
    assert waiter.watch_tick_s() is None  # purely event-driven

    def publish():
        time.sleep(0.1)
        writer.put("xh/key", 3)

    t = threading.Thread(target=publish)
    t.start()
    t0 = time.monotonic()
    waiter.wait_keys(["xh/key"], timeout_s=5.0)
    elapsed = time.monotonic() - t0
    t.join()
    assert elapsed < 0.5  # woken by the other handle's notify, not timeout


def test_finish_job_drops_stale_queued_duplicates():
    """A duplicate of a finished job still sitting in the queue must be
    dropped at lease time, not resurrect attempts/lease state the GC freed."""
    store, kv, sched, func = _mk_sched()
    task = TaskSpec.make("donejob", func, stage_input(store, "donejob", 1), 0)
    sched.submit(task)
    leased = sched.lease_next("w0")
    assert leased is not None
    store.publish_result(task.result_key, "v")
    sched.complete(leased, "w0", 0.01)
    # a speculative duplicate is still queued when the job gets GC'd
    kv.rpush("sched/queue", task)
    sched.finish_job("donejob")
    assert sched.lease_next("w1") is None  # dropped, not leased
    assert kv.get("sched/attempts/" + task.task_id) is None  # not resurrected
    assert kv.get("sched/lease/" + task.task_id) is None
    # completions of in-flight duplicates don't re-create the duration key
    sched.complete(task, "w1", 0.01)
    assert kv.get("sched/durations/donejob") is None
    # a late duplicate that re-publishes after GC (key was absent again, so
    # its if_absent publish wins) is scrubbed when it completes
    store.publish_result(task.result_key, "late-dup")
    sched.complete(task, "w2", 0.01)
    assert store.list(task.result_key) == []
    # a graceful release of a still-leased duplicate doesn't re-create
    # attempts or requeue the GC'd task either
    sched.release(task, "w3")
    assert kv.get("sched/attempts/" + task.task_id) is None
    assert sched.lease_next("w4") is None


def test_finish_job_prefix_does_not_eat_sibling_jobs():
    """GC of job 'train' must not delete job 'train2's staged inputs or
    results (prefix must be slash-terminated)."""
    with WrenExecutor(num_workers=2) as wex:
        from repro.core import get_all

        a = wex.map(lambda x: x, [1], job_id="train")
        b = wex.map(lambda x: x + 1, [1], job_id="train2")
        assert get_all(a, timeout_s=30) == [1]
        assert get_all(b, timeout_s=30) == [2]
        wex.finish_job("train")
        assert wex.store.list("input/train2/") != []
        assert wex.store.list("result/train2/") != []


def test_file_backend_wait_keys_sees_out_of_band_writer(tmp_path):
    """A second backend instance over the same directory publishes without
    reaching the first instance's in-process condition — the waiter's watch
    thread must catch it, with zero fallback ticks."""
    waiter = ObjectStore(backend=FileBackend(str(tmp_path)))
    writer = ObjectStore(backend=FileBackend(str(tmp_path)))

    def publish():
        time.sleep(0.1)
        writer.put("oob/key", 7)

    t = threading.Thread(target=publish)
    t.start()
    waiter.wait_keys(["oob/key"], timeout_s=5.0)  # must not hang
    t.join()
    assert waiter.get("oob/key") == 7
    assert waiter.fallback_tick_waits == 0  # event-driven, not tick-driven
