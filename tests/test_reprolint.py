"""reprolint (static invariant lint) + runtime sanitizer.

Per rule: one minimal offending snippet and one clean counterpart; the
disable-comment escape hatch; the baseline-file CLI contract; and the pin
that the repo's own tree lints clean.  Then the four runtime detectors,
exercised directly against sanitized stores and tracked locks.
"""

import json
import os
import subprocess
import sys
import threading

import pytest

from repro.analysis import lint, sanitizer
from repro.storage.kv_store import KVStore

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src", "repro")


def _rules(source, path="core/example.py"):
    return sorted({f.rule for f in lint.active(lint.lint_source(source, path))})


# ---------------------------------------------------------------------------
# static rules: offending + clean snippet per rule
# ---------------------------------------------------------------------------


def test_fence001_bare_sched_write():
    assert _rules('def f(kv):\n    kv.set("sched/lease/t1", 1)\n') == ["FENCE001"]
    assert _rules('def f(kv):\n    kv.delete("sched/epoch/t1")\n') == ["FENCE001"]
    # fenced mutation verbs are the sanctioned path
    assert _rules('def f(kv):\n    kv.eval("sched/lease/t1", fn)\n') == []
    assert _rules('def f(kv):\n    kv.incr("sched/epoch/t1", 1)\n') == []
    # non-sched keyspace is anyone's to write
    assert _rules('def f(kv):\n    kv.set("ps/block/0", 1)\n') == []


def test_fence001_blessed_finish_job():
    src = (
        "class Scheduler:\n"
        "    def finish_job(self, job):\n"
        '        self.kv.mdel(["sched/lease/a"])\n'
    )
    assert _rules(src, path="src/repro/core/scheduler.py") == []
    # same code anywhere else is a violation
    assert _rules(src, path="src/repro/core/other.py") == ["FENCE001"]


def test_fence001_job_manifest_keyspace():
    """The job-manifest keyspace (core/jobs.py) rides FENCE001 with a
    manifest-specific message naming its blessed paths."""
    findings = lint.active(
        lint.lint_source('def f(kv):\n    kv.set("sched/job/j1/manifest", 1)\n',
                         "core/example.py")
    )
    assert [f.rule for f in findings] == ["FENCE001"]
    assert "jobs.commit_records" in findings[0].message
    assert _rules('def f(kv):\n    kv.mdel(["sched/job/j1/driver"])\n') == ["FENCE001"]
    # the blessed mutation paths are eval/eval_many (commit_records and the
    # term-compared driver-lease transitions)
    assert _rules('def f(kv):\n    kv.eval("sched/job/j1/driver", fn)\n') == []
    assert _rules('def f(kv):\n    kv.eval_many({"sched/job/j1/manifest": fn})\n') == []
    # finish_job's tombstone-then-GC is still the one blessed deleter
    src = (
        "class Scheduler:\n"
        "    def finish_job(self, job):\n"
        '        self.kv.mdel(["sched/job/j1/manifest"])\n'
    )
    assert _rules(src, path="src/repro/core/scheduler.py") == []


def test_batch001_per_key_op_in_loop():
    bad = "def f(kv, keys):\n    for k in keys:\n        kv.get(k)\n"
    assert _rules(bad) == ["BATCH001"]
    good = "def f(kv, keys):\n    vals = kv.mget(keys)\n"
    assert _rules(good) == []
    # store verbs and comprehensions count too
    comp = "def f(store, keys):\n    return [store.get(k) for k in keys]\n"
    assert _rules(comp) == ["BATCH001"]


def test_batch001_raw_wire_verbs_in_loop():
    """PR 9 shard-map surface: a constant kv./ob. op through the raw wire
    verbs inside a loop is the same N-round-trip mistake as a per-key kv
    verb; the pipelined start_call/finish_call scatter and per-key watch
    registration are the sanctioned shapes."""
    bad = (
        "def f(clients, keys):\n"
        "    for c in clients:\n"
        '        c.call("kv.mget", keys)\n'
    )
    assert _rules(bad) == ["BATCH001"]
    assert _rules(
        "def f(clients, key):\n"
        "    for c in clients:\n"
        '        c.cast("ob.put", key, b"x")\n'
    ) == ["BATCH001"]
    assert _rules(
        "def f(c, keys):\n"
        '    return [c.call_rid("kv.lpop_n", k, 1, None) for k in keys]\n'
    ) == ["BATCH001"]
    # the scatter half of a fan-out is the fix, not a violation
    good = (
        "def f(clients, keys):\n"
        '    hs = [c.start_call("kv.mget", keys) for c in clients]\n'
        "    return [c.finish_call(h) for c, h in zip(clients, hs)]\n"
    )
    assert _rules(good) == []
    # watch registration is per-key by protocol (reconnect re-pin loop)
    assert _rules(
        "def f(c, live):\n"
        "    for key in live:\n"
        '        c.call("watch.kv", key, True)\n'
    ) == []
    # dynamic op names are out of static reach; outside a loop is fine
    assert _rules('def f(c, op, k):\n    for _ in range(2):\n        c.call(op, k)\n') == []
    assert _rules('def f(c, k):\n    c.call("kv.get", k)\n') == []


def test_fence001_raw_wire_verbs():
    """The fence follows the op name through the wire verb: a bare kv.set/
    kv.mdel on sched/ keys via .call is the same violation as the kv-verb
    spelling."""
    assert _rules('def f(c):\n    c.call("kv.set", "sched/lease/t1", 1)\n') == ["FENCE001"]
    assert _rules('def f(c):\n    c.cast("kv.mdel", ["sched/epoch/t1"])\n') == ["FENCE001"]
    findings = lint.active(
        lint.lint_source('def f(c):\n    c.call("kv.set", "sched/job/j1/manifest", 1)\n',
                         "core/example.py")
    )
    assert [f.rule for f in findings] == ["FENCE001"]
    assert "jobs.commit_records" in findings[0].message
    # fenced ops and other keyspaces through the wire stay clean
    assert _rules('def f(c):\n    c.call("kv.eval", "sched/lease/t1", fn)\n') == []
    assert _rules('def f(c):\n    c.call("kv.set", "ps/block/0", 1)\n') == []


def test_lock001_blocking_under_lock():
    bad = (
        "def f(self, kv):\n"
        "    with self._lock:\n"
        '        kv.get("k")\n'
    )
    assert _rules(bad) == ["LOCK001"]
    good = (
        "def f(self, kv):\n"
        "    with self._lock:\n"
        "        x = self.cache\n"
        '    kv.get("k")\n'
    )
    assert _rules(good) == []
    # Condition.wait is the sanctioned blocking-under-lock idiom
    waity = (
        "def f(self):\n"
        "    with self.cond:\n"
        "        self.cond.wait(1.0)\n"
    )
    assert _rules(waity) == []


def test_event001_sleep_polling_loop():
    bad = (
        "import time\n"
        "def f(done):\n"
        "    while not done():\n"
        "        time.sleep(0.1)\n"
    )
    assert _rules(bad) == ["EVENT001"]
    # Watcher classes own the fallback tick
    ok = (
        "import time\n"
        "class FileWatcher:\n"
        "    def run(self, done):\n"
        "        while not done():\n"
        "            time.sleep(0.1)\n"
    )
    assert _rules(ok) == []


def test_gc001_delete_without_tombstone():
    bad = "def gc(kv, keys):\n" '    kv.mdel(["shuffle/job1/p0"])\n'
    assert _rules(bad) == ["GC001"]
    good = (
        "def gc(kv, keys):\n"
        '    kv.set("sched/finished/job1", 1)\n'
        '    kv.mdel(["shuffle/job1/p0"])\n'
    )
    # the tombstone write itself is not a FENCE001 hit (finished/ is the
    # tombstone namespace) — but it is outside finish_job, so check GC001
    # in isolation via disabled filtering
    assert "GC001" not in _rules(good)


# ---------------------------------------------------------------------------
# escape hatch + baseline
# ---------------------------------------------------------------------------


def test_disable_comment_waives_finding():
    src = (
        "def f(kv, keys):\n"
        "    for k in keys:\n"
        "        # reprolint: disable=BATCH001(demo reason)\n"
        "        kv.get(k)\n"
    )
    findings = lint.lint_source(src, "core/example.py")
    assert lint.active(findings) == []
    waived = [f for f in findings if f.disabled]
    assert len(waived) == 1
    assert waived[0].rule == "BATCH001"
    assert waived[0].disable_reason == "demo reason"
    assert lint.disabled_counts(findings) == {"BATCH001": 1}
    # a disable for the wrong rule waives nothing
    wrong = src.replace("BATCH001", "FENCE001")
    assert _rules(wrong) == ["BATCH001"]


def test_cli_strict_and_baseline(tmp_path):
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "reprolint_cli", os.path.join(_REPO, "tools", "reprolint.py")
    )
    cli = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cli)

    bad = tmp_path / "bad.py"
    bad.write_text('def f(kv):\n    kv.set("sched/lease/x", 1)\n')
    clean = tmp_path / "clean.py"
    clean.write_text(
        "def f(kv, keys):\n"
        "    # reprolint: disable=BATCH001(test fixture)\n"
        "    vals = [kv.get(k) for k in keys]\n"
    )

    # strict fails on the offending file, passes on the clean one
    assert cli.main([str(bad), "--strict", "--quiet"]) == 1
    assert cli.main([str(clean), "--strict", "--quiet"]) == 0

    # baseline: missing file errors; update creates; growth fails
    base = tmp_path / "base.json"
    assert cli.main([str(clean), "--baseline", str(base), "--quiet"]) == 1
    assert (
        cli.main([str(clean), "--baseline", str(base), "--update-baseline", "--quiet"])
        == 0
    )
    assert json.loads(base.read_text())["disabled_findings"] == {"BATCH001": 1}
    assert cli.main([str(clean), "--baseline", str(base), "--quiet"]) == 0
    # a second waiver grows the count past the baseline -> fail
    grown = tmp_path / "grown.py"
    grown.write_text(
        clean.read_text()
        + "\n\ndef g(kv, keys):\n"
        "    # reprolint: disable=BATCH001(another waiver)\n"
        "    return [kv.get(k) for k in keys]\n"
    )
    assert cli.main([str(grown), "--baseline", str(base), "--quiet"]) == 1


def test_repo_tree_lints_clean():
    """The repo's own source must stay clean — the CI gate in code form."""
    findings = lint.lint_tree(_SRC)
    assert lint.active(findings) == [], [f.format() for f in lint.active(findings)]
    # every waiver carries a reason
    for f in findings:
        if f.disabled:
            assert f.disable_reason, f.format()


def test_seeded_bug_is_caught_end_to_end(tmp_path):
    """The CLI (as CI runs it) flags a planted bare sched/ write."""
    planted = tmp_path / "seeded.py"
    planted.write_text(
        "def requeue(kv, task_id, spec):\n"
        '    kv.set("sched/lease/" + task_id, spec)\n'
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(_REPO, "tools", "reprolint.py"),
         str(planted), "--strict"],
        capture_output=True, text=True,
    )
    assert proc.returncode == 1
    assert "FENCE001" in proc.stdout


# ---------------------------------------------------------------------------
# runtime sanitizer detectors
# ---------------------------------------------------------------------------


@pytest.fixture
def san_state():
    sanitizer.state.clear()
    yield sanitizer.state
    sanitizer.state.clear()


def _kinds(state):
    return sorted({r.kind for r in state.snapshot()})


def test_sanitizer_unfenced_sched_write(san_state):
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=2))
    kv.eval("sched/lease/j/t000000-aaaaaaaa", lambda cur: {"epoch": 1})
    assert san_state.snapshot() == []  # fenced verb: clean
    kv.set("sched/lease/j/t000000-aaaaaaaa", {"epoch": 2})
    assert _kinds(san_state) == ["unfenced-write"]


def test_sanitizer_unfenced_job_manifest_write(san_state):
    """Runtime mirror of the FENCE001 extension: bare writes into the
    sched/job/ manifest keyspace are flagged; the eval-based commit and
    lease transitions are clean; deletion needs the job's tombstone first
    (the manifest key's job id is its FIRST path segment)."""
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=2))
    kv.eval("sched/job/j1/driver", lambda cur: {"owner": "d", "term": 1})
    kv.eval_many({"sched/job/j1/manifest": lambda cur: {"kind": "stage"}})
    assert san_state.snapshot() == []  # fenced verbs: clean
    kv.set("sched/job/j1/manifest", {"kind": "stage"})
    assert _kinds(san_state) == ["unfenced-write"]
    san_state.clear()
    # deleting manifest records without the job tombstone is flagged...
    kv.mdel(["sched/job/j1/stage/0"])
    assert _kinds(san_state) == ["unfenced-write"]
    san_state.clear()
    # ...and clean behind it (finish_job's tombstone-then-GC order)
    kv.set("sched/finished/j1", 1.0)
    kv.mdel(["sched/job/j1/stage/0", "sched/job/j1/barrier/0",
             "sched/job/j1/manifest", "sched/job/j1/driver"])
    assert san_state.snapshot() == []


def test_sanitizer_gc_requires_tombstone(san_state):
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=2))
    kv.mdel(["sched/lease/jobA/t000000-aaaaaaaa"])
    assert _kinds(san_state) == ["unfenced-write"]
    san_state.clear()
    kv.set("sched/finished/jobB", 1.0)
    kv.mdel(["sched/lease/jobB/t000000-bbbbbbbb", "sched/epoch/jobB/t000000-bbbbbbbb"])
    assert san_state.snapshot() == []


def test_sanitizer_blocked_under_lock(san_state):
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=1))
    lock = sanitizer.track_lock(threading.Lock(), "test.lock")
    kv.get("k")  # outside the lock: clean
    assert san_state.snapshot() == []
    with lock:
        kv.get("k")
    assert _kinds(san_state) == ["blocked-under-lock"]


def test_sanitizer_lock_order_inversion(san_state):
    a = sanitizer.track_lock(threading.Lock(), "lock.a")
    b = sanitizer.track_lock(threading.Lock(), "lock.b")
    with a:
        with b:
            pass
    assert san_state.snapshot() == []  # consistent order so far
    with b:
        with a:
            pass
    assert _kinds(san_state) == ["lock-order"]


def test_sanitizer_torn_read(san_state):
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=1))
    kv.mset({"pair/x": 1, "pair/y": 1})
    kv.mset({"pair/x": 2, "pair/y": 2})
    assert kv.mget(["pair/x", "pair/y"]) == [2, 2]
    assert san_state.snapshot() == []  # atomic batch observed whole
    # simulate a torn apply: revert one member behind the store's back
    sh = kv._shards[0]
    with sh.lock._inner:
        sh.data["pair/y"] = 1
    kv.mget(["pair/x", "pair/y"])
    assert _kinds(san_state) == ["torn-read"]


def test_sanitizer_preserves_isinstance_and_shard_waits(san_state):
    kv = sanitizer.SanitizingKVStore(KVStore(num_shards=2))
    assert isinstance(kv, KVStore)
    # shard-condition waiting still works over tracked locks
    seq = kv.shard_seq("wk")
    t = threading.Timer(0.05, lambda: kv.set("wk", 1))
    t.start()
    try:
        kv.wait_key("wk", seq, timeout_s=5.0)
    finally:
        t.join()
    assert kv.get("wk") == 1
    # the waiter held no tracked lock during its KV ops -> no reports
    assert san_state.snapshot() == []
