"""Multi-driver control plane: stateless scheduler handles + epoch fencing.

Pins the PR-4 contract:
  * **epoch fencing** — ``heartbeat``/``complete``/``release`` from a stale
    attempt epoch are rejected; a zombie's result publish is suppressed by
    the ``run_task`` fence; ``release`` burns the released epoch;
  * **statelessness** — a *fresh* ``Scheduler`` handle over an existing KV
    rebuilds its lease index from storage and reaps a foreign handle's
    expired lease; two handles racing one completion settle exactly once;
  * **two-scheduler soak** — 20 consecutive jobs through two executors
    sharing one KV/store under aggressive concurrent reap + speculate:
    zero lost tasks, exactly one visible result object per task, and no
    ``(task, epoch)`` ever completes twice;
  * **cross-process** — a spawned subprocess worker pool over shared
    ``FileKVStore``/``FileBackend`` executes a map submitted by this
    process, event-driven end to end (the driver's fallback-tick counter
    stays 0 and the job completes well inside the event-driven deadline).

And the PR-7 contract (KV-resident job manifests, ``core/jobs.py``):
  * **driver-lease fencing** — term monotonicity across acquire / takeover /
    release, heartbeat rejection at a stale term, first-writer-wins record
    commits, and the event-driven expiry wait;
  * **re-entrancy** — re-running ``run_stage``/``mapreduce`` with the same
    ``job_id`` resumes from recorded barriers with ZERO resubmitted tasks;
  * **driver-kill suite** — a subprocess driver is SIGKILLed between the
    map and reduce stages of a ``mapreduce`` (and between the partition and
    merge stages of a ``terasort``) over ``FileKVStore``/``FileBackend``;
    this process adopts via ``bsp.adopt_job`` and finishes with zero lost
    tasks, no duplicate results, and the ``shuffle/`` + ``sched/job/``
    keyspaces empty after the terminal ``finish_job``.
"""

import os
import subprocess
import sys
import time

import pytest

from repro.core import (
    FaultPlan,
    FunctionSpec,
    Scheduler,
    SchedulerConfig,
    TaskSpec,
    WrenExecutor,
    adopt_job,
    get_all,
    run_task,
    stage_input,
)
from repro.core import jobs
from repro.storage import FileBackend, FileKVStore, KVStore, ObjectStore

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _mk(**cfg):
    store = ObjectStore()
    kv = KVStore(num_shards=2)
    sched = Scheduler(kv, store, SchedulerConfig(**cfg))
    func = FunctionSpec.register(store, lambda x: x)
    return store, kv, sched, func


def _submit_one(store, sched, func, job="fence", idx=0, value=1):
    task = TaskSpec.make(job, func, stage_input(store, job, value), idx)
    sched.submit(task)
    return task


# ---------------------------------------------------------------------------
# epoch-fencing primitives
# ---------------------------------------------------------------------------

def test_lease_assigns_monotonic_epochs():
    store, kv, sched, func = _mk(lease_timeout_s=0.05)
    task = _submit_one(store, sched, func)
    t1 = sched.lease_next("w0")
    assert t1 is not None and t1.epoch == 1
    time.sleep(0.1)
    assert sched.reap() == 1  # expired; requeued
    t2 = sched.lease_next("w1")
    assert t2 is not None and t2.epoch == 2
    assert sched.epoch(task) == 2


def test_stale_heartbeat_and_complete_rejected():
    store, kv, sched, func = _mk(lease_timeout_s=0.05)
    task = _submit_one(store, sched, func, job="hb")
    t1 = sched.lease_next("w0")
    time.sleep(0.1)
    assert sched.reap() == 1
    t2 = sched.lease_next("w1")
    # the zombie's heartbeat must not extend the new attempt's lease
    assert sched.heartbeat(t1, "w0") is False
    assert sched.heartbeat(t2, "w1") is True
    # the zombie's complete must not free the new attempt's lease or
    # contribute a duration sample
    assert sched.complete(t1, "w0", 99.0) is False
    assert kv.get("sched/lease/" + task.task_id) is not None
    assert kv.get("sched/durations/hb") is None
    # the owner's complete wins exactly once
    assert sched.complete(t2, "w1", 0.01) is True
    assert kv.get("sched/lease/" + task.task_id) is None
    assert kv.lrange("sched/durations/hb") == [0.01]


def test_zombie_result_publish_is_fenced():
    store, kv, sched, func = _mk(lease_timeout_s=0.05)
    task = _submit_one(store, sched, func, job="zpub", value=7)
    t1 = sched.lease_next("w0")
    time.sleep(0.1)
    assert sched.reap() == 1  # t1 is now a zombie attempt
    res1 = run_task(store, t1, worker="w0", fence=lambda: sched.owns_lease(t1))
    assert res1.fenced and res1.success
    assert not store.backend.exists(task.result_key)  # publish suppressed
    t2 = sched.lease_next("w1")
    res2 = run_task(store, t2, worker="w1", fence=lambda: sched.owns_lease(t2))
    assert not res2.fenced
    assert store.get(task.result_key).value == 7
    assert len(store.list(task.result_key)) == 1


def test_release_burns_epoch_and_requeues():
    store, kv, sched, func = _mk()
    task = _submit_one(store, sched, func, job="rel")
    t1 = sched.lease_next("w0")
    assert t1.epoch == 1 and sched.attempts(task) == 1
    sched.release(t1, "w0")
    # epoch burned: the released attempt can no longer act
    assert sched.epoch(task) == 2
    assert sched.owns_lease(t1) is False
    assert sched.heartbeat(t1, "w0") is False
    # attempt charge undone, task back in the queue with a fresh epoch next
    assert sched.attempts(task) == 0
    t2 = sched.lease_next("w1")
    assert t2 is not None and t2.epoch == 3
    # double-release from the stale epoch is a fenced no-op
    sched.release(t1, "w0")
    assert sched.queue_depth() == 0


def test_two_handles_exactly_once_complete():
    store, kv, sched, func = _mk()
    sched2 = Scheduler(kv, store, sched.config)
    task = _submit_one(store, sched, func, job="race")
    t1 = sched.lease_next("w0")
    wins = [sched.complete(t1, "w0", 0.01), sched2.complete(t1, "w0", 0.01)]
    assert wins.count(True) == 1
    assert kv.lrange("sched/durations/race") == [0.01]  # one sample, not two


def test_fresh_handle_recovers_foreign_lease():
    """Statelessness: a second handle that never saw the submit rebuilds the
    lease index from the KV and reaps the first handle's dead worker."""
    store, kv, sched, func = _mk(lease_timeout_s=0.05)
    task = _submit_one(store, sched, func, job="foreign")
    assert sched.lease_next("w0") is not None
    sched2 = Scheduler(kv, store, SchedulerConfig(lease_timeout_s=0.05))
    time.sleep(0.1)
    assert sched2.reap() == 1  # refresh_index folded in the foreign lease
    t2 = sched2.lease_next("w1")
    assert t2 is not None and t2.task_id == task.task_id and t2.epoch == 2


def test_two_handles_speculate_once():
    """The setnx speculation mark dedupes across handles: one straggler gets
    exactly one duplicate no matter how many drivers watch the job."""
    store, kv, sched, func = _mk(
        lease_timeout_s=30.0,
        min_completed_for_speculation=1,
        min_speculation_age_s=0.01,
        speculation_k=1.0,
    )
    sched2 = Scheduler(kv, store, sched.config)
    task = _submit_one(store, sched, func, job="spec1")
    assert sched.lease_next("w0") is not None
    kv.rpush("sched/durations/spec1", 0.001, worker="t")  # tiny q95
    time.sleep(0.05)  # past the floor: task is now a straggler
    sched2.refresh_index()  # handle B learns the lease from the KV
    total = sched.speculate() + sched2.speculate()
    assert total == 1
    dups = kv.lrange("sched/queue")
    assert [d.task_id for d in dups] == [task.task_id]  # exactly one duplicate


# ---------------------------------------------------------------------------
# quantile-adaptive speculation rule
# ---------------------------------------------------------------------------

def test_straggler_threshold_quantile_vs_legacy():
    durations = [0.1] * 18 + [0.2, 1.0]  # q95 = 0.2, median = 0.1
    quantile_cfg = SchedulerConfig(speculation_quantile=0.95, speculation_k=2.0)
    assert quantile_cfg.straggler_threshold_s(durations) == pytest.approx(0.4)
    legacy = SchedulerConfig(speculation_factor=3.0)
    assert legacy.straggler_threshold_s(durations) == pytest.approx(0.3)
    # the floor wins for microsecond-scale no-op distributions
    noop = [1e-5] * 20
    assert quantile_cfg.straggler_threshold_s(noop) == quantile_cfg.min_speculation_age_s


def test_straggler_threshold_fenced_zombie_backoff():
    """Every fenced zombie multiplies the threshold: a job that keeps
    fencing live workers was speculating too eagerly, so it backs off
    (and with enough zombies, effectively stops)."""
    durations = [0.1] * 20
    cfg = SchedulerConfig(speculation_quantile=0.95, speculation_k=2.0)
    base = cfg.straggler_threshold_s(durations)
    assert cfg.straggler_threshold_s(durations, fenced=1) == pytest.approx(2 * base)
    assert cfg.straggler_threshold_s(durations, fenced=9) == pytest.approx(10 * base)
    # the backoff multiplies the *floored* threshold too
    noop = [1e-5] * 20
    assert cfg.straggler_threshold_s(noop, fenced=3) == pytest.approx(
        4 * cfg.min_speculation_age_s
    )
    # knob off → no backoff
    off = SchedulerConfig(speculation_zombie_backoff=0.0)
    assert off.straggler_threshold_s(durations, fenced=50) == pytest.approx(
        off.straggler_threshold_s(durations)
    )


def test_speculation_budget_formula():
    cfg = SchedulerConfig(speculation_budget_frac=0.10)
    assert cfg.speculation_budget(1) == 1  # small jobs may still hedge once
    assert cfg.speculation_budget(9) == 1
    assert cfg.speculation_budget(20) == 2
    assert cfg.speculation_budget(100) == 10


def test_speculation_budget_caps_duplicates():
    """A job of 20 tasks with a 10% budget gets at most 2 duplicates no
    matter how many tasks look like stragglers."""
    store, kv, sched, func = _mk(
        lease_timeout_s=30.0,
        min_completed_for_speculation=1,
        min_speculation_age_s=0.01,
        speculation_k=1.0,
        speculation_budget_frac=0.10,
    )
    n = 20
    tasks = [
        TaskSpec.make("budget", func, stage_input(store, "budget", i), i)
        for i in range(n)
    ]
    sched.submit_many(tasks)
    for i in range(n):
        assert sched.lease_next(f"w{i}") is not None
    kv.rpush("sched/durations/budget", 0.001, worker="t")  # tiny q95
    time.sleep(0.05)  # every leased task is past the floor: all stragglers
    assert sched.speculate() == 2  # 10% of 20, not 20
    assert kv.get("sched/speccount/budget") == 2
    assert kv.llen("sched/queue") == 2
    # later passes add nothing: the budget is spent for the job's lifetime
    time.sleep(0.25)  # durations cache expires; candidates still pending
    assert sched.speculate() == 0
    assert kv.llen("sched/queue") == 2


def test_fenced_zombies_stop_speculation():
    """With fenced-zombie completions recorded, the same straggler that
    would have been duplicated is left alone (threshold backed off)."""
    store, kv, sched, func = _mk(
        lease_timeout_s=30.0,
        min_completed_for_speculation=1,
        min_speculation_age_s=0.01,
        speculation_k=1.0,
    )
    task = _submit_one(store, sched, func, job="zfb")
    assert sched.lease_next("w0") is not None
    kv.rpush("sched/durations/zfb", 0.001, worker="t")
    kv.incr("sched/fenced/zfb", 50, worker="t")  # job kept fencing zombies
    time.sleep(0.05)  # past the un-backed-off floor
    assert sched.speculate() == 0  # threshold now 51x the floor: no dup
    assert kv.llen("sched/queue") == 0
    # scrub the feedback → the straggler is duplicated after all
    kv.delete("sched/fenced/zfb", worker="t")
    sched._dur_cache.clear()  # drop the cached (durations, fenced) read
    total = sched.speculate()
    assert total == 1
    dups = kv.lrange("sched/queue")
    assert [d.task_id for d in dups] == [task.task_id]


def test_fenced_complete_increments_zombie_counter():
    # decay off: pins that a won complete is never *counted* as a fence
    # (the default decay path is pinned separately below)
    store, kv, sched, func = _mk(lease_timeout_s=0.05, speculation_zombie_decay=0.0)
    _submit_one(store, sched, func, job="zc")
    t1 = sched.lease_next("w0")
    time.sleep(0.1)
    assert sched.reap() == 1
    t2 = sched.lease_next("w1")
    # the zombie's complete is fenced AND counted as feedback
    assert sched.complete(t1, "w0", 9.9) is False
    assert kv.get("sched/fenced/zc") == 1
    # the owner's complete is not counted (and, with decay off, not healed)
    assert sched.complete(t2, "w1", 0.01) is True
    assert kv.get("sched/fenced/zc") == 1


def test_won_complete_decays_zombie_counter():
    """The zombie backoff heals: each un-fenced (won) completion decays the
    job's fenced counter by ``speculation_zombie_decay``, deleting the key
    at zero — a transient fencing blip doesn't suppress speculation for
    the rest of a long job."""
    store, kv, sched, func = _mk(lease_timeout_s=0.05)  # default decay = 1.0
    for i in range(2):
        _submit_one(store, sched, func, job="zd", idx=i, value=i)
    t1 = sched.lease_next("w0")
    time.sleep(0.1)
    assert sched.reap() == 1
    t1b = sched.lease_next("w1")
    assert sched.complete(t1, "w0", 9.9) is False  # fenced zombie
    assert kv.get("sched/fenced/zd") == 1
    # a clean completion heals the backoff; the key is deleted at zero
    assert sched.complete(t1b, "w1", 0.01) is True
    assert kv.get("sched/fenced/zd") is None
    # further wins on a never-fenced-again job leave the keyspace alone
    t2 = sched.lease_next("w2")
    assert sched.complete(t2, "w2", 0.01) is True
    assert kv.get("sched/fenced/zd") is None


def test_zombie_decay_gated_on_observed_fences():
    """A handle only pays the decay round-trip for jobs it has *seen*
    fence — via its own fenced complete (local hint) or a nonzero count in
    its speculate() cache (fences raised by another driver).  A foreign
    fence the handle never observed is left un-decayed."""
    store, kv, sched, func = _mk()
    for i in range(2):
        _submit_one(store, sched, func, job="zg", idx=i, value=i)
    # a foreign driver's fence, invisible to this handle
    kv.incr("sched/fenced/zg", 2, worker="other-driver")
    t0 = sched.lease_next("w0")
    assert sched.complete(t0, "w0", 0.01) is True
    assert kv.get("sched/fenced/zg") == 2  # unobserved -> untouched
    # once the speculate() cache has seen the count, wins decay it
    sched._dur_cache["zg"] = (time.monotonic(), [0.01], 2)
    t1 = sched.lease_next("w1")
    assert sched.complete(t1, "w1", 0.01) is True
    assert kv.get("sched/fenced/zg") == 1


def test_finish_job_gcs_speculation_feedback_keys():
    store, kv, sched, func = _mk()
    task = _submit_one(store, sched, func, job="gcf")
    t1 = sched.lease_next("w0")
    run_task(store, t1, worker="w0")
    sched.complete(t1, "w0", 0.01)
    kv.incr("sched/speccount/gcf", 1, worker="t")
    kv.incr("sched/fenced/gcf", 1, worker="t")
    sched.finish_job("gcf")
    assert kv.get("sched/speccount/gcf") is None
    assert kv.get("sched/fenced/gcf") is None
    assert kv.get("sched/attempts/" + task.task_id) is None


# ---------------------------------------------------------------------------
# two-scheduler soak (shared in-memory KV, concurrent reap/speculate)
# ---------------------------------------------------------------------------

SOAK_ITERATIONS = 20


def test_two_driver_soak_exactly_once_per_epoch():
    """20 consecutive jobs through two executors sharing one KV/store with
    aggressive leases + speculation and an injected straggler: no lost
    tasks, one visible result object per task, and no (task, epoch) pair
    ever completes twice."""
    store = ObjectStore()
    kv = KVStore(num_shards=2)
    cfg = SchedulerConfig(
        lease_timeout_s=0.25,  # short: running tasks get reaped under load
        max_attempts=1000,  # churn must re-attempt, not drop
        min_completed_for_speculation=3,
        min_speculation_age_s=0.02,
        speculation_k=1.0,
    )
    completions = []  # (task_id, epoch) of every fenced-complete win

    def _instrument(sched):
        orig = sched.complete

        def wrapped(task, worker, duration_s):
            won = orig(task, worker, duration_s)
            if won:
                completions.append((task.task_id, task.epoch))
            return won

        sched.complete = wrapped

    wex_a = WrenExecutor(
        store=store, kv=kv, num_workers=2, scheduler_config=cfg,
        fault_plan=FaultPlan(slowdown={"w0000": 200.0}), seed=1,
    )
    wex_b = WrenExecutor(store=store, kv=kv, num_workers=2, scheduler_config=cfg, seed=2)
    _instrument(wex_a.scheduler)
    _instrument(wex_b.scheduler)
    try:
        for i in range(SOAK_ITERATIONS):
            driver = wex_a if i % 2 == 0 else wex_b
            job = f"soak-{i}"
            n = 12
            futs = driver.map(lambda x: x * 2, list(range(n)), job_id=job)
            # zero lost tasks: every future resolves with the right value
            assert get_all(futs, timeout_s=60) == [x * 2 for x in range(n)]
            # exactly one visible result object per task (duplicates lost
            # the if_absent race or were fenced)
            assert len(store.list(f"result/{job}/")) == n
            driver.finish_job(job)
        # epoch fencing verified: a (task, epoch) pair never completes twice
        assert len(completions) == len(set(completions)), (
            "duplicate fenced completion for the same attempt epoch"
        )
    finally:
        wex_a.shutdown()
        wex_b.shutdown()


# ---------------------------------------------------------------------------
# cross-process: FileKVStore + FileBackend with a subprocess worker pool
# ---------------------------------------------------------------------------

# Wall-clock bound for the 16-task cross-process map below.  Event-driven
# wakes are bounded by the watcher's 50 ms max backoff; with the old 250 ms
# fallback tick on both the queue pops and the driver's result waits the
# job serializes into multi-second tick waits.  15 s leaves CI slack while
# still failing hard on event loss (the pre-watcher behavior measured ~2-4x
# this bound under load).
CROSS_PROCESS_DEADLINE_S = 15.0


def _spawn_worker_pool(kv_root: str, obj_root: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "worker", kv_root, obj_root],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
    )


def test_cross_process_map_is_event_driven(tmp_path):
    """A subprocess worker pool over shared FileKVStore/FileBackend executes
    a map submitted here: queue pushes wake the child's blpop, result
    publishes wake this driver's futures — no fallback ticks anywhere."""
    kv_root = str(tmp_path / "kv")
    obj_root = str(tmp_path / "obj")
    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    # num_workers=0: every task MUST be executed by the subprocess
    wex = WrenExecutor(
        store=store, kv=kv, num_workers=0,
        scheduler_config=SchedulerConfig(lease_timeout_s=10.0),
    )
    proc = _spawn_worker_pool(kv_root, obj_root)
    try:
        deadline = time.monotonic() + 30
        while kv.get("ctl/ready") is None:
            assert proc.poll() is None, proc.stdout.read().decode()
            assert time.monotonic() < deadline, "subprocess pool never came up"
            time.sleep(0.05)
        n = 16
        t0 = time.monotonic()
        futs = wex.map(lambda x: x * 3, list(range(n)), job_id="xproc")
        assert get_all(futs, timeout_s=60) == [x * 3 for x in range(n)]
        wall = time.monotonic() - t0
        # exactly-once visibility across the process boundary
        assert len(store.list("result/xproc/")) == n
        # event-driven end to end: the driver never fell back to a tick...
        assert store.fallback_tick_waits == 0
        # ...and the job cleared the event-driven deadline
        assert wall < CROSS_PROCESS_DEADLINE_S, f"map took {wall:.1f}s"
    finally:
        kv.rpush("ctl/shutdown", 1, worker="driver")
        try:
            proc.wait(timeout=15)
        except subprocess.TimeoutExpired:
            proc.kill()
        wex.shutdown()
        kv.close()


def _worker_pool_main(kv_root: str, obj_root: str) -> None:
    """Subprocess entry: a worker pool over the shared directory stores.
    Its Scheduler handle shares *all* state with the parent's through the
    file KV — it leases tasks the parent submitted and publishes results
    the parent's futures wake on."""
    from repro.core import Scheduler, SchedulerConfig, WorkerPool
    from repro.storage import FileBackend, FileKVStore, ObjectStore

    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    sched = Scheduler(kv, store, SchedulerConfig(lease_timeout_s=10.0))
    pool = WorkerPool(store, sched, num_workers=2)
    kv.set("ctl/ready", 1, worker="child")
    # blpop is the cross-process event-driven wait under test: the parent's
    # shutdown push wakes it directly.
    while kv.blpop("ctl/shutdown", timeout_s=5.0) is None:
        pass
    pool.stop_all()


# ---------------------------------------------------------------------------
# job manifests (core/jobs.py): driver-lease fencing primitives
# ---------------------------------------------------------------------------

def test_driver_lease_term_monotonic():
    """Acquire → 1; expired takeover → 2; release keeps the record (term
    intact) so the next acquisition still draws term + 1."""
    kv = KVStore(num_shards=2)
    rec = jobs.acquire_driver(kv, "j", "drvA", 30.0)
    assert rec["owner"] == "drvA" and rec["term"] == 1
    # a live foreign driver can't take it
    rec2 = jobs.acquire_driver(kv, "j", "drvB", 30.0)
    assert rec2["owner"] == "drvA" and rec2["term"] == 1
    # re-acquire by the owner extends, same term
    rec3 = jobs.acquire_driver(kv, "j", "drvA", 30.0)
    assert rec3["term"] == 1 and rec3["expires"] > rec["expires"]
    # release keeps the record, expired
    assert jobs.release_driver(kv, "j", "drvA", 1) is True
    kept = jobs.driver_record(kv, "j")
    assert kept["term"] == 1 and kept["expires"] == 0.0
    # next acquisition fences at term + 1
    rec4 = jobs.acquire_driver(kv, "j", "drvB", 30.0)
    assert rec4["owner"] == "drvB" and rec4["term"] == 2
    # expired (not released) lease is also taken at term + 1
    rec5 = jobs.acquire_driver(kv, "j2", "drvA", 0.0)  # expires immediately
    assert rec5["term"] == 1
    rec6 = jobs.acquire_driver(kv, "j2", "drvB", 30.0)
    assert rec6["owner"] == "drvB" and rec6["term"] == 2


def test_driver_heartbeat_fenced_by_term_and_gc():
    kv = KVStore(num_shards=2)
    jobs.acquire_driver(kv, "hb", "drvA", 30.0)
    # the holder's heartbeat extends
    assert jobs.heartbeat_drivers(kv, {"hb": 1}, "drvA", 30.0) == []
    # a stale term (zombie after takeover) is rejected, record untouched
    jobs.release_driver(kv, "hb", "drvA", 1)
    rec = jobs.acquire_driver(kv, "hb", "drvB", 30.0)
    assert rec["term"] == 2
    assert jobs.heartbeat_drivers(kv, {"hb": 1}, "drvA", 30.0) == ["hb"]
    assert jobs.driver_record(kv, "hb")["owner"] == "drvB"
    # a GC'd job (key gone) is reported lost and NOT resurrected
    kv.eval("sched/job/gone/driver", lambda cur: None)
    assert jobs.heartbeat_drivers(kv, {"gone": 1}, "drvA", 30.0) == ["gone"]
    assert jobs.driver_record(kv, "gone") is None


def test_commit_records_first_writer_wins():
    kv = KVStore(num_shards=2)
    key = jobs.barrier_key("fw", 0)
    first = jobs.commit_records(kv, {key: {"outputs": [1], "term": 1}})
    assert first[key]["outputs"] == [1]
    # a later writer (zombie replaying the same stage) gets the STORED value
    second = jobs.commit_records(kv, {key: {"outputs": [2], "term": 2}})
    assert second[key]["outputs"] == [1]


def test_wait_for_driver_expiry_event_driven():
    kv = KVStore(num_shards=2)
    # absent lease: adoptable immediately
    assert jobs.wait_for_driver_expiry(kv, "nolease", 1.0) is True
    # live lease: not adoptable within the timeout
    jobs.acquire_driver(kv, "live", "drvA", 30.0)
    t0 = time.monotonic()
    assert jobs.wait_for_driver_expiry(kv, "live", 0.2) is False
    assert time.monotonic() - t0 < 5.0
    # short lease: the wait runs out exactly at the recorded expiry
    jobs.acquire_driver(kv, "dying", "drvA", 0.15)
    assert jobs.wait_for_driver_expiry(kv, "dying", 10.0) is True


# ---------------------------------------------------------------------------
# re-entrancy: same job_id resumes from the recorded barrier, zero resubmits
# ---------------------------------------------------------------------------

def _count_submits(wex, counter):
    orig = wex.scheduler.submit_many

    def wrapped(tasks):
        counter.append(len(tasks))
        return orig(tasks)

    wex.scheduler.submit_many = wrapped


def test_run_stage_reentrant_zero_resubmits():
    from repro.core.bsp import run_stage

    submits = []
    with WrenExecutor(num_workers=2) as wex:
        _count_submits(wex, submits)
        out1 = run_stage(wex, lambda x: x + 1, [1, 2, 3], job_id="rs-re")
        assert out1 == [2, 3, 4]
        assert sum(submits) == 3
        # second call: barrier recorded → stored outputs, no task traffic
        out2 = run_stage(wex, lambda x: x + 1, [1, 2, 3], job_id="rs-re")
        assert out2 == [2, 3, 4]
        assert sum(submits) == 3
        # the driver lease is released (not deleted) between calls; the SAME
        # owner re-acquiring is an extension, not a takeover — term stays 1
        rec = jobs.driver_record(wex.kv, "rs-re")
        assert rec["expires"] == 0.0 and rec["term"] == 1
        # gc=True drops the manifest keyspace entirely
        run_stage(wex, lambda x: x + 1, [1, 2, 3], job_id="rs-re", gc=True)
        assert wex.kv.scan("sched/job/rs-re/") == []


def test_mapreduce_reentrant_resumes_from_barriers():
    from repro.core.bsp import mapreduce

    submits = []
    with WrenExecutor(num_workers=2) as wex:
        _count_submits(wex, submits)
        expected = {k: sum(x for x in range(20) if x % 4 == k) for k in range(4)}
        out = mapreduce(
            wex,
            lambda part: [(x % 4, x) for x in part],
            lambda _k, vs: sum(vs),
            [list(range(0, 10)), list(range(10, 20))],
            4,
            job_id="mr-re",
        )
        assert out == expected
        assert sum(submits) == 2 + 4  # maps + reduces, exactly once
        # terminal finish_job dropped the manifest with the job
        assert wex.kv.scan("sched/job/mr-re/") == []


# ---------------------------------------------------------------------------
# driver-kill suite: SIGKILL the submitting subprocess mid-job, adopt here
# ---------------------------------------------------------------------------

# Deterministic workload shared by parent (expectations) and child (submit).
_KILL_PARTS = [list(range(0, 10)), list(range(10, 20)), list(range(20, 30))]
_KILL_REDUCERS = 5


def _kill_expected():
    allx = [x for part in _KILL_PARTS for x in part]
    return {k: sum(x for x in allx if x % _KILL_REDUCERS == k)
            for k in range(_KILL_REDUCERS)}


def _spawn_kill_driver(kv_root: str, obj_root: str, kind: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "killdriver", kv_root, obj_root, kind],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
    )


def _kill_driver_main(kv_root: str, obj_root: str, kind: str) -> None:
    """Subprocess entry: submit a job, then SIGKILL ourselves the instant a
    chosen stage barrier commits — a real uncatchable driver death at the
    exact stage boundary the suite pins (map→reduce for mapreduce,
    partition→merge for terasort).  No release, no cleanup: the parent must
    adopt through the lease expiry path alone."""
    import signal

    import numpy as np

    from repro.core import WrenExecutor, SchedulerConfig
    from repro.core import bsp
    from repro.storage import FileBackend, FileKVStore, ObjectStore

    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    wex = WrenExecutor(
        store=store, kv=kv, num_workers=2,
        scheduler_config=SchedulerConfig(driver_lease_timeout_s=1.0),
    )

    kill_after = {"mr": 0, "sort": 1}[kind]
    orig_barrier = bsp._stage_barrier

    def killing_barrier(wex_, job, idx, plan, outputs, **kw):
        out = orig_barrier(wex_, job, idx, plan, outputs, **kw)
        if idx == kill_after:
            kv.set("ctl/barrier-committed", 1, worker="child")
            os.kill(os.getpid(), signal.SIGKILL)
        return out

    bsp._stage_barrier = killing_barrier

    if kind == "mr":
        bsp.mapreduce(
            wex,
            lambda part: [(x % _KILL_REDUCERS, x) for x in part],
            lambda _k, vs: sum(vs),
            _KILL_PARTS,
            _KILL_REDUCERS,
            job_id="kill-mr",
        )
    else:
        rng = np.random.default_rng(7)
        keys = []
        for i in range(3):
            recs = rng.integers(0, 256, size=(40, 100), dtype=np.uint8)
            key = f"sortin/part{i}"
            store.put(key, recs, worker="gen")
            keys.append(key)
        bsp.terasort(
            wex, keys, "sorted", num_partitions=4, intermediate=store,
            job_id="kill-sort",
        )
    raise SystemExit("driver survived past the kill barrier")  # pragma: no cover


def _adopt_after_kill(tmp_path, kind: str):
    """Shared driver-kill scaffold: spawn the submitting driver, confirm it
    died by SIGKILL after the chosen barrier, then adopt from this process
    over the same FileKVStore/FileBackend roots."""
    kv_root = str(tmp_path / "kv")
    obj_root = str(tmp_path / "obj")
    proc = _spawn_kill_driver(kv_root, obj_root, kind)
    try:
        proc.wait(timeout=60)
    except subprocess.TimeoutExpired:
        proc.kill()
        pytest.fail("kill-driver subprocess never reached the kill barrier")
    assert proc.returncode == -9, proc.stdout.read().decode()

    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    assert kv.get("ctl/barrier-committed") == 1
    wex = WrenExecutor(
        store=store, kv=kv, num_workers=2,
        scheduler_config=SchedulerConfig(driver_lease_timeout_s=1.0),
    )
    return kv, store, wex


def test_driver_sigkilled_between_map_and_reduce_is_adopted(tmp_path):
    """The headline pin: the submitting driver is SIGKILLed the instant the
    map barrier commits; this process waits out the driver lease, fences the
    takeover at term 2, and replays — the map stage returns from its barrier
    (zero resubmitted map tasks), only the reduce stage runs, the merged
    result is exact (zero lost tasks, no duplicate contributions), and the
    shuffle/ + sched/job/ keyspaces are empty after the terminal GC."""
    kv, store, wex = _adopt_after_kill(tmp_path, "mr")
    try:
        submits = []
        _count_submits(wex, submits)
        t0 = time.monotonic()
        out = adopt_job(wex, "kill-mr", wait_timeout_s=30.0, timeout_s=120.0)
        adoption_wall = time.monotonic() - t0
        assert out == _kill_expected()
        # the recorded map barrier was honored: only reduce tasks moved
        assert sum(submits) == _KILL_REDUCERS
        # the adopter holds (held) the fenced term
        assert kv.get("sched/finished/kill-mr") is not None
        # keyspaces empty after finish_job: manifest, shuffle, results
        assert kv.scan("sched/job/kill-mr/") == []
        assert store.list("shuffle/") == []
        assert store.list("result/") == []
        # detect → fence → replay happened promptly (lease 1 s + replay)
        assert adoption_wall < 30.0
    finally:
        wex.shutdown()
        kv.close()


def test_driver_sigkilled_between_partition_and_merge_terasort(tmp_path):
    """Same death, two stages deep: the sort driver dies the instant the
    partition barrier commits (intermediates fully written, merge never
    planned).  The adopter re-derives splitters from the recorded sample
    barrier, runs only the merge stage, and the output is globally sorted
    with every record accounted for."""
    from repro.core.bsp import verify_sorted

    kv, store, wex = _adopt_after_kill(tmp_path, "sort")
    try:
        submits = []
        _count_submits(wex, submits)
        report = adopt_job(wex, "kill-sort", wait_timeout_s=30.0, timeout_s=120.0)
        assert report is not None and report.n_records == 3 * 40
        # sample + partition barriers honored: only the 4 merge tasks moved
        assert sum(submits) == 4
        assert verify_sorted(store, "sorted")
        total = sum(len(store.get(k)) for k in store.list("sorted"))
        assert total == 3 * 40  # zero lost records, no duplicates
        assert kv.scan("sched/job/kill-sort/") == []
        assert store.list("shuffle/") == []
    finally:
        wex.shutdown()
        kv.close()


def test_adopt_job_returns_none_for_finished_job():
    with WrenExecutor(num_workers=2) as wex:
        from repro.core.bsp import run_stage

        run_stage(wex, lambda x: x, [1], job_id="done-job", gc=True)
        # tombstoned and GC'd: nothing to adopt, no lease resurrected
        assert adopt_job(wex, "done-job", wait_timeout_s=5.0) is None
        assert wex.kv.scan("sched/job/done-job/") == []


def test_adopt_job_times_out_on_live_driver():
    store = ObjectStore()
    kv = KVStore(num_shards=2)
    wex_a = WrenExecutor(store=store, kv=kv, num_workers=1)
    wex_b = WrenExecutor(store=store, kv=kv, num_workers=1)
    try:
        assert wex_a.register_driver("held-job") == 1
        with pytest.raises(TimeoutError):
            adopt_job(wex_b, "held-job", wait_timeout_s=0.3)
        # after an explicit release the job is immediately adoptable (and,
        # with no manifest, finished-or-empty → None + lease scrubbed)
        wex_a.release_driver("held-job")
        assert adopt_job(wex_b, "held-job", wait_timeout_s=5.0) is None
    finally:
        wex_a.shutdown()
        wex_b.shutdown()


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "worker":
        _worker_pool_main(sys.argv[2], sys.argv[3])
    elif len(sys.argv) == 5 and sys.argv[1] == "killdriver":
        _kill_driver_main(sys.argv[2], sys.argv[3], sys.argv[4])
    else:
        raise SystemExit(
            f"usage: {sys.argv[0]} worker <kv_root> <obj_root> | "
            f"killdriver <kv_root> <obj_root> mr|sort"
        )
