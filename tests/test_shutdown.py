"""Regression tests for the thread-shutdown bug cascade and the
event-driven control plane.

Pins:
  * ``Worker`` must not shadow ``threading.Thread._stop`` (CPython private
    method) — ``join()`` after ``kill()``/``stop()`` returns cleanly;
  * ``WorkerPool.stop_all()`` terminates promptly (workers are woken out of
    blocked lease waits, not left to time out);
  * repeated ``scale_to`` up/down converges to exactly ``n`` runnable
    containers (liveness tracked by a not-stopped predicate, not thread
    aliveness alone);
  * scale-down mid-job loses no tasks;
  * scale-down *preemption* releases leased-but-unstarted batch tasks back
    to the queue immediately (epoch-invalidated), instead of stranding
    them until lease expiry — the PR-4 ``scale_to`` race fix;
  * ``wait_keys`` / futures return promptly (well under the heartbeat
    interval) once a result is published — the event-driven contract.
"""

import threading
import time

import pytest

from repro.core import SchedulerConfig, WrenExecutor, get_all
from repro.storage import KVStore, ObjectStore

HEARTBEAT_S = 0.2  # SchedulerConfig.heartbeat_interval_s default


def test_join_after_kill_returns_cleanly():
    wex = WrenExecutor(num_workers=2)
    try:
        assert wex.map_get(lambda x: x, [1, 2], timeout_s=30) == [1, 2]
        w = wex.pool.workers[0]
        w.kill()
        w.join(timeout=5.0)  # seed bug: raised TypeError ('Event' not callable)
        assert not w.is_alive()
    finally:
        wex.shutdown()


def test_stop_all_terminates_within_timeout():
    wex = WrenExecutor(num_workers=4)
    assert wex.map_get(lambda x: x + 1, list(range(8)), timeout_s=30) == list(range(1, 9))
    t0 = time.monotonic()
    wex.shutdown()
    assert time.monotonic() - t0 < 5.0
    assert wex.pool.alive_count() == 0


def test_scale_converges_to_exact_runnable_count():
    wex = WrenExecutor(num_workers=4)
    try:
        for n in [1, 5, 2, 6, 3, 0, 3]:
            wex.scale_to(n)
            assert len(wex.pool.runnable_workers()) == n, f"scale_to({n})"
        # killed workers actually exit (they are woken, not stuck polling)
        deadline = time.monotonic() + 5.0
        while wex.pool.alive_count() > 3 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert wex.pool.alive_count() == 3
    finally:
        wex.shutdown()


def test_scale_down_mid_job_loses_no_tasks():
    wex = WrenExecutor(num_workers=8, seed=7)
    try:
        futs = wex.map(lambda x: x * 3, list(range(60)))
        wex.scale_to(3)
        wex.scale_to(1)  # thrash down while the queue drains
        wex.scale_to(4)
        assert get_all(futs, timeout_s=60) == [x * 3 for x in range(60)]
        assert len(wex.pool.runnable_workers()) == 4
    finally:
        wex.shutdown()


def test_scale_down_releases_unstarted_leases_promptly():
    """A worker that leased a batch right before ``scale_to`` stopped it
    must hand its unstarted leases straight back with their epochs burned —
    with a 30 s lease timeout, anything that relied on expiry would stall
    the queue far past this test's deadlines."""
    store = ObjectStore()
    kv = KVStore(num_shards=2)
    cfg = SchedulerConfig(lease_timeout_s=30.0)  # expiry cannot help in time
    wex = WrenExecutor(store=store, kv=kv, num_workers=0, scheduler_config=cfg)
    try:
        def gated(x):
            # closures over KV handles pickle by reference, so the test can
            # gate the first task's completion from outside
            kv.set(f"started/{x}", 1, worker="task")
            while kv.get("gate") is None:
                time.sleep(0.005)
            return x

        futs = wex.map(gated, list(range(8)), job_id="preempt")
        wex.scale_to(1)  # one worker leases a batch of 4, starts task 0
        deadline = time.monotonic() + 10
        while kv.get("started/0") is None or wex.scheduler.queue_depth() != 4:
            assert time.monotonic() < deadline, "worker never leased its batch"
            time.sleep(0.01)
        wex.scale_to(0)  # preempt while 3 leased tasks are still unstarted
        kv.set("gate", 1, worker="test")  # let the in-flight task finish
        # the 3 unstarted leases come back via release, well before expiry
        deadline = time.monotonic() + 5
        while wex.scheduler.queue_depth() != 7:
            assert time.monotonic() < deadline, (
                f"queue stuck at {wex.scheduler.queue_depth()} — leases stranded"
            )
            time.sleep(0.01)
        assert kv.scan("sched/lease/") == []  # nothing left leased
        # epochs: task 0 completed on epoch 1; tasks 1-3 were released and
        # their epoch burned (lease=1, release-invalidate=2); 4-7 unleased
        epochs = sorted(wex.scheduler.epoch(f.task) for f in futs)
        assert epochs == [0, 0, 0, 0, 1, 2, 2, 2]
        wex.scale_to(2)  # the released tasks are immediately re-leasable
        assert get_all(futs, timeout_s=30) == list(range(8))
    finally:
        kv.set("gate", 1, worker="test")
        wex.shutdown()


def test_wait_keys_returns_promptly_after_publish():
    """Event-driven pin: a publish through the store handle must wake
    ``wait_keys`` immediately — not after a poll interval or fallback tick."""
    store = ObjectStore()
    publish_delay = 0.15

    def _publish():
        time.sleep(publish_delay)
        store.publish_result("evt/r0", 42, worker="w")

    t = threading.Thread(target=_publish)
    t.start()
    t0 = time.monotonic()
    store.wait_keys(["evt/r0"], timeout_s=5.0)
    elapsed = time.monotonic() - t0
    t.join()
    assert elapsed < publish_delay + HEARTBEAT_S, (
        f"wait_keys took {elapsed:.3f}s; expected < {publish_delay + HEARTBEAT_S:.3f}s"
    )


def test_future_result_wakes_on_publish():
    with WrenExecutor(num_workers=2) as wex:
        [fut] = wex.map(lambda x: x ** 2, [9])
        t0 = time.monotonic()
        assert fut.result(timeout_s=30) == 81
        # sanity: no pathological stall (seed polled; events should be fast)
        assert time.monotonic() - t0 < 10.0


def test_wait_keys_timeout_still_raises():
    store = ObjectStore()
    with pytest.raises(TimeoutError):
        store.wait_keys(["never/exists"], timeout_s=0.3)
