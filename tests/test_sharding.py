"""Sharding rules + a small-device-count lowering of the real model code.

The production 512-device dry-run runs via launch/dryrun.py; here we verify
the same machinery on an 8-device host mesh in a subprocess (the XLA device
count must be set before jax initializes, so this cannot run in-process).
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_param_pspec_rules():
    out = run_sub(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import CONFIGS
        from repro.models import init_params
        from repro.models.sharding import param_pspec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = CONFIGS["llama3-8b"].reduced()
        shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        specs = param_pspec(mesh, shapes)
        # embeddings vocab-sharded over model (512 % 4 == 0)
        assert specs["embed"]["tok"] == P("model", ("data",)), specs["embed"]["tok"]
        # stacked (outer, period, D, H, hd): trailing dims follow the rule
        wq = specs["decoder"]["attn"]["wq"]
        assert tuple(wq)[-3:] == (("data",), "model", None) or tuple(wq)[-3:] == ("data", "model", None), wq
        print("OK")
        """
    )
    assert "OK" in out


def test_tiny_mesh_train_lowering_with_collectives():
    """Lower the real train step on an 8-device mesh with a reduced config;
    assert it compiles and emits collectives (the FSDP/TP proof at mini
    scale)."""
    out = run_sub(
        """
        import jax, jax.numpy as jnp, re
        from jax.sharding import NamedSharding
        from repro.configs import CONFIGS
        from repro.launch.shardings import batch_pspec, state_pspec, to_shardings
        from repro.train import adamw, make_train_step
        from repro.train.train_step import TrainState
        from repro.models import init_params

        import dataclasses
        cfg = dataclasses.replace(
            CONFIGS["llama3-8b"].reduced(),
            d_model=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
            vocab_size=512, n_layers=4,
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        opt = adamw(1e-3)
        def make():
            p = init_params(cfg, jax.random.PRNGKey(0))
            return TrainState(params=p, opt_state=opt.init(p))
        state_shapes = jax.eval_shape(make)
        ssh = to_shardings(mesh, state_pspec(mesh, state_shapes))
        state_structs = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            state_shapes, ssh)
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        bsh = to_shardings(mesh, batch_pspec(mesh, batch))
        batch_structs = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            batch, bsh)
        step = make_train_step(cfg, opt)
        with mesh:
            compiled = jax.jit(step, donate_argnums=(0,)).lower(
                state_structs, batch_structs).compile()
        txt = compiled.as_text()
        colls = re.findall(r"(all-reduce|all-gather|reduce-scatter)", txt)
        mem = compiled.memory_analysis()
        assert len(colls) > 0, "expected collectives in partitioned HLO"
        assert mem.argument_size_in_bytes > 0
        print("OK", len(colls))
        """
    )
    assert "OK" in out


def test_tiny_mesh_decode_lowering():
    out = run_sub(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import CONFIGS
        from repro.launch.shardings import cache_pspec, state_pspec, to_shardings
        from repro.models import decode_step, init_cache, init_params

        cfg = dataclasses.replace(
            CONFIGS["qwen3-32b"].reduced(),
            d_model=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
            vocab_size=512, n_layers=2,
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params_shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        psh = to_shardings(mesh, state_pspec(mesh, params_shapes))
        params_structs = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            params_shapes, psh)
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, 8, 128, cache_dtype=jnp.bfloat16))
        csh = to_shardings(mesh, cache_pspec(mesh, cfg, cache_shapes))
        cache_structs = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            cache_shapes, csh)
        fn = lambda p, t, c, l: decode_step(p, cfg, t, c, l)
        with mesh:
            compiled = jax.jit(fn, donate_argnums=(2,)).lower(
                params_structs,
                jax.ShapeDtypeStruct((8, 1), jnp.int32),
                cache_structs,
                jax.ShapeDtypeStruct((), jnp.int32),
            ).compile()
        assert compiled.memory_analysis().argument_size_in_bytes > 0
        print("OK")
        """
    )
    assert "OK" in out


def test_mesh_constructors():
    out = run_sub(
        """
        from repro.launch.mesh import make_mesh, mesh_num_devices
        m = make_mesh(dp=2, tp=4)
        assert m.axis_names == ("data", "model")
        assert mesh_num_devices(m) == 8
        m2 = make_mesh(dp=2, tp=2, pods=2)
        assert m2.axis_names == ("pod", "data", "model")
        print("OK")
        """
    )
    assert "OK" in out


def test_checkpoint_reshard_across_meshes():
    """Elastic remesh: checkpoint under (4,2), resume under (2,4) — losses
    continue (storage-resident state + stateless steps)."""
    out = run_sub(
        """
        import dataclasses, jax
        from repro.configs import CONFIGS
        from repro.data import DataConfig, synthetic_batch
        from repro.launch.mesh import make_mesh
        from repro.launch.shardings import state_pspec, to_shardings
        from repro.storage import ObjectStore
        from repro.train import TrainState, adamw, init_train_state, make_train_step
        from repro.train import checkpoint as ck

        cfg = dataclasses.replace(
            CONFIGS["llama3-8b"].reduced(), n_layers=2, d_model=128, d_ff=256,
            n_heads=4, n_kv_heads=4, head_dim=32, vocab_size=512,
        )
        opt = adamw(3e-3, weight_decay=0.0)
        dcfg = DataConfig(seq_len=16, global_batch=8, vocab_size=cfg.vocab_size)
        store = ObjectStore()

        def place(state, mesh):
            sh = to_shardings(mesh, state_pspec(mesh, state))
            return jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), state, sh)

        mesh_a = make_mesh(dp=4, tp=2)
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, opt))
        with mesh_a:
            state = place(state, mesh_a)
            first = None
            for i in range(6):
                state, m = step(state, synthetic_batch(dcfg, i, cfg))
                first = float(m["loss"]) if first is None else first
        ck.save(store, "rt", 1, tuple(state))

        mesh_b = make_mesh(dp=2, tp=4)
        loaded, _, _ = ck.load(store, "rt")
        state_b = TrainState(*loaded)
        with mesh_b:
            state_b = place(state_b, mesh_b)
            state_b, m = step(state_b, synthetic_batch(dcfg, 6, cfg))
        resumed = float(m["loss"])
        assert resumed < first, (resumed, first)
        print("OK", round(first, 3), "->", round(resumed, 3))
        """
    )
    assert "OK" in out
