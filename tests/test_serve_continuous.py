"""Continuous batching + lease-driven request plane.

The pins, straight from the PR contract:
  * a request arriving mid-decode is admitted at the next chunk boundary
    WITHOUT draining the running batch;
  * slot-cache isolation: a slot's new occupant never reads the previous
    occupant's KV;
  * parity: continuous batching emits exactly what the batch-synchronous
    `Engine.generate` emits for the same requests (greedy AND sampled);
  * leases: a lapsed lease is reaped and requeued exactly once; published
    results are never requeued;
  * SIGKILL one of two engines mid-stream: zero lost requests, zero
    duplicated/overwritten results (real subprocess, shared file backend).
"""

import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import ContinuousEngine, Engine, ServeConfig
from repro.serve import request_plane as rp
from repro.storage import FileBackend, FileKVStore, KVStore, ObjectStore

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))

_PARAMS = {}


def _setup(arch="qwen3-32b", **kw):
    cfg = CONFIGS[arch].reduced()
    if arch not in _PARAMS:
        _PARAMS[arch] = init_params(cfg, jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("max_new_tokens", 6)
    kw.setdefault("decode_chunk", 2)
    kw.setdefault("prefill_bucket", 8)
    scfg = ServeConfig(**kw)
    return cfg, _PARAMS[arch], scfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in lens]


# ---------------------------------------------------------------------------
# slot engine semantics (no request plane)
# ---------------------------------------------------------------------------

def test_mid_stream_admission_without_draining():
    """A request admitted at a chunk boundary joins slots that are mid-
    decode; the running batch keeps its positions and is never drained."""
    cfg, params, scfg = _setup(max_new_tokens=10)
    eng = ContinuousEngine(cfg, params, scfg)
    pa, pb = _prompts(cfg, [5, 9])
    eng.admit([("a", pa, 10)])
    eng.step_chunk(2)
    a_slot = next(s for s in eng.slots if s is not None)
    a_pos = int(eng.cache_lens[eng.slots.index(a_slot)])
    assert len(a_slot.out) == 3  # 1 at admit + 2 decode steps
    # b arrives mid-decode: admitted into a free slot, a is untouched
    eng.admit([("b", pb, 10)])
    assert eng.stats["mid_batch_admissions"] == 1
    assert eng.n_live() == 2
    assert len(a_slot.out) == 3  # no drain, no re-prefill
    assert int(eng.cache_lens[eng.slots.index(a_slot)]) == a_pos
    finished = {}
    for _ in range(20):
        done, _ = eng.step_chunk()
        finished.update({r: s.out for r, s in done.items()})
        if len(finished) == 2:
            break
    # both complete, and both match the batch-synchronous reference
    ref = Engine(cfg, params, scfg)
    for rid, prompt in (("a", pa), ("b", pb)):
        exp = ref.generate(jnp.asarray([prompt], jnp.int32))[0].tolist()
        assert finished[rid] == exp, rid


def test_slot_reuse_never_reads_prior_occupants_kv():
    """Serve a long-prompt request, then a short one through the SAME slot:
    the short request's output must equal a fresh single-request run (the
    insert replaces the slot's cache rows wholesale)."""
    cfg, params, scfg = _setup(max_batch=1)
    eng = ContinuousEngine(cfg, params, scfg)
    long_p, short_p = _prompts(cfg, [40, 4], seed=3)
    eng.admit([("long", long_p, 6)])
    while eng.n_live():
        eng.step_chunk()
    eng.admit([("short", short_p, 6)])
    out = {}
    while eng.n_live():
        done, _ = eng.step_chunk()
        out.update({r: s.out for r, s in done.items()})
    fresh = ContinuousEngine(cfg, params, scfg)
    fresh.admit([("short", short_p, 6)])
    exp = {}
    while fresh.n_live():
        done, _ = fresh.step_chunk()
        exp.update({r: s.out for r, s in done.items()})
    assert out["short"] == exp["short"]


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v3-671b", "xlstm-1.3b"])
def test_parity_with_batch_synchronous_generate(arch):
    """Mixed-length requests served continuously == per-request generate
    (which left-pads nothing at B=1).  Covers dense/GQA, MoE/MLA latent
    caches, and recurrent-state (exact-length prefill groups) families."""
    cfg, params, scfg = _setup(arch)
    eng = ContinuousEngine(cfg, params, scfg)
    store, kv = ObjectStore(), KVStore(num_shards=2)
    prompts = _prompts(cfg, [3, 11, 7, 5, 9], seed=1)
    for i, p in enumerate(prompts):
        rp.submit(store, kv, f"r{i}", p)
    eng.run(store, kv, engine_id="e0", idle_timeout_s=0.3)
    ref = Engine(cfg, params, scfg)
    res = rp.get_results(store, [f"r{i}" for i in range(len(prompts))], timeout_s=5)
    for i, p in enumerate(prompts):
        exp = ref.generate(jnp.asarray([p], jnp.int32))[0].tolist()
        assert res[f"r{i}"]["tokens"] == exp, f"r{i}"


def test_sampled_decode_per_request_deterministic_and_independent():
    cfg, params, scfg = _setup(temperature=0.8)
    store, kv = ObjectStore(), KVStore(num_shards=2)
    prompt = _prompts(cfg, [6], seed=5)[0]
    eng = ContinuousEngine(cfg, params, scfg)
    # same prompt, two ids -> independent streams
    rp.submit(store, kv, "x", prompt)
    rp.submit(store, kv, "y", prompt)
    eng.run(store, kv, engine_id="e0", idle_timeout_s=0.3)
    res = rp.get_results(store, ["x", "y"], timeout_s=5)
    assert res["x"]["tokens"] != res["y"]["tokens"]
    # re-serving the same id (fresh engine) replays the identical stream
    store2, kv2 = ObjectStore(), KVStore(num_shards=2)
    rp.submit(store2, kv2, "x", prompt)
    eng2 = ContinuousEngine(cfg, params, scfg)
    eng2.run(store2, kv2, engine_id="other", idle_timeout_s=0.3)
    assert store2.get(rp.done_key("x"))["tokens"] == res["x"]["tokens"]
    # and the batch-synchronous engine agrees when keyed the same way
    ref = Engine(cfg, params, scfg)
    exp = ref.generate(
        jnp.asarray([prompt], jnp.int32), seeds=[rp.request_seed("x")]
    )[0].tolist()
    assert res["x"]["tokens"] == exp


def test_streaming_chunks_arrive_before_completion():
    cfg, params, scfg = _setup(max_new_tokens=8, decode_chunk=2)
    eng = ContinuousEngine(cfg, params, scfg)
    store, kv = ObjectStore(), KVStore(num_shards=2)
    rp.submit(store, kv, "s", _prompts(cfg, [5])[0])
    leased = rp.lease_requests(store, kv, "e0", 1)
    eng.admit([(r, b["prompt"], 8) for r, b in leased])
    done, chunks = eng.step_chunk()
    rp.stream_chunks(kv, chunks, worker="e0")
    assert not done  # still mid-stream...
    assert kv.lrange(rp.stream_key("s")) == [{"off": 0, "toks": chunks["s"][1]}]
    while eng.n_live():
        done, chunks = eng.step_chunk()
        rp.stream_chunks(kv, chunks, worker="e0")
    rp.publish_results(store, kv, "e0", {r: {"tokens": s.out} for r, s in done.items()})
    # the streamed chunks concatenate to the published result, exactly once
    seen = [t for c in kv.lrange(rp.stream_key("s")) if "off" in c for t in c["toks"]]
    assert seen == store.get(rp.done_key("s"))["tokens"]


# ---------------------------------------------------------------------------
# request plane: leases, reaping
# ---------------------------------------------------------------------------

def test_lease_lapse_reaped_and_requeued_exactly_once():
    store, kv = ObjectStore(), KVStore(num_shards=2)
    rp.submit(store, kv, "r0", [1, 2, 3])
    leased = rp.lease_requests(store, kv, "dead", 4, lease_timeout_s=0.05)
    assert [r for r, _ in leased] == ["r0"]
    assert kv.llen(rp.queue_key(0)) == 0
    time.sleep(0.06)  # the lease lapses (its engine is "dead")
    assert rp.reap_expired(store, kv) == 1
    assert rp.reap_expired(store, kv) == 0  # exactly once
    relea = rp.lease_requests(store, kv, "alive", 4)
    assert [r for r, _ in relea] == ["r0"]
    rec = kv.mget([rp.lease_key("r0")])[0]
    assert rec["engine"] == "alive" and rec["term"] == 2  # re-serve = new term


def test_reap_drops_already_published_results():
    store, kv = ObjectStore(), KVStore(num_shards=2)
    rp.submit(store, kv, "r0", [1, 2])
    rp.lease_requests(store, kv, "e0", 4, lease_timeout_s=0.05)
    rp.publish_results(store, kv, "e0", {"r0": {"tokens": [7]}})
    time.sleep(0.06)
    assert rp.reap_expired(store, kv) == 0  # published: nothing to requeue
    assert kv.llen(rp.queue_key(0)) == 0
    # ...and a queue replay of a served id is consumed without re-leasing
    kv.rpush(rp.queue_key(0), "r0")
    assert rp.lease_requests(store, kv, "e1", 4) == []


def test_live_lease_blocks_other_engines():
    store, kv = ObjectStore(), KVStore(num_shards=2)
    rp.submit(store, kv, "r0", [1])
    assert len(rp.lease_requests(store, kv, "e0", 4, lease_timeout_s=30.0)) == 1
    kv.rpush(rp.queue_key(0), "r0")  # duplicate enqueue (e.g. double reap)
    assert rp.lease_requests(store, kv, "e1", 4) == []  # e0 still owns it
    rp.heartbeat_leases(kv, "e0", ["r0"], lease_timeout_s=30.0)
    rec = kv.mget([rp.lease_key("r0")])[0]
    assert rec["engine"] == "e0"


# ---------------------------------------------------------------------------
# SIGKILL one of two engines: zero lost, zero duplicated
# ---------------------------------------------------------------------------

_ENGINE_SCRIPT = r"""
import sys, time
import jax
from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import ContinuousEngine, ServeConfig
from repro.serve import request_plane as rp
from repro.storage import FileBackend, FileKVStore, ObjectStore

kv_root, obj_root, engine_id = sys.argv[1], sys.argv[2], sys.argv[3]
kv = FileKVStore(kv_root, num_shards=2)
store = ObjectStore(backend=FileBackend(obj_root))
cfg = CONFIGS["qwen3-32b"].reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
scfg = ServeConfig(max_batch=2, max_len=64, max_new_tokens=12,
                   decode_chunk=1, lease_timeout_s=1.0)
eng = ContinuousEngine(cfg, params, scfg)
print("READY", flush=True)
# Throttled serve loop (one decode step per tick) so the parent can land a
# SIGKILL while requests are demonstrably mid-stream with live leases.
while True:
    free = eng.free_slots()
    if free:
        leased = rp.lease_requests(store, kv, engine_id, len(free),
                                   lease_timeout_s=1.0, wait_s=0.2)
        if leased:
            eng.admit([(r, b["prompt"], int(b.get("max_new", 12)))
                       for r, b in leased])
    if eng.n_live() == 0:
        continue
    finished, chunks = eng.step_chunk(1)
    rp.stream_chunks(kv, chunks, worker=engine_id)
    rp.heartbeat_leases(kv, engine_id, eng.live_req_ids(), lease_timeout_s=1.0)
    if finished:
        rp.publish_results(store, kv, engine_id,
                           {r: {"tokens": s.out} for r, s in finished.items()})
    time.sleep(0.12)
"""


def test_sigkill_engine_zero_lost_zero_duplicated(tmp_path):
    kv_root, obj_root = str(tmp_path / "kv"), str(tmp_path / "obj")
    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    cfg = CONFIGS["qwen3-32b"].reduced()
    ids = [f"k{i}" for i in range(6)]
    prompts = _prompts(cfg, [4, 7, 5, 9, 6, 3], seed=11)
    for r, p in zip(ids, prompts):
        rp.submit(store, kv, r, p)

    env = dict(os.environ, PYTHONPATH=_SRC)
    proc = subprocess.Popen(
        [sys.executable, "-c", _ENGINE_SCRIPT, kv_root, obj_root, "victim"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        assert proc.stdout.readline().strip() == "READY"
        # kill once >=1 result is published but in-flight work remains
        deadline = time.monotonic() + 240
        while time.monotonic() < deadline:
            done = store.exists_many([rp.done_key(r) for r in ids])
            if 1 <= len(done) < len(ids):
                break
            time.sleep(0.05)
        else:
            pytest.fail("victim engine never reached a mid-stream state")
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()

    survivors_before = {
        k: store.get(k) for k in store.exists_many([rp.done_key(r) for r in ids])
    }
    assert survivors_before and len(survivors_before) < len(ids)

    # the second engine reaps the victim's lapsed leases and finishes
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=2, max_len=64, max_new_tokens=12,
                       decode_chunk=1, lease_timeout_s=1.0)
    eng_b = ContinuousEngine(cfg, params, scfg)
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:
        eng_b.run(store, kv, engine_id="survivor", idle_timeout_s=3.0)
        if len(store.exists_many([rp.done_key(r) for r in ids])) == len(ids):
            break
    res = rp.get_results(store, ids, timeout_s=10)

    # zero lost: every request has a result, and it is the correct one
    ref = Engine(cfg, params, scfg)
    for r, p in zip(ids, prompts):
        exp = ref.generate(jnp.asarray([p], jnp.int32))[0].tolist()
        assert res[r]["tokens"] == exp, r
    # zero duplicated: the victim's published results were not overwritten
    # by the survivor's replay (first-writer-wins pinned via the engine tag)
    for k, rec in survivors_before.items():
        now = store.get(k)
        assert now["engine"] == rec["engine"] == "victim", k
        assert now["tokens"] == rec["tokens"], k
    assert eng_b.stats["served"] >= 1
    kv.close()
