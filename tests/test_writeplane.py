"""Write-plane regressions: batched puts, per-shard write coalescing,
and shuffle-intermediate GC.

Pins the PR-3 contract (the write-side mirror of PR 2's batched reads):
  * ``ObjectStore.put_many``/``put_many_bytes`` — one backend call charged
    exactly one request latency + summed transfer, one ``notify_put`` for
    the whole batch, per-key first-writer-wins under ``if_absent``;
  * ``KVStore.mset``/``rpush_many``/``eval_many`` — one charged op and one
    shard-sequence bump per shard touched (a batch wakes each shard's
    watchers exactly once), with bit-identical results to looped writes;
  * ``shuffle.write_partitions`` — a map task's whole fan-out in one
    batched write; ``shuffle.delete_intermediates`` — the job's column
    space retired in one batched delete, and mapreduce/terasort leave no
    ``shuffle/{job}`` keys behind;
  * driver-side batching — ``wren.map`` stages all inputs in one ``mput``
    and submits all tasks in one pipelined push; ``ParameterServer``
    pushes ride ``eval_many``.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    ParameterServer,
    PSConfig,
    WrenExecutor,
    get_all,
    mapreduce,
    terasort,
    verify_sorted,
)
from repro.storage import KVStore, ObjectStore
from repro.storage import shuffle as shf


# ---------------------------------------------------------------------------
# ObjectStore.put_many
# ---------------------------------------------------------------------------

def test_put_many_single_amortized_round_trip():
    """N objects must cost one request latency + summed transfer — the
    perf-model accounting must equal the formula exactly."""
    store = ObjectStore()
    items = {f"k/{i}": bytes(100) for i in range(32)}
    store.put_many_bytes(items, worker="w")
    recs = [r for r in store.ledger.records() if r.op == "mput"]
    assert len(recs) == 1
    total = sum(len(b) for b in items.values())
    expected = store.profile.write_latency_s + total / store.profile.write_bw_per_conn
    assert recs[0].nbytes == total
    assert abs(recs[0].vtime_s - expected) < 1e-12
    # amortized: far cheaper than 32 independent puts would have been
    assert recs[0].vtime_s < 32 * store.profile.write_latency_s / 2


def test_put_many_parity_with_looped_puts():
    """Batched and looped writes must leave bit-identical store contents."""
    values = {f"p/{i}": {"i": i, "blob": "x" * i} for i in range(16)}
    batched, looped = ObjectStore(), ObjectStore()
    batched.put_many(values)
    for k, v in values.items():
        looped.put(k, v)
    assert batched.get_many(list(values)) == looped.get_many(list(values))
    assert batched.list("p/") == looped.list("p/")


def test_put_many_single_notify_wakes_waiters():
    """The whole batch fires exactly one put notification — and that one
    wakeup is enough for a waiter blocked on any key of the batch."""
    store = ObjectStore()
    seq0 = store.put_seq()
    store.put_many({f"n/{i}": i for i in range(8)})
    assert store.put_seq() == seq0 + 1  # one bump for 8 objects

    woken = []

    def waiter():
        store.wait_keys(["n2/5"], timeout_s=5.0)
        woken.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    store.put_many({f"n2/{i}": i for i in range(8)})
    t.join(timeout=5.0)
    assert woken and time.monotonic() - t0 < 0.5


def test_put_many_if_absent_first_writer_wins():
    store = ObjectStore()
    store.put("a", "old")
    won = store.put_many({"a": "new", "b": "fresh"}, if_absent=True)
    assert won == 1  # only 'b' landed
    assert store.get("a") == "old"
    assert store.get("b") == "fresh"
    # empty batch: no round-trip charged, no notify
    store.ledger.clear()
    seq = store.put_seq()
    assert store.put_many({}) == 0
    assert store.ledger.records() == []
    assert store.put_seq() == seq


def test_delete_many_single_round_trip():
    store = ObjectStore()
    store.put_many({f"d/{i}": i for i in range(8)})
    store.ledger.clear()
    store.delete_many([f"d/{i}" for i in range(8)], worker="gc")
    assert [r.op for r in store.ledger.records()] == ["mdel"]
    assert store.list("d/") == []


# ---------------------------------------------------------------------------
# KVStore.mset / rpush_many / eval_many: per-shard coalescing
# ---------------------------------------------------------------------------

def test_mset_one_charge_and_one_wakeup_per_shard():
    kv = KVStore(num_shards=4)
    mapping = {f"ms/{i}": i for i in range(16)}
    shards = {kv.shard_of(k) for k in mapping}
    seqs_before = {s: kv._shards[s].seq for s in shards}
    before = kv.total_ops()
    kv.mset(mapping)
    # one charged op per shard touched, not one per key
    assert kv.total_ops() - before == len(shards)
    # each touched shard's sequence bumped exactly once for the whole batch
    for s in shards:
        assert kv._shards[s].seq == seqs_before[s] + 1
    assert kv.mget(list(mapping)) == list(mapping.values())


def test_mset_parity_with_looped_sets():
    mapping = {f"par/{i}": [i, str(i)] for i in range(12)}
    batched, looped = KVStore(num_shards=3), KVStore(num_shards=3)
    batched.mset(mapping)
    for k, v in mapping.items():
        looped.set(k, v)
    assert batched.mget(list(mapping)) == looped.mget(list(mapping))


def test_rpush_many_returns_lengths_and_wakes_blpop():
    kv = KVStore(num_shards=2)
    kv.rpush("q/a", "seed")
    got = []

    def consumer():
        got.append(kv.blpop("q/b", timeout_s=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    lengths = kv.rpush_many({"q/a": [1, 2], "q/b": ["payload"]})
    t.join(timeout=5.0)
    assert lengths["q/a"] == 3
    assert got == ["payload"]  # woken by the batched push itself
    assert time.monotonic() - t0 < 0.5
    # one charged op per shard touched
    ops = [r.op for r in kv.ledger.records() if r.op == "mrpush"]
    shards = {kv.shard_of("q/a"), kv.shard_of("q/b")}
    assert len(ops) == len(shards)


def test_eval_many_atomic_per_key_one_wakeup_per_shard():
    kv = KVStore(num_shards=4)
    kv.mset({"e/x": 10, "e/y": 20})
    keys = ("e/x", "e/y", "e/new")
    touched = {kv.shard_of(k) for k in keys}
    seqs_before = {s: kv._shards[s].seq for s in touched}
    out = kv.eval_many(
        {"e/x": lambda v: v + 1, "e/y": lambda v: v * 2, "e/new": lambda v: (v or 0) + 5}
    )
    assert out == {"e/x": 11, "e/y": 40, "e/new": 5}
    assert kv.get("e/x") == 11 and kv.get("e/y") == 40 and kv.get("e/new") == 5
    # one sequence bump per touched shard, regardless of how many keys landed
    for s in touched:
        assert kv._shards[s].seq == seqs_before[s] + 1


def test_eval_many_charges_per_shard():
    kv = KVStore(num_shards=4)
    keys = [f"ev/{i}" for i in range(12)]
    kv.mset({k: 0 for k in keys})
    before = kv.total_ops()
    kv.eval_many({k: (lambda v: v + 1) for k in keys})
    assert kv.total_ops() - before == len({kv.shard_of(k) for k in keys})
    assert kv.mget(keys) == [1] * 12


# ---------------------------------------------------------------------------
# shuffle: batched write_partitions + intermediate GC
# ---------------------------------------------------------------------------

def test_write_partitions_one_request_object_store():
    store = ObjectStore()
    parts = [[(p, i) for i in range(3)] for p in range(6)]
    store.ledger.clear()
    n = shf.write_partitions(store, "job", 0, parts, worker="m0")
    assert n == 6
    writes = [r for r in store.ledger.records() if r.op in ("put", "mput")]
    assert [r.op for r in writes] == ["mput"]  # whole fan-out, one request
    col = shf.read_partition_column(store, "job", 1, 2, worker="r2")
    assert col == parts[2]


def test_write_partitions_per_shard_kv_store():
    kv = KVStore(num_shards=2)
    parts = [[(p, i) for i in range(3)] for p in range(6)]
    kv.ledger.clear()
    shf.write_partitions(kv, "job", 0, parts, worker="m0")
    writes = [r for r in kv.ledger.records() if r.op in ("set", "mset")]
    assert all(r.op == "mset" for r in writes)
    assert len(writes) <= kv.num_shards  # one per shard touched, never per key
    col = shf.read_partition_column(kv, "job", 1, 4, worker="r4")
    assert col == parts[4]


@pytest.mark.parametrize("kind", ["obj", "kv"])
def test_delete_intermediates_retires_column_space(kind):
    store = KVStore(num_shards=2) if kind == "kv" else ObjectStore()
    n_maps, n_parts = 3, 4
    for m in range(n_maps):
        shf.write_partitions(store, "gcjob", m, [[m, p] for p in range(n_parts)])
    deleted = shf.delete_intermediates(store, "gcjob", n_maps, n_parts)
    assert deleted == n_maps * n_parts
    for m in range(n_maps):
        for p in range(n_parts):
            key = shf.intermediate_key("gcjob", m, p)
            if kind == "kv":
                assert not store.exists(key)
            else:
                assert not store.backend.exists(key)
    if kind == "obj":
        assert store.list("shuffle/gcjob/") == []
    # zombie guard: a straggler map attempt finishing after GC must not
    # resurrect the deleted column space (its write is dropped)
    assert shf.write_partitions(store, "gcjob", 0, [[9], [9]]) == 0
    assert not (
        store.exists(shf.intermediate_key("gcjob", 0, 0))
        if kind == "kv"
        else store.backend.exists(shf.intermediate_key("gcjob", 0, 0))
    )
    # job ids are single-use after GC — but clearing the tombstone is the
    # explicit escape hatch that revives the name
    shf.clear_gc_tombstone(store, "gcjob")
    assert shf.write_partitions(store, "gcjob", 0, [[9], [9]]) == 2


def test_mapreduce_leaves_no_shuffle_intermediates():
    docs = [[f"w{i % 5} w{(i * 3) % 7}" for i in range(10)] for _ in range(4)]
    with WrenExecutor(num_workers=4) as wex:
        out = mapreduce(
            wex,
            lambda doc: [(w, 1) for line in doc for w in line.split()],
            lambda _k, vs: sum(vs),
            docs,
            num_reducers=3,
        )
        assert sum(out.values()) == sum(len(l.split()) for d in docs for l in d)
        assert wex.store.list("shuffle/") == []  # GC'd after merge


def test_terasort_leaves_no_shuffle_intermediates_kv():
    with WrenExecutor(num_workers=4) as wex:
        store = wex.store
        keys = []
        for i in range(3):
            k = f"tin/{i}"
            store.put(k, shf.make_sort_records(40, seed=i))
            keys.append(k)
        kv = KVStore(num_shards=2)
        rep = terasort(wex, keys, "tout", 4, intermediate=kv)
        assert verify_sorted(store, "tout")
        assert rep.n_records == 3 * 40
        # every shuffle/<job> KV key retired after merge
        for sh in kv._shards:
            assert not any(k.startswith("shuffle/") for k in sh.data)


# ---------------------------------------------------------------------------
# driver-side batching: input staging + batch submit
# ---------------------------------------------------------------------------

def test_map_stages_inputs_in_one_batched_put():
    with WrenExecutor(num_workers=2) as wex:
        wex.store.ledger.clear()
        futs = wex.map(lambda x: x * 3, list(range(10)), job_id="batched")
        driver_puts = [
            r
            for r in wex.store.ledger.records()
            if r.worker == "driver" and r.op in ("put", "mput")
        ]
        # one mput stages all 10 inputs; the only per-key put is the
        # content-addressed function registration
        assert sum(1 for r in driver_puts if r.op == "mput") == 1
        assert sum(1 for r in driver_puts if r.op == "put") <= 1
        assert get_all(futs, timeout_s=30) == [x * 3 for x in range(10)]


def test_submit_many_single_pipelined_push():
    with WrenExecutor(num_workers=2) as wex:
        # map → submit_many: the queue push must be one mrpush, not N rpushes
        wex.kv.ledger.clear()
        futs = wex.map(lambda x: x + 1, list(range(8)), job_id="pipelined")
        pushes = [
            r
            for r in wex.kv.ledger.records()
            if r.worker == "scheduler" and r.op in ("rpush", "mrpush")
        ]
        assert [r.op for r in pushes] == ["mrpush"]
        assert get_all(futs, timeout_s=30) == [x + 1 for x in range(8)]


def test_stage_inputs_content_addressing_dedupes():
    from repro.core import stage_inputs

    store = ObjectStore()
    keys = stage_inputs(store, "dj", [1, 2, 1, 2, 1], worker="driver")
    assert len(keys) == 5
    assert keys[0] == keys[2] == keys[4]  # identical items share one object
    assert len(set(keys)) == 2
    assert store.get(keys[0]) == 1 and store.get(keys[1]) == 2


# ---------------------------------------------------------------------------
# parameter server: batched pushes
# ---------------------------------------------------------------------------

def test_ps_push_is_batched_eval_many():
    kv = KVStore(num_shards=4)
    ps = ParameterServer(kv, np.zeros(64, np.float32), PSConfig(num_blocks=8))
    kv.ledger.clear()
    applied = ps.push_delta(np.ones(64, np.float32), worker="pusher")
    assert applied == 8
    ops = [r.op for r in kv.ledger.records() if r.worker == "pusher"]
    assert set(ops) == {"meval"}
    # two batched phases (block data, then version bumps — data must land
    # first), each at most one round-trip per shard, never one per block
    assert len(ops) <= 2 * 4
    params, vers = ps.pull()
    np.testing.assert_allclose(params, np.ones(64, np.float32))
    assert vers == [1] * 8


def test_ps_push_lands_data_before_versions():
    """A version bump must never publish ahead of its block data: the push
    writes all blocks in one eval_many, then all versions in a second, so
    any ledger 'meval' touching a version key comes after every block
    write.  (A wait_fresh reader woken by the version bump would otherwise
    pull stale block data believing it fresh.)"""
    kv = KVStore(num_shards=4)
    ps = ParameterServer(kv, np.zeros(64, np.float32), PSConfig(num_blocks=8))
    kv.ledger.clear()
    ps.push_delta(np.ones(64, np.float32), worker="pusher")
    mevals = [r for r in kv.ledger.records() if r.op == "meval"]
    # first half of the meval records carries block bytes (float arrays),
    # second half the integer version counters — sizes tell them apart
    assert len(mevals) >= 2
    half = len(mevals) // 2
    data_bytes = sum(r.nbytes for r in mevals[:half])
    version_bytes = sum(r.nbytes for r in mevals[half:])
    assert data_bytes > version_bytes  # data phase strictly precedes versions


def test_ps_push_staleness_still_rejects():
    kv = KVStore(num_shards=2)
    ps = ParameterServer(kv, np.zeros(8, np.float32), PSConfig(num_blocks=2, max_staleness=0))
    # advance every block once
    assert ps.push_delta(np.ones(8, np.float32), pulled_versions=[0, 0]) == 2
    # a push based on the stale snapshot is rejected block-wise
    assert ps.push_delta(np.ones(8, np.float32), pulled_versions=[-1, -1]) == 0
    params, vers = ps.pull()
    np.testing.assert_allclose(params, np.ones(8, np.float32))
    assert vers == [1, 1]


def test_ps_batched_push_wakes_wait_fresh():
    kv = KVStore(num_shards=2)
    ps = ParameterServer(kv, np.zeros(8, np.float32), PSConfig(num_blocks=2))

    def pusher():
        time.sleep(0.05)
        ps.push_delta(np.ones(8, np.float32))

    t = threading.Thread(target=pusher)
    t.start()
    t0 = time.monotonic()
    ver = ps.wait_fresh(1, seen_version=0, timeout_s=5.0)
    t.join()
    assert ver >= 1
    assert time.monotonic() - t0 < 1.0  # eval_many's shard touch woke us
