"""End-to-end behaviour: the paper's full story on one runtime instance —
featurize (map) -> monolithic reduce -> BSP wordcount -> PS training -> 
elastic LM training with a mid-run failure."""

import numpy as np

from repro.core import (
    ParameterServer,
    PSConfig,
    WrenExecutor,
    hogwild_sgd,
    run_stage,
    word_count,
)
from repro.data import make_documents, shard_corpus, tokenize_line


def test_map_then_monolithic_reduce():
    """§3.3 'Map + monolithic Reduce': parallel featurization, single-node
    model fit — the ImageNet-GIST workflow shape on synthetic data."""
    with WrenExecutor(num_workers=4) as wex:
        docs = make_documents(8, 5, seed=1)
        store = wex.store  # close over the store handle (pickles by-ref),
        keys = shard_corpus(store, "corpus", docs)  # never over the executor

        def featurize(key):
            doc = store.get(key, worker="feat")
            feats = np.zeros(64)
            for line in doc:
                for tok in tokenize_line(line, 64):
                    feats[tok] += 1.0
            out_key = key.replace("corpus/", "feats/")
            store.put(out_key, feats, worker="feat")
            return out_key

        feat_keys = run_stage(wex, featurize, keys)
        # monolithic reduce: fetch all features to 'one machine' and fit
        X = np.stack([store.get(k) for k in feat_keys])
        w = np.linalg.lstsq(X, np.ones(len(X)), rcond=None)[0]
        assert np.isfinite(w).all()


def test_full_pipeline_wordcount_and_ps():
    with WrenExecutor(num_workers=4) as wex:
        docs = make_documents(6, 4, seed=2)
        wc = word_count(wex, docs, num_reducers=2)
        assert sum(wc.values()) == sum(len(l.split()) for d in docs for l in d)

        # parameter server: least squares via HOGWILD
        rng = np.random.default_rng(0)
        true_w = rng.normal(size=8)
        shards = []
        for _ in range(4):
            X = rng.normal(size=(16, 8))
            shards.append((X, X @ true_w))
        ps = ParameterServer(wex.kv, np.zeros(8), PSConfig(num_blocks=2))
        w = hogwild_sgd(
            wex, ps,
            lambda w, s: 2 * s[0].T @ (s[0] @ w - s[1]) / len(s[1]),
            shards, steps_per_worker=40, lr=0.02,
        )
        assert np.linalg.norm(w - true_w) < 0.2


def test_elastic_lm_training_with_failure():
    import jax
    from repro.configs import CONFIGS
    from repro.data import DataConfig, synthetic_batch
    from repro.train import ElasticTrainConfig, adamw, train_elastic
    from repro.train import checkpoint as ck

    cfg = CONFIGS["llama3-8b"].reduced()
    dcfg = DataConfig(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)
    opt = adamw(1e-3)
    wex = WrenExecutor(num_workers=2)
    try:
        tcfg = ElasticTrainConfig(run="sys", steps_per_chunk=2, total_steps=4)
        hist = train_elastic(
            wex, cfg, opt, tcfg, lambda s: synthetic_batch(dcfg, s, cfg)
        )
        assert len(hist) == 2
        # kill a worker, then keep training — the runtime must still finish
        wex.pool.kill_worker(0)
        tcfg2 = ElasticTrainConfig(run="sys", steps_per_chunk=2, total_steps=8)
        hist2 = train_elastic(
            wex, cfg, opt, tcfg2, lambda s: synthetic_batch(dcfg, s, cfg)
        )
        assert len(hist2) == 2
        assert ck.latest_version(wex.store, "sys") == 4
    finally:
        wex.shutdown()
