"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.

All Pallas kernels run in interpret mode on CPU (the TPU target cannot
execute here); the chunked-jnp production paths are validated against the
same oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba2_ssd import ssd_pallas
from repro.kernels.mlstm_kernel import mlstm_pallas


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, K, D, causal, window, cap, q_offset
    (2, 128, 128, 4, 4, 64, True, None, None, 0),
    (1, 256, 256, 8, 2, 64, True, None, None, 0),      # GQA 4:1
    (1, 128, 128, 4, 1, 128, True, None, None, 0),     # MQA
    (2, 128, 128, 4, 2, 32, True, 64, None, 0),        # sliding window
    (1, 128, 128, 2, 2, 64, True, None, 50.0, 0),      # softcap (gemma2)
    (1, 128, 256, 4, 4, 64, True, None, None, 128),    # continuation offset
    (1, 128, 128, 2, 1, 64, False, None, None, 0),     # encoder (full)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, Sq, Sk, H, K, D, causal, window, cap, off = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = _rand(rng, (B, Sq, H, D), dtype)
    k = _rand(rng, (B, Sk, K, D), dtype)
    v = _rand(rng, (B, Sk, K, D), dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, logit_cap=cap, q_offset=off,
        block_q=64, block_k=64,
    )
    exp = ref.mha_reference(
        q, k, v, causal=causal, window=window, logit_cap=cap, q_offset=off
    )
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol)


@given(
    st.integers(1, 2), st.sampled_from([64, 128, 192]), st.sampled_from([1, 2, 4]),
    st.sampled_from([32, 64]), st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_chunked_attention_matches_ref(B, S, K, D, causal):
    H = K * 2
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, S, H, D))
    k = _rand(rng, (B, S, K, D))
    v = _rand(rng, (B, S, K, D))
    out = ops._attention_chunked_jnp(
        q, k, v, causal=causal, window=None, logit_cap=None, q_offset=0,
        scale=D**-0.5, block_k=64,
    )
    exp = ref.mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_attention_mla_head_dims():
    """Dv != Dqk (MLA): jnp path must handle it."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (2, 300, 8, 192))
    k = _rand(rng, (2, 300, 8, 192))
    v = _rand(rng, (2, 300, 8, 128))
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.mha_reference(q, k, v, causal=True)
    assert out.shape == (2, 300, 8, 128)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_flash_attention_grad_finite():
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 384, 4, 32))
    k = _rand(rng, (1, 384, 2, 32))
    v = _rand(rng, (1, 384, 2, 32))

    def loss(q):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True, block_k=128) ** 2)

    g = jax.grad(loss)(q)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,K,D,window,cap", [
    (256, 8, 2, 64, None, None),
    (512, 4, 4, 32, None, None),
    (256, 8, 1, 128, 64, None),
    (256, 4, 2, 64, None, 30.0),
])
def test_decode_attention_pallas_vs_ref(S, H, K, D, window, cap):
    B = 3
    rng = np.random.default_rng(S + H)
    q = _rand(rng, (B, H, D))
    kc = _rand(rng, (B, S, K, D))
    vc = _rand(rng, (B, S, K, D))
    clen = jnp.asarray([S, S // 2, 17], jnp.int32)
    out = decode_attention_pallas(q, kc, vc, clen, window=window, logit_cap=cap, block_k=128)
    exp = ref.decode_attention_reference(q, kc, vc, clen, window=window, logit_cap=cap)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("S,block_k", [
    (97, 32),    # prime cache length, partial final block
    (300, 256),  # the old `assert S % block_k == 0` crash shape
    (130, 64),
])
def test_decode_attention_pallas_partial_block(S, block_k):
    """Arbitrary max_len values: the final partial cache block is padded and
    masked instead of tripping an assert."""
    B, H, K, D = 2, 4, 2, 32
    rng = np.random.default_rng(S)
    q = _rand(rng, (B, H, D))
    kc = _rand(rng, (B, S, K, D))
    vc = _rand(rng, (B, S, K, D))
    clen = jnp.asarray([S, S // 3], jnp.int32)
    out = decode_attention_pallas(q, kc, vc, clen, block_k=block_k)
    exp = ref.decode_attention_reference(q, kc, vc, clen)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,P,G,N,chunk", [
    (128, 4, 16, 2, 8, 32),
    (256, 2, 32, 1, 16, 64),
    (192, 8, 8, 4, 4, 64),   # pad path for jnp (192 % 64 == 0 though)
])
def test_ssd_pallas_vs_sequential(S, H, P, G, N, chunk):
    B = 2
    rng = np.random.default_rng(S)
    x = _rand(rng, (B, S, H, P))
    dt = jax.nn.softplus(_rand(rng, (B, S, H)))
    A = -jnp.exp(_rand(rng, (H,)))
    Bm = _rand(rng, (B, S, G, N))
    Cm = _rand(rng, (B, S, G, N))
    D = _rand(rng, (H,))
    out = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk)
    exp = ref.ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(out, exp, atol=5e-4, rtol=1e-3)


@given(st.integers(1, 3), st.sampled_from([60, 100, 128]))
@settings(max_examples=8, deadline=None)
def test_ssd_jnp_chunked_pad_path(B, S):
    """ops.ssd_scan must be exact also when S is not a chunk multiple."""
    H, P, G, N = 2, 8, 1, 4
    rng = np.random.default_rng(B * S)
    x = _rand(rng, (B, S, H, P))
    dt = jax.nn.softplus(_rand(rng, (B, S, H)))
    A = -jnp.exp(_rand(rng, (H,)))
    Bm = _rand(rng, (B, S, G, N))
    Cm = _rand(rng, (B, S, G, N))
    out = ops.ssd_scan(x, dt, A, Bm, Cm, None, chunk=32)
    exp = ref.ssd_reference(x, dt, A, Bm, Cm, None)
    np.testing.assert_allclose(out, exp, atol=5e-4, rtol=1e-3)


def test_ssd_decode_step_matches_scan():
    B, S, H, P, G, N = 2, 24, 4, 8, 2, 4
    rng = np.random.default_rng(7)
    x = _rand(rng, (B, S, H, P))
    dt = jax.nn.softplus(_rand(rng, (B, S, H)))
    A = -jnp.exp(_rand(rng, (H,)))
    Bm = _rand(rng, (B, S, G, N))
    Cm = _rand(rng, (B, S, G, N))
    y_seq = ref.ssd_reference(x, dt, A, Bm, Cm, None)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        state, y = ops.ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_seq, atol=1e-4, rtol=1e-3)


def test_ssd_prefill_state_continues_decode():
    """State returned by ssd_scan(return_state=True) must seamlessly continue."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 4
    rng = np.random.default_rng(9)
    x = _rand(rng, (B, S + 8, H, P))
    dt = jax.nn.softplus(_rand(rng, (B, S + 8, H)))
    A = -jnp.exp(_rand(rng, (H,)))
    Bm = _rand(rng, (B, S + 8, G, N))
    Cm = _rand(rng, (B, S + 8, G, N))
    full = ref.ssd_reference(x, dt, A, Bm, Cm, None)
    _, state = ops.ssd_scan(
        x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], None, chunk=32, return_state=True
    )
    outs = []
    for t in range(S, S + 8):
        state, y = ops.ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs, 1), full[:, S:], atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,D,bq,bk", [
    (128, 2, 32, 64, 64),
    (256, 4, 16, 128, 64),
])
def test_mlstm_pallas_vs_ref(S, H, D, bq, bk):
    B = 2
    rng = np.random.default_rng(S + D)
    q = _rand(rng, (B, S, H, D))
    k = _rand(rng, (B, S, H, D))
    v = _rand(rng, (B, S, H, D))
    ig = _rand(rng, (B, S, H))
    fg = _rand(rng, (B, S, H)) + 2.0
    out = mlstm_pallas(q, k, v, ig, fg, block_q=bq, block_k=bk)
    exp = ref.mlstm_reference(q, k, v, ig, fg)
    np.testing.assert_allclose(out, exp, atol=5e-4, rtol=1e-3)


def test_mlstm_chunked_jnp_matches_ref():
    B, S, H, D = 1, 512, 2, 16
    rng = np.random.default_rng(11)
    q = _rand(rng, (B, S, H, D))
    k = _rand(rng, (B, S, H, D))
    v = _rand(rng, (B, S, H, D))
    ig = _rand(rng, (B, S, H))
    fg = _rand(rng, (B, S, H)) + 1.0
    out = ops._mlstm_chunked_jnp(q, k, v, ig, fg, block_k=128)
    exp = ref.mlstm_reference(q, k, v, ig, fg)
    np.testing.assert_allclose(out, exp, atol=5e-4, rtol=1e-3)


def test_mlstm_recurrent_matches_parallel():
    B, S, H, D = 2, 48, 2, 8
    rng = np.random.default_rng(13)
    q = _rand(rng, (B, S, H, D))
    k = _rand(rng, (B, S, H, D))
    v = _rand(rng, (B, S, H, D))
    ig = _rand(rng, (B, S, H))
    fg = _rand(rng, (B, S, H)) + 1.0
    par = ref.mlstm_reference(q, k, v, ig, fg)
    c = jnp.zeros((B, H, D, D))
    n = jnp.zeros((B, H, D))
    m = jnp.full((B, H), -1e9)
    outs = []
    for t in range(S):
        (c, n, m), h = ops.mlstm_decode_step(c, n, m, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t])
        outs.append(h)
    np.testing.assert_allclose(jnp.stack(outs, 1), par, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_attention_is_permutation_invariant_over_batch(seed):
    """Property: attention over batch rows is independent."""
    rng = np.random.default_rng(seed)
    B, S, H, D = 4, 64, 2, 16
    q = _rand(rng, (B, S, H, D))
    k = _rand(rng, (B, S, H, D))
    v = _rand(rng, (B, S, H, D))
    out = ref.mha_reference(q, k, v, causal=True)
    perm = np.asarray([2, 0, 3, 1])
    out_p = ref.mha_reference(q[perm], k[perm], v[perm], causal=True)
    np.testing.assert_allclose(out[perm], out_p, atol=1e-6)


@given(st.floats(1.0, 100.0))
@settings(max_examples=10, deadline=None)
def test_softcap_bounds_logits(cap):
    x = jnp.linspace(-1e4, 1e4, 64)
    y = ref.softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap * (1 + 1e-6)
