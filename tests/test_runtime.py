"""Serverless runtime: map semantics, fault tolerance, speculation,
idempotency, elasticity."""

import time

import pytest

from repro.core import (
    FaultPlan,
    FunctionSpec,
    ResultFuture,
    Scheduler,
    SchedulerConfig,
    TaskSpec,
    WrenExecutor,
    get_all,
    stage_input,
    wait,
)
from repro.core.futures import ANY_COMPLETED
from repro.storage import KVStore, ObjectStore


def test_map_basic():
    with WrenExecutor(num_workers=4) as wex:
        assert wex.map_get(lambda x: x * 2, list(range(20))) == [x * 2 for x in range(20)]


def test_map_mirrors_python_map_semantics():
    with WrenExecutor(num_workers=2) as wex:
        items = ["a", "bb", "ccc"]
        assert wex.map_get(len, items) == list(map(len, items))


def test_call_async_and_wait_any():
    with WrenExecutor(num_workers=2) as wex:
        futs = wex.map(lambda x: x + 1, [1, 2, 3, 4])
        done, not_done = wait(futs, ANY_COMPLETED, timeout_s=30)
        assert len(done) >= 1
        assert wex.call_async(lambda x: -x, 5).result(timeout_s=30) == -5


def test_task_exception_surfaces():
    def boom(x):
        raise ValueError(f"bad {x}")

    with WrenExecutor(num_workers=2) as wex:
        [fut] = wex.map(boom, [7])
        # failures are published per-attempt; result() keeps polling the
        # result key until timeout (retries may still be running), so check
        # the error objects instead
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline and not fut.errors():
            time.sleep(0.01)
        errs = fut.errors()
        assert errs and "bad 7" in errs[0].error


def test_worker_death_recovers_via_lease_expiry():
    wex = WrenExecutor(num_workers=0, seed=3)
    try:
        func = FunctionSpec.register(wex.store, lambda x: x * 10, worker="driver")
        tasks = [
            TaskSpec.make("ft", func, stage_input(wex.store, "ft", v), i)
            for i, v in enumerate([1, 2, 3])
        ]
        wex.pool.fault_plan.die_before_publish_tasks.add(tasks[0].task_id)
        wex.scheduler.submit_many(tasks)
        wex.scale_to(3)
        futs = [ResultFuture(wex.store, t) for t in tasks]
        assert get_all(futs, timeout_s=60) == [10, 20, 30]
        # the killed task was attempted at least twice
        assert wex.scheduler.attempts(tasks[0]) >= 2
    finally:
        wex.shutdown()


def test_duplicate_execution_is_idempotent():
    """Speculative duplicates publish to the same key; first writer wins."""
    store = ObjectStore()
    from repro.core.functions import run_task

    func = FunctionSpec.register(store, lambda x: x + 100)
    task = TaskSpec.make("dup", func, stage_input(store, "dup", 1), 0)
    r1 = run_task(store, task, worker="w1")
    r2 = run_task(store, task.retry(), worker="w2")  # duplicate execution
    assert r1.success and r2.success
    fut = ResultFuture(store, task)
    assert fut.result(timeout_s=5) == 101
    # exactly one visible result object
    assert len(store.list(task.result_key)) == 1


def test_straggler_speculation_duplicates_slow_tasks():
    cfg = SchedulerConfig(
        lease_timeout_s=5.0,
        speculation_factor=3.0,
        min_completed_for_speculation=3,
    )
    fp = FaultPlan(slowdown={"w0000": 400.0})  # first worker is a straggler
    wex = WrenExecutor(num_workers=4, scheduler_config=cfg, fault_plan=fp, seed=0)
    try:
        futs = wex.map(lambda x: x, list(range(12)))
        results = get_all(futs, timeout_s=60)
        assert results == list(range(12))
    finally:
        wex.shutdown()


def test_elastic_scale_up_mid_job():
    wex = WrenExecutor(num_workers=1)
    try:
        futs = wex.map(lambda x: x * x, list(range(30)))
        wex.scale_to(6)  # scale up while queue is draining
        assert get_all(futs, timeout_s=60) == [x * x for x in range(30)]
        assert wex.pool.alive_count() >= 1
    finally:
        wex.shutdown()


def test_scale_down_does_not_lose_tasks():
    wex = WrenExecutor(num_workers=6, seed=1)
    try:
        futs = wex.map(lambda x: x + 1, list(range(40)))
        wex.scale_to(2)
        assert get_all(futs, timeout_s=60) == [x + 1 for x in range(40)]
    finally:
        wex.shutdown()


def test_cold_start_accounting():
    with WrenExecutor(num_workers=2) as wex:
        wex.map_get(lambda x: x, list(range(8)))
        stats = wex.pool.stats()
        total_cold = sum(s.cold_starts for s in stats.values())
        total_ok = sum(s.tasks_ok for s in stats.values())
        assert total_ok == 8
        # each container cold-starts exactly once, then stays warm
        assert total_cold <= 2


def test_resource_limit_memory():
    from repro.core import LAMBDA_2017

    with pytest.raises(MemoryError):
        LAMBDA_2017.check_payload(int(3e9), "input")


def test_scheduler_queue_depth_and_pending():
    store = ObjectStore()
    kv = KVStore()
    sched = Scheduler(kv, store)
    func = FunctionSpec.register(store, lambda x: x)
    tasks = [TaskSpec.make("q", func, stage_input(store, "q", i), i) for i in range(5)]
    sched.submit_many(tasks)
    assert sched.queue_depth() == 5
    assert sched.pending() == 5
    t = sched.lease_next("w")
    assert t is not None
    assert sched.queue_depth() == 4
