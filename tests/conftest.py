"""Shared fixtures: opt-in runtime sanitizer.

``REPRO_SANITIZE=1 pytest ...`` runs the whole suite under the runtime
sanitizer (``repro.analysis.sanitizer``): every ``KVStore`` / ``FileKVStore``
/ ``ObjectStore`` / backend constructed during a test is instrumented in
place, shard and scheduler locks are tracked, and a test that triggers any
invariant report (unfenced ``sched/`` write, lock-order inversion, blocking
op under a lock, torn multi-key read) **fails** with the report list —
even if its own assertions passed.  CI runs the multidriver suite this way.
"""

from __future__ import annotations

import os

import pytest

_SANITIZE = os.environ.get("REPRO_SANITIZE") == "1"

if _SANITIZE:
    from repro.analysis import sanitizer

    sanitizer.install()


@pytest.fixture(autouse=True)
def _sanitizer_guard():
    if not _SANITIZE:
        yield
        return
    from repro.analysis import sanitizer

    sanitizer.state.clear()
    yield
    reports = sanitizer.state.snapshot()
    if reports:
        lines = "\n".join(f"  {r}" for r in reports)
        sanitizer.state.clear()
        pytest.fail(
            f"runtime sanitizer: {len(reports)} invariant report(s):\n{lines}",
            pytrace=False,
        )
