"""Cross-backend conformance: one behavioural contract, three substrates.

Every test here runs against the in-memory stores, the file substrate
(``FileKVStore``/``FileBackend``), and the wire tier (``repro-kvd`` +
``NetKVStore``/``NetBackend``) — the point of the matrix is that PR 8's
socket server is *indistinguishable* from the in-process stores at the API
level, so the scheduler/executor stack runs unchanged over any of them:

  * batched verbs (``mget``/``mset``/``eval_many``/``rpush_many``) keep the
    PR-5 charging model: one charged op per shard touched, never one per
    key — and on the wire tier one *frame* per batched verb;
  * a batch bumps each touched shard's sequence exactly ONCE (a widening
    batch cannot multiply watcher wakeups);
  * ``eval`` runs server-side but its captured-state side effects land on
    the caller via the replay contract, and the ``DELETE`` sentinel drops
    the key from any backend;
  * first-writer-wins everywhere it is promised: ``setnx`` on the KV,
    ``if_absent`` puts on the object tier;
  * destructive reads (``lpop_n``/``blpop``) hand each element to exactly
    one consumer, across handles and across the wire;
  * waits are event-driven: a cross-handle publisher wakes a blocked
    ``wait_keys``/``blpop`` with zero fallback poll ticks.
"""

import threading
import time

import numpy as np
import pytest

from repro.storage import (
    DELETE,
    FileBackend,
    FileKVStore,
    KVStore,
    NetBackend,
    NetKVStore,
    ObjectStore,
    kv_pure,
)
from repro.storage.net_server import KVDServer

BACKENDS = ("memory", "file", "net")


class _Fixture:
    """One backend instantiation: a KV handle, an ObjectStore, and
    second-handle factories that model a *different process* sharing the
    substrate (a second client for net, a second root-handle for file)."""

    def __init__(self, kind, tmp_path):
        self.kind = kind
        self._extra = []
        if kind == "memory":
            self.kv = KVStore(num_shards=4)
            self.store = ObjectStore()
            self.server = None
        elif kind == "file":
            self.kv = FileKVStore(str(tmp_path / "kv"), num_shards=4, fsync="never")
            self.store = ObjectStore(
                backend=FileBackend(str(tmp_path / "obj"), fsync="never")
            )
            self.server = None
        else:
            self.server = KVDServer(
                str(tmp_path / "kvd"),
                f"unix:{tmp_path / 'kvd.sock'}",
                num_shards=4,
                fsync="never",
            ).start()
            self.kv = NetKVStore(self.server.address)
            self.store = ObjectStore(backend=NetBackend(self.server.address))

    def seq_probe(self, key):
        """The authoritative wake-token sequence for ``key``'s shard.  For
        the wire tier that lives on the SERVER (clients mirror it only via
        pushes while watching), so probe the server's store directly."""
        if self.kind == "net":
            return self.server.kv.shard_seq(key)
        return self.kv.shard_seq(key)

    def second_kv(self):
        """A handle another process would hold."""
        if self.kind == "memory":
            return self.kv  # in-memory state IS the shared substrate
        if self.kind == "file":
            kv = FileKVStore(self.kv.root, num_shards=4, fsync="never")
        else:
            kv = NetKVStore(self.server.address)
        self._extra.append(kv)
        return kv

    def second_store(self):
        if self.kind == "memory":
            return self.store
        if self.kind == "file":
            st = ObjectStore(backend=FileBackend(self.store.backend.root, fsync="never"))
        else:
            st = ObjectStore(backend=NetBackend(self.server.address))
        self._extra.append(st)
        return st

    def close(self):
        for h in self._extra:
            close = getattr(h, "close", None) or getattr(h.backend, "close", None)
            close()
        for h in (self.kv, self.store.backend, self.server):
            close = getattr(h, "close", None)
            if close:
                close()


@pytest.fixture(params=BACKENDS)
def bk(request, tmp_path):
    fx = _Fixture(request.param, tmp_path)
    yield fx
    fx.close()


# ---------------------------------------------------------------------------
# KV plane: roundtrips, batching, charging
# ---------------------------------------------------------------------------

def test_kv_roundtrip_and_scan(bk):
    kv = bk.kv
    kv.set("a/1", {"x": 1})
    kv.set("a/2", [1, 2, 3])
    kv.set("b/1", "other")
    assert kv.get("a/1") == {"x": 1}
    assert kv.get("missing") is None
    assert kv.get("missing", default="d") == "d"
    assert sorted(kv.scan("a/")) == ["a/1", "a/2"]
    assert kv.exists("a/2") and not kv.exists("a/3")
    kv.delete("a/2")
    assert not kv.exists("a/2")


def test_kv_mget_order_defaults_and_charging(bk):
    kv = bk.kv
    kv.set("a", 1)
    kv.set("b", 2)
    before = kv.total_ops()
    out = kv.mget(["b", "missing", "a"], default="absent")
    assert out == [2, "absent", 1]
    # THE batched-op charging formula, identical across substrates: one
    # charged op per shard touched, never one per key.
    shards = len({kv.shard_of(k) for k in ["b", "missing", "a"]})
    assert kv.total_ops() - before == shards <= 3


def test_kv_mset_batch_charging_and_single_wakeup_per_shard(bk):
    kv = bk.kv
    keys = [f"batch/{i}" for i in range(12)]
    seqs = {k: bk.seq_probe(k) for k in keys}
    before = kv.total_ops()
    kv.mset({k: i for i, k in enumerate(keys)})
    shards = {kv.shard_of(k) for k in keys}
    assert kv.total_ops() - before == len(shards)
    # each touched shard's sequence advanced exactly once for the batch —
    # a widening batch cannot multiply watcher wakeups
    bumps = {}
    for k in keys:
        bumps.setdefault(kv.shard_of(k), set()).add(bk.seq_probe(k) - seqs[k])
    for sidx, deltas in bumps.items():
        assert deltas == {1}, f"shard {sidx} bumped {deltas} times"


def test_kv_setnx_first_writer_wins(bk):
    kv = bk.kv
    assert kv.setnx("claim", "w1") is True
    assert kv.setnx("claim", "w2") is False
    assert kv.get("claim") == "w1"


def test_kv_incr_and_mdel(bk):
    kv = bk.kv
    assert kv.incr("n", 5) == 5
    assert kv.incr("n", -2) == 3
    kv.set("d1", 1)
    kv.set("d2", 2)
    assert kv.mdel(["d1", "d2", "nope"]) >= 0
    assert not kv.exists("d1") and not kv.exists("d2")


def test_large_array_parity_and_charging(bk):
    """PR 9: a ≥ 8 MiB ndarray rides every substrate identically — same
    values back through set/get/mget and object put/get/get_many, and the
    same charging rows (one op per verb per shard touched, the payload's
    nbytes charged in full) whether the bytes moved through process memory,
    the shard log, or wire buffer frames."""
    big = np.arange(1 << 20, dtype=np.float64)  # 8 MiB
    kv = bk.kv
    ops0 = kv.total_ops()
    bin0 = sum(s.bytes_in for s in kv.shard_stats())
    bout0 = sum(s.bytes_out for s in kv.shard_stats())
    kv.set("big/a", big)
    np.testing.assert_array_equal(kv.get("big/a"), big)
    assert kv.total_ops() - ops0 == 2  # one charged op per verb
    assert sum(s.bytes_in for s in kv.shard_stats()) - bin0 == big.nbytes
    assert sum(s.bytes_out for s in kv.shard_stats()) - bout0 == big.nbytes
    kv.set("big/b", big * 2)
    kv.set("small", 7)
    ops1 = kv.total_ops()
    got = kv.mget(["big/a", "small", "big/b"])
    np.testing.assert_array_equal(got[0], big)
    assert got[1] == 7
    np.testing.assert_array_equal(got[2], big * 2)
    # batched charging stays per-shard even when the rows are 8 MiB wide
    shards = len({kv.shard_of(k) for k in ["big/a", "small", "big/b"]})
    assert kv.total_ops() - ops1 == shards
    st = bk.store
    st.put("blob/x", {"w": big})
    np.testing.assert_array_equal(st.get("blob/x")["w"], big)
    np.testing.assert_array_equal(st.get_many(["blob/x"])["blob/x"]["w"], big)


def test_net_large_payload_rides_buffer_frames_not_pickle(bk):
    """The zero-copy acceptance pin (wire tier only): moving an 8 MiB blob
    through the object plane must move ≥ 5× fewer bytes through the pickle
    codec than the payload itself — the raw bytes ride out-of-band buffer
    frames.  A pickled-path control client on the same daemon moves the
    payload through the codec in full."""
    if bk.kind != "net":
        pytest.skip("wire-tier byte accounting only exists on the net backend")
    blob = np.arange(1 << 20, dtype=np.float64).tobytes()  # 8 MiB
    st = bk.store
    client = st.backend._client
    p0, b0 = client.bytes_pickled, client.bytes_buffer
    st.put_bytes("zc/x", blob)
    assert st.get_bytes("zc/x") == blob
    pickled = client.bytes_pickled - p0
    buffered = client.bytes_buffer - b0
    assert buffered >= 2 * len(blob)  # put out + get back, both out-of-band
    assert pickled * 5 < 2 * len(blob)  # ≥5× fewer copied bytes than payload
    # control: a zero_copy=False client pays the codec in full
    from repro.storage import NetBackend

    legacy = ObjectStore(backend=NetBackend(bk.server.address, zero_copy=False))
    try:
        lc = legacy.backend._client
        lp0 = lc.bytes_pickled
        legacy.put_bytes("zc/legacy", blob)
        assert legacy.get_bytes("zc/legacy") == blob
        assert lc.bytes_pickled - lp0 >= 2 * len(blob)
        assert lc.bytes_buffer == 0
    finally:
        legacy.backend.close()


# ---------------------------------------------------------------------------
# eval: server-side scripting, replay side effects, DELETE sentinel
# ---------------------------------------------------------------------------

@kv_pure
def _bump(cur):
    return int(cur or 0) + 10


@kv_pure
def _capture_then_delete(out, cur):
    out["seen"] = cur
    return DELETE


def test_eval_applies_and_returns_new_value(bk):
    assert bk.kv.eval("counter", _bump) == 10
    assert bk.kv.eval("counter", _bump) == 20
    assert bk.kv.get("counter") == 20


def test_eval_delete_sentinel_drops_key_and_side_effects_replay(bk):
    """The eval replay contract: the function runs inside the store's shard
    transaction, but mutations to captured state (the ``out`` dict riding a
    partial) land on the CALLER — identically in-process and over the
    wire."""
    from functools import partial

    kv = bk.kv
    kv.set("rec", {"epoch": 3})
    out = {}
    kv.eval("rec", partial(_capture_then_delete, out))
    assert out["seen"] == {"epoch": 3}
    assert not kv.exists("rec")


def test_eval_many_per_shard_charging_and_delete(bk):
    from functools import partial

    kv = bk.kv
    keys = [f"em/{i}" for i in range(8)]
    for k in keys:
        kv.set(k, 1)
    before = kv.total_ops()
    res = kv.eval_many({k: _bump for k in keys})
    assert kv.total_ops() - before == len({kv.shard_of(k) for k in keys})
    assert all(res[k] == 11 for k in keys)
    outs = {k: {} for k in keys}
    kv.eval_many({k: partial(_capture_then_delete, outs[k]) for k in keys})
    assert all(outs[k]["seen"] == 11 for k in keys)
    assert not any(kv.exists(k) for k in keys)


# ---------------------------------------------------------------------------
# lists: exactly-once destructive reads, cross-handle wakes
# ---------------------------------------------------------------------------

def test_lpop_n_hands_out_each_element_once(bk):
    kv = bk.kv
    kv.rpush("q", *range(10))
    a = kv.lpop_n("q", 4)
    b = kv.lpop_n("q", 100)
    assert a == [0, 1, 2, 3]
    assert b == [4, 5, 6, 7, 8, 9]
    assert kv.lpop_n("q", 1) == []
    assert kv.llen("q") == 0


def test_rpush_lrange_llen(bk):
    kv = bk.kv
    kv.rpush("lst", "a")
    kv.rpush("lst", "b", "c")
    assert kv.llen("lst") == 3
    assert kv.lrange("lst") == ["a", "b", "c"]


def test_rpush_nowait_lands(bk):
    kv = bk.kv
    kv.rpush_nowait("durs", 0.5)
    kv.rpush_nowait("durs", 0.7)
    # advisory, but ordered behind this handle's own next call
    deadline = time.monotonic() + 5.0
    while kv.llen("durs") < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert kv.lrange("durs") == [0.5, 0.7]


def test_blpop_cross_handle_wake_is_event_driven(bk):
    """A consumer blocked in one handle is woken by a producer in ANOTHER
    handle (another process for file, another socket for net) — promptly,
    with no fallback polling."""
    consumer_kv = bk.kv
    producer_kv = bk.second_kv()
    got = []

    def consume():
        got.append(consumer_kv.blpop("jobs", timeout_s=10.0))

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.25)  # let the consumer register its watch and block
    t0 = time.monotonic()
    producer_kv.rpush("jobs", "work")
    t.join(timeout=10.0)
    assert got == ["work"]
    assert time.monotonic() - t0 < 2.0


# ---------------------------------------------------------------------------
# object plane
# ---------------------------------------------------------------------------

def test_object_roundtrip_list_and_missing(bk):
    st = bk.store
    st.put("res/a", {"v": 1})
    st.put("res/b", [1, 2])
    assert st.get("res/a") == {"v": 1}
    got = st.get_many(["res/a", "res/b", "res/nope"])
    assert got == {"res/a": {"v": 1}, "res/b": [1, 2]}
    with pytest.raises(KeyError):
        st.get_many(["res/nope"], missing="error")
    assert st.exists("res/a") and not st.exists("res/zzz")
    assert st.exists_many(["res/a", "res/zzz"]) == {"res/a"}


def test_object_if_absent_first_writer_wins(bk):
    st = bk.store
    assert st.put("winner", "first", if_absent=True) is True
    assert st.put("winner", "second", if_absent=True) is False
    assert st.get("winner") == "first"
    n = st.put_many({"winner": "third", "fresh": 1}, if_absent=True)
    assert n == 1
    assert st.get("winner") == "first"
    assert st.get("fresh") == 1


def test_object_wait_keys_cross_handle_zero_fallback_ticks(bk):
    """``wait_keys`` blocked in one handle returns when ANOTHER handle
    publishes — via the backend's own watch/push plane, with zero fallback
    poll ticks (the PR-4/PR-8 no-polling contract)."""
    waiter = bk.store
    publisher = bk.second_store()
    done = []

    def wait():
        waiter.wait_keys(["out/x", "out/y"], timeout_s=10.0)
        done.append(True)

    t = threading.Thread(target=wait)
    t.start()
    time.sleep(0.25)
    publisher.put("out/x", 1)
    publisher.put("out/y", 2)
    t.join(timeout=10.0)
    assert done == [True]
    assert waiter.fallback_tick_waits == 0
