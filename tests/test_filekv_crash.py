"""Crash safety of the log-structured ``FileKVStore`` (PR 5).

Pins the contract the substrate's recovery story rests on:

  * **committed prefix** — killing a writer process at an arbitrary point
    (including mid-append and mid-compaction) loses at most the one
    uncommitted transaction: a reopened store replays exactly the committed
    prefix, with zero lost and zero duplicated records;
  * **torn tails** — garbage or a half-written frame at the end of a log is
    detected by the length/CRC framing, dropped on replay, and truncated by
    the next writer;
  * **compaction atomicity** — the generation header fences a snapshot
    against the log it superseded, so the crash window between the two
    renames (snapshot landed, log not yet swapped) reads back exactly the
    same state and never double-applies non-idempotent records (rpush);
  * **no half-compacted reads** — a concurrent reader in another handle
    never observes a shard mid-compaction (multi-key transactions are
    all-or-nothing across handles);
  * **inotify watcher** — where inotify is available, a cross-handle wake
    is delivered with ZERO timed poll wakeups (the poll backoff is only a
    fallback).
"""

import glob
import os
import signal
import struct
import subprocess
import sys
import time

import pytest

from repro.storage import FileKVStore
from repro.storage.kv_store import encode_frame

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# subprocess writer harness
# ---------------------------------------------------------------------------

def _spawn_writer(root: str, compact_min_bytes: int) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [
            sys.executable,
            os.path.abspath(__file__),
            "writer",
            root,
            str(compact_min_bytes),
        ],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
    )


def _writer_main(root: str, compact_min_bytes: int) -> None:
    """Write transactions as fast as possible until killed.  Each iteration
    appends ``i`` to the ``log`` list and mirrors it into two keys that a
    validator requires to be equal — so any replay divergence, lost commit,
    or double-applied record is visible in the final state."""
    kv = FileKVStore(
        root, num_shards=1, fsync="never", compact_min_bytes=compact_min_bytes
    )
    i = kv.llen("log", worker="w")  # resume the sequence across restarts
    while True:
        kv.rpush("log", i, worker="w")
        kv.mset({"a": i, "b": i}, worker="w")
        i += 1


def _run_kill_cycle(root: str, compact_min_bytes: int, min_entries: int) -> list:
    """Spawn the writer, wait for progress, SIGKILL it, reopen, and return
    the recovered ``log`` list."""
    proc = _spawn_writer(root, compact_min_bytes)
    watcher = FileKVStore(root, num_shards=1)
    try:
        deadline = time.monotonic() + 60
        baseline = watcher.llen("log")
        while watcher.llen("log") < baseline + min_entries:
            assert proc.poll() is None, proc.stdout.read().decode()
            assert time.monotonic() < deadline, "writer made no progress"
            time.sleep(0.01)
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=15)
        watcher.close()
    fresh = FileKVStore(root, num_shards=1)
    try:
        entries = fresh.lrange("log")
        a, b = fresh.mget(["a", "b"])
        # the mirror keys land in one frame: both or neither
        assert a == b, f"half-applied transaction after kill: a={a} b={b}"
        # iteration i runs rpush(i) then mset(a=i): a kill between the two
        # leaves the mirror exactly one behind the log head, never more
        if entries:
            assert a in (None, entries[-1], entries[-1] - 1), (
                f"mirror diverged from log: a={a}, head={entries[-1]}"
            )
        return entries
    finally:
        fresh.close()


def _assert_exact_prefix(entries: list) -> None:
    """The recovered log must be 0..n-1 with no holes and no duplicates."""
    assert entries == list(range(len(entries))), (
        f"lost or duplicated records: len={len(entries)}, "
        f"head={entries[:5]}, tail={entries[-5:]}"
    )


def test_kill_writer_midstream_recovers_committed_prefix(tmp_path):
    """SIGKILL during steady appends: the committed prefix survives
    exactly (compaction effectively disabled by a huge threshold)."""
    entries = _run_kill_cycle(str(tmp_path / "kv"), 1 << 30, min_entries=40)
    assert len(entries) >= 40
    _assert_exact_prefix(entries)


def test_kill_writer_mid_compaction_storm(tmp_path):
    """SIGKILL under constant compaction churn (tiny threshold: the writer
    compacts every few commits), repeated: recovery is still exact."""
    root = str(tmp_path / "kv")
    for _cycle in range(3):
        entries = _run_kill_cycle(root, 2048, min_entries=30)
        _assert_exact_prefix(entries)
    # compaction actually ran: a generation snapshot exists
    assert glob.glob(os.path.join(root, "shard-0.snap.*"))


# ---------------------------------------------------------------------------
# torn tails (crafted, deterministic)
# ---------------------------------------------------------------------------

def _shard_log(root: str) -> str:
    (path,) = glob.glob(os.path.join(root, "shard-0.log"))
    return path


def test_torn_garbage_tail_dropped_and_truncated(tmp_path):
    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1)
    kv.set("k", "keep", worker="t")
    kv.rpush("q", 1, 2, worker="t")
    kv.close()
    with open(_shard_log(root), "ab") as f:
        f.write(b"\xde\xad\xbe\xef torn garbage")
    size_torn = os.path.getsize(_shard_log(root))
    kv2 = FileKVStore(root, num_shards=1)
    try:
        assert kv2.get("k") == "keep"  # committed prefix intact
        assert kv2.lrange("q") == [1, 2]
        kv2.set("after", 1, worker="t")  # next commit truncates the garbage
        assert os.path.getsize(_shard_log(root)) < size_torn + 64
    finally:
        kv2.close()
    kv3 = FileKVStore(root, num_shards=1)
    try:
        assert kv3.get("after") == 1
        assert kv3.get("k") == "keep"
    finally:
        kv3.close()


def test_torn_half_frame_dropped(tmp_path):
    """A frame with a valid header but truncated payload (writer died mid
    ``pwrite``) is dropped; so is one with a corrupted payload (bad CRC)."""
    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1)
    kv.set("k", 42, worker="t")
    kv.close()
    frame = encode_frame([("s", "lost", "value-that-never-committed")])
    with open(_shard_log(root), "ab") as f:
        f.write(frame[: len(frame) - 3])  # truncated payload
    kv2 = FileKVStore(root, num_shards=1)
    try:
        assert kv2.get("k") == 42
        assert kv2.get("lost") is None
    finally:
        kv2.close()
    # corrupt CRC: flip a payload byte of a whole appended frame
    bad = bytearray(frame)
    bad[-1] ^= 0xFF
    with open(_shard_log(root), "ab") as f:
        f.write(bytes(bad))
    kv3 = FileKVStore(root, num_shards=1)
    try:
        assert kv3.get("k") == 42
        assert kv3.get("lost") is None
    finally:
        kv3.close()


def test_truncated_log_header_recovers_from_snapshot(tmp_path):
    """A log whose header itself is torn (crash during initial creation
    models) falls back to the snapshot generation cleanly."""
    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1, compact_min_bytes=64)
    for i in range(20):
        kv.set(f"k{i}", i, worker="t")  # forces at least one compaction
    kv.close()
    assert glob.glob(os.path.join(root, "shard-0.snap.*"))
    with open(_shard_log(root), "wb") as f:
        f.write(b"\x00\x01")  # 2-byte husk: not even a whole header
    kv2 = FileKVStore(root, num_shards=1)
    try:
        # everything up to the last compaction is in the snapshot; the
        # husk is discarded, not misread
        assert kv2.get("k0") == 0
        kv2.set("post", 1, worker="t")
        assert kv2.get("post") == 1
    finally:
        kv2.close()


# ---------------------------------------------------------------------------
# mid-compaction crash window (deterministic, via the engine seam)
# ---------------------------------------------------------------------------

def test_snapshot_published_but_log_not_swapped_reads_back_identically(tmp_path):
    """The compaction crash window: the gen+1 snapshot renamed but the log
    still at gen with ALL its records — including non-idempotent list
    appends.  Generation-suffixed snapshots make the new snapshot inert
    until the log swap, so the state must read back identically (never
    doubled), and — the subtle half — a live WARM peer that keeps
    committing to the old-generation log after the compactor died must not
    have those commits discarded by a later recovery."""
    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1)
    peer = FileKVStore(root, num_shards=1)
    for i in range(10):
        kv.rpush("q", i, worker="t")  # replaying these twice would duplicate
    kv.incr("ctr", 5, worker="t")
    assert peer.llen("q") == 10  # peer is warm on the current log
    engine = kv._engines[0]
    state_before = dict(engine.load())
    # simulate the crash: step 1 of compaction only, then "die"
    engine._publish_snapshot(state_before)
    kv.close()
    # the warm peer keeps working against the old-generation log: its
    # acknowledged commit must survive any subsequent recovery
    peer.rpush("q", 10, worker="peer")
    peer.close()
    fresh = FileKVStore(root, num_shards=1)
    try:
        assert fresh.lrange("q") == list(range(11))  # not 0..9,0..9; incl. 10
        assert fresh.get("ctr") == 5
        fresh.rpush("q", 11, worker="t")
        assert fresh.lrange("q") == list(range(12))
    finally:
        fresh.close()
    again = FileKVStore(root, num_shards=1)
    try:
        assert again.lrange("q") == list(range(12))
    finally:
        again.close()


def test_stored_none_is_a_real_queue_element(tmp_path):
    """Redis LPOP nil-vs-stored distinction: a queued None round-trips
    instead of being silently dropped."""
    kv = FileKVStore(str(tmp_path / "kv"), num_shards=1)
    try:
        kv.rpush("q", None, 7, worker="t")
        assert kv.blpop("q", timeout_s=5.0) is None  # the stored None
        assert kv.lpop("q") == 7  # ...was actually consumed, not dropped
        assert kv.llen("q") == 0
    finally:
        kv.close()


def test_input_prefetch_does_not_share_mutable_objects():
    """Two tasks whose equal inputs dedupe to one content-addressed key
    must each get a private deserialized copy — a mutating task function
    cannot corrupt its sibling's argument."""
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=1) as wex:

        def pop_last(lst):
            return lst.pop()

        futs = wex.map(pop_last, [[1, 2], [1, 2], [1, 2], [1, 2]])
        assert get_all(futs, timeout_s=60) == [2, 2, 2, 2]


def test_concurrent_reader_never_observes_half_compacted_shard(tmp_path):
    """A reader handle polls ``a``/``b`` (always written in one frame)
    while a writer subprocess churns commits and compactions: every read
    must be internally consistent."""
    root = str(tmp_path / "kv")
    proc = _spawn_writer(root, 2048)
    reader = FileKVStore(root, num_shards=1)
    try:
        deadline = time.monotonic() + 60
        while reader.llen("log") < 5:
            assert proc.poll() is None, proc.stdout.read().decode()
            assert time.monotonic() < deadline
            time.sleep(0.01)
        for _ in range(300):
            a, b = reader.mget(["a", "b"])
            assert a == b, f"reader saw a half-applied state: a={a} b={b}"
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=15)
        reader.close()


# ---------------------------------------------------------------------------
# log-structure mechanics worth pinning directly
# ---------------------------------------------------------------------------

def test_compaction_bounds_log_and_preserves_state(tmp_path):
    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1, compact_min_bytes=4096)
    for i in range(500):
        kv.set(f"k{i % 7}", "v" * 100, worker="t")
    kv.close()
    # the log was repeatedly truncated: far smaller than 500 × frame size
    assert os.path.getsize(_shard_log(root)) < 20_000
    fresh = FileKVStore(root, num_shards=1)
    try:
        for i in range(7):
            assert fresh.get(f"k{i}") == "v" * 100
    finally:
        fresh.close()


def test_commit_path_never_rewrites_snapshot_inline(tmp_path):
    """PR 9: with the default ``compaction="thread"``, a commit that crosses
    the compaction threshold returns after one O(record) append — the
    O(shard) snapshot rewrite runs on the compactor thread.  Pinned
    structurally: every snapshot publication is recorded with its thread,
    and the committing thread never appears; the PR-5 inline path is
    booby-trapped outright."""
    import threading

    root = str(tmp_path / "kv")
    kv = FileKVStore(root, num_shards=1, compact_min_bytes=2048)
    eng = kv._engines[0]
    snap_threads = []
    orig_finish = eng.finish_compaction

    def spy_finish(plan):
        snap_threads.append(threading.current_thread().name)
        return orig_finish(plan)

    eng.finish_compaction = spy_finish

    def boom(_state):
        raise AssertionError("inline snapshot rewrite in the commit path")

    eng._compact = boom
    try:
        for i in range(300):
            kv.set(f"k{i % 5}", "v" * 200, worker="t")
        kv.compact_now()
        assert snap_threads and set(snap_threads) == {"filekv-compactor"}
        assert glob.glob(os.path.join(root, "shard-0.snap.*"))
        assert os.path.getsize(_shard_log(root)) < 10_000  # storm stayed bounded
        for i in range(5):
            assert kv.get(f"k{i}") == "v" * 200
    finally:
        kv.close()


def test_compaction_storm_p99_commit_cost_bounded(tmp_path):
    """The compaction-storm regression pin, deterministic: with the
    threshold crossed on effectively every commit, the commit path's own
    disk writes stay O(record) — worst-case (p100, hence p99) commit cost
    is one small frame.  Inline mode on the same storm pays the O(shard)
    snapshot rewrite inside the commit, which is exactly the stall the
    compactor thread removes."""
    kv = FileKVStore(str(tmp_path / "t"), num_shards=1, compact_min_bytes=256)
    requests = []
    kv._request_compact = requests.append  # isolate commit-path bytes
    per_commit = []
    try:
        kv.set("base", "v" * 400, worker="t")  # past the threshold for good
        for i in range(50):
            before = kv.disk_bytes_written()
            kv.set(f"k{i}", "v" * 50, worker="t")
            per_commit.append(kv.disk_bytes_written() - before)
        assert max(per_commit) < 500  # every commit: one frame, no rewrite
        assert requests  # ...even while compaction was being requested
    finally:
        kv.close()
    inline = FileKVStore(
        str(tmp_path / "i"), num_shards=1, compact_min_bytes=256,
        compaction="inline",
    )
    worst = 0
    try:
        inline.set("base", "v" * 400, worker="t")
        for i in range(50):
            before = inline.disk_bytes_written()
            inline.set(f"k{i}", "v" * 50, worker="t")
            worst = max(worst, inline.disk_bytes_written() - before)
        assert worst > 500  # snapshot blob charged to the committing op
    finally:
        inline.close()


def test_log_and_snapshot_engines_agree(tmp_path):
    """Differential check: the same op sequence through both engines ends
    in the same visible state."""
    stores = {
        "log": FileKVStore(str(tmp_path / "log"), num_shards=2, engine="log",
                           compact_min_bytes=512),
        "snapshot": FileKVStore(str(tmp_path / "snap"), num_shards=2,
                                engine="snapshot"),
    }
    from repro.storage import DELETE

    for kv in stores.values():
        kv.mset({"a": 1, "b": [1, 2], "c": "x"}, worker="t")
        kv.rpush("q", 1, 2, 3, worker="t")
        assert kv.lpop("q") == 1
        kv.incr("ctr", 2.5, worker="t")
        kv.eval("b", lambda v: v + [3], worker="t")
        kv.eval("c", lambda v: DELETE, worker="t")
        kv.delete("a", worker="t")
        kv.setnx("nx", 9, worker="t")
        assert kv.lpop_n("q", 5) == [2, 3]
    views = {}
    for name, kv in stores.items():
        reopened = FileKVStore(kv.root, num_shards=2, engine=kv.engine)
        views[name] = {
            k: reopened.get(k) for k in ["a", "b", "c", "ctr", "nx", "q"]
        }
        reopened.close()
        kv.close()
    assert views["log"] == views["snapshot"]
    assert views["log"]["b"] == [1, 2, 3] and views["log"]["a"] is None


def test_disk_bytes_written_is_o_record_not_o_shard(tmp_path):
    """The structural claim behind the perf win, pinned deterministically:
    with a large resident state, the log engine's bytes-per-op stay flat
    while the snapshot engine rewrites the whole shard every commit."""
    # distinct values per key (pickle memoizes repeated identical objects,
    # which would shrink the snapshot engine's rewrite artificially)
    resident = {f"key{i}": f"v{i:04d}" * 20 for i in range(300)}
    log_kv = FileKVStore(str(tmp_path / "log"), num_shards=1, engine="log")
    snap_kv = FileKVStore(str(tmp_path / "snap"), num_shards=1, engine="snapshot")
    for kv in (log_kv, snap_kv):
        kv.mset(resident, worker="t")
        mark = kv.disk_bytes_written()
        for i in range(50):
            kv.set("hot", i, worker="t")
        kv.per_op = (kv.disk_bytes_written() - mark) / 50
        kv.close()
    assert log_kv.per_op < 100  # one small frame per op
    assert snap_kv.per_op > 10_000  # whole-shard pickle per op
    assert snap_kv.per_op / log_kv.per_op > 100


def test_frame_header_is_length_crc(tmp_path):
    """The framing layout is a cross-process contract (another process may
    be a different build): pin it."""
    frame = encode_frame([("s", "k", 1)])
    length, crc = struct.unpack_from("<II", frame)
    assert length == len(frame) - 8
    import zlib

    assert crc == zlib.crc32(frame[8:])


# ---------------------------------------------------------------------------
# inotify watcher: event-driven, zero poll wakeups
# ---------------------------------------------------------------------------

def test_inotify_wake_has_zero_poll_wakeups(tmp_path):
    """Where inotify is available, a cross-handle blpop wake rides kernel
    events: the watcher runs in inotify mode and its timed-poll counter
    stays exactly 0 (the exponential backoff is only the fallback)."""
    from repro.storage.inotify import Inotify

    if not Inotify.available():
        pytest.skip("inotify not available on this platform")
    root = str(tmp_path / "kv")
    consumer = FileKVStore(root, num_shards=1)
    producer = FileKVStore(root, num_shards=1)
    try:
        import threading

        got = []
        th = threading.Thread(
            target=lambda: got.append(consumer.blpop("q", timeout_s=20.0))
        )
        th.start()
        time.sleep(0.3)  # let the consumer park on the shard condition
        producer.rpush("q", "wake", worker="t")
        th.join(timeout=20)
        assert got == ["wake"]
        watcher = consumer._watcher
        assert watcher is not None
        assert watcher.mode == "inotify"
        assert watcher.poll_wakeups == 0
    finally:
        consumer.close()
        producer.close()


def test_poll_fallback_still_works_when_inotify_disabled(tmp_path):
    """Forcing the fallback (use_inotify=False) must still deliver the
    cross-handle wake — via timed backoff polls this time."""
    from repro.storage.object_store import _PollWatcher

    root = str(tmp_path / "kv")
    consumer = FileKVStore(root, num_shards=1)
    producer = FileKVStore(root, num_shards=1)
    # pre-build the watcher with inotify forced off
    paths = [eng.watch_path for eng in consumer._engines]

    def _on_change(changed):
        for sidx in changed:
            sh = consumer._shards[sidx]
            with sh.lock:
                sh.touch()

    consumer._watcher = _PollWatcher(paths, _on_change, use_inotify=False)
    try:
        import threading

        got = []
        th = threading.Thread(
            target=lambda: got.append(consumer.blpop("q", timeout_s=20.0))
        )
        th.start()
        time.sleep(0.2)
        producer.rpush("q", "wake", worker="t")
        th.join(timeout=20)
        assert got == ["wake"]
        assert consumer._watcher.mode == "poll"
        assert consumer._watcher.poll_wakeups > 0
    finally:
        consumer.close()
        producer.close()


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "writer":
        _writer_main(sys.argv[2], int(sys.argv[3]))
    else:
        raise SystemExit(f"usage: {sys.argv[0]} writer <root> <compact_min_bytes>")
