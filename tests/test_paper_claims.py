"""Paper-claim reproduction tests (EXPERIMENTS.md §Paper-faithful).

Each test checks one quantitative claim from 'Occupy the Cloud' against the
runtime + calibrated storage model.  Wall-clock-free: virtual-time ledgers.
"""

import numpy as np
import pytest

from repro.core import (
    WrenExecutor,
    io_compute_balance,
    terasort,
    verify_sorted,
    word_count,
)
from repro.storage import (
    KVStore,
    LOCAL_SSD_C3,
    ObjectStore,
    REDIS_2017,
    S3_2017,
)
from repro.storage import shuffle as shf


# ---------------------------------------------------------------------------
# Table 1: remote storage faster than single local SSD
# ---------------------------------------------------------------------------

def test_table1_remote_vs_local_ssd():
    from repro.storage.perf_model import MB, S3_SINGLE_MACHINE_WRITE_BW

    assert S3_SINGLE_MACHINE_WRITE_BW > LOCAL_SSD_C3.write_bw_per_conn
    assert S3_SINGLE_MACHINE_WRITE_BW == pytest.approx(501.13 * MB)
    assert LOCAL_SSD_C3.write_bw_per_conn == pytest.approx(208.73 * MB)


# ---------------------------------------------------------------------------
# Fig 3: per-worker 30-40 MB/s; aggregate scales to >60/80 GB/s @ 2800
# ---------------------------------------------------------------------------

def test_fig3_per_worker_bandwidth_constants():
    assert 28e6 <= S3_2017.write_bw_per_conn <= 32e6
    assert 38e6 <= S3_2017.read_bw_per_conn <= 42e6


def test_fig3_aggregate_scaling():
    # linear region then cap, as in the figure
    w2800_write = 2800 * S3_2017.effective_write_bw(2800)
    w2800_read = 2800 * S3_2017.effective_read_bw(2800)
    assert w2800_write > 60e9
    assert w2800_read > 80e9
    # near-linear at low worker counts
    assert S3_2017.effective_write_bw(10) == S3_2017.write_bw_per_conn


def test_fig3_measured_through_runtime():
    """Run actual workers writing through the store; ledger bandwidth per
    worker must match the calibrated 30 MB/s within 20%."""
    store = ObjectStore(profile=S3_2017)
    with WrenExecutor(store=store, num_workers=4) as wex:
        payload = np.zeros(20_000_000, np.uint8)  # large object: streaming regime

        def put_chunk(i):
            store.put(f"bw/{i}", payload, worker=f"bench{i}")
            return i

        wex.map_get(put_chunk, list(range(8)))
    per = store.ledger.per_worker()
    rates = []
    for w, ops in per.items():
        if w.startswith("bench") and "put" in ops:
            nbytes, vt = ops["put"]
            rates.append(nbytes / vt)
    assert rates and all(24e6 < r <= 31e6 for r in rates)


# ---------------------------------------------------------------------------
# Fig 4: KV ops <1 ms latency, ~700 txn/s/worker, shard saturation
# ---------------------------------------------------------------------------

def test_fig4_kv_latency_sub_ms():
    assert REDIS_2017.read_latency_s < 1e-3
    kv = KVStore(num_shards=2, profile=REDIS_2017)
    kv.set("x", b"0" * 128, worker="w")
    kv.get("x", worker="w")
    recs = kv.ledger.records()
    assert all(r.vtime_s < 1.2e-3 for r in recs)


def test_fig4_scaling_saturates_at_shard_throughput():
    # up to ~1000 workers the two-shard deployment sustains ~700 txn/s each
    r1000 = REDIS_2017.effective_ops_per_s(1000, shards=2)
    assert r1000 >= 690
    # beyond saturation per-worker rate decays
    r4000 = REDIS_2017.effective_ops_per_s(4000, shards=2)
    assert r4000 < r1000 / 2


# ---------------------------------------------------------------------------
# §3.3 word count: storage-BSP within ~17% of in-process baseline (virtual)
# ---------------------------------------------------------------------------

def test_wordcount_correctness_vs_inprocess():
    docs = [[f"w{i % 7} w{(i * 3) % 5} common" for i in range(20)] for _ in range(6)]
    with WrenExecutor(num_workers=4) as wex:
        wc = word_count(wex, docs, num_reducers=3)
    # in-process ground truth
    from collections import Counter

    truth = Counter()
    for doc in docs:
        for line in doc:
            truth.update(line.split())
    assert wc == dict(truth)


# ---------------------------------------------------------------------------
# §3.3 sort: correctness + the Redis-shard bottleneck
# ---------------------------------------------------------------------------

def _run_sort(n_shards, n_parts=6, n_files=6, recs_per_file=120):
    wex = WrenExecutor(num_workers=4)
    try:
        store = wex.store
        keys = []
        for i in range(n_files):
            k = f"sin/{i}"
            store.put(k, shf.make_sort_records(recs_per_file, seed=i))
            keys.append(k)
        kv = KVStore(num_shards=n_shards, profile=REDIS_2017)
        rep = terasort(wex, keys, f"sout{n_shards}", n_parts, intermediate=kv)
        ok = verify_sorted(store, f"sout{n_shards}")
        return rep, ok, kv
    finally:
        wex.shutdown()


def test_terasort_correct_and_quadratic_intermediates():
    rep, ok, _ = _run_sort(n_shards=4)
    assert ok
    assert rep.n_records == 6 * 120
    assert rep.n_intermediate_objects == 6 * 6  # n_tasks x n_partitions


def test_fig6_more_shards_reduce_hotspot():
    rep2, ok2, kv2 = _run_sort(n_shards=1)
    rep8, ok8, kv8 = _run_sort(n_shards=8)
    assert ok2 and ok8
    # hottest-shard virtual busy time drops with more shards (Fig 5/6)
    assert rep8.hottest_shard_vtime < rep2.hottest_shard_vtime


# ---------------------------------------------------------------------------
# §4 resource balance heuristic
# ---------------------------------------------------------------------------

def test_resource_balance_matches_paper_numbers():
    out = io_compute_balance(1.5e9, 35e6, 300.0)
    # 'fill up its memory of 1.5GB in around 40s'
    assert out["fill_seconds"] == pytest.approx(42.9, rel=0.05)
    # 'around 80s of I/O and 220s of compute'
    assert out["io_seconds"] == pytest.approx(85.7, rel=0.05)
    assert out["compute_seconds"] == pytest.approx(214.3, rel=0.05)


# ---------------------------------------------------------------------------
# §3.1 fault tolerance contract
# ---------------------------------------------------------------------------

def test_atomic_result_contract():
    store = ObjectStore()
    assert store.publish_result("r/1", {"v": 1})
    assert not store.publish_result("r/1", {"v": 2})
    assert store.get("r/1")["v"] == 1
