"""Serving engine + storage-mediated request plane."""

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import Engine, ServeConfig, serve_pending, submit_request
from repro.storage import ObjectStore


def _engine(arch="qwen3-32b", **kw):
    cfg = CONFIGS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_len=64, max_new_tokens=6, **kw)), cfg


def test_generate_shapes_and_determinism():
    eng, cfg = _engine()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic


def test_generate_ssm_arch():
    eng, cfg = _engine("xlstm-1.3b")
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0, cfg.vocab_size)
    out = eng.generate(prompts)
    assert out.shape == (2, 6)


def test_request_plane_idempotent_publish():
    eng, cfg = _engine()
    store = ObjectStore()
    for i in range(5):
        submit_request(store, f"r{i}", [1, 2, 3, i + 1])
    n1 = serve_pending(store, eng, batch_size=3)
    n2 = serve_pending(store, eng, batch_size=8)
    n3 = serve_pending(store, eng, batch_size=8)  # nothing pending
    assert n1 == 3 and n2 == 2 and n3 == 0
    done = store.list("serve/done/")
    assert len(done) == 5
    # replaying a batch does not overwrite published results
    before = store.get(done[0])
    serve_pending(store, eng, batch_size=8)
    np.testing.assert_array_equal(store.get(done[0])["tokens"], before["tokens"])
