"""Storage layer: S3/Redis semantics, atomicity, serialization properties."""

import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.storage import (
    FileBackend,
    KVStore,
    ObjectStore,
    dumps,
    loads,
)
from repro.storage import shuffle as shf


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

@given(
    st.recursive(
        st.one_of(
            st.integers(min_value=-(2**40), max_value=2**40),
            st.floats(allow_nan=False, allow_infinity=False),
            st.text(max_size=40),
            st.binary(max_size=64),
            st.booleans(),
            st.none(),
        ),
        lambda children: st.one_of(
            st.lists(children, max_size=4),
            st.dictionaries(st.text(max_size=8), children, max_size=4),
        ),
        max_leaves=12,
    )
)
@settings(max_examples=60, deadline=None)
def test_serialization_roundtrip(value):
    assert loads(dumps(value)) == value


@given(st.integers(0, 3), st.integers(1, 5), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_serialization_array_pytree(seed, a, b):
    rng = np.random.default_rng(seed)
    tree = {"x": rng.normal(size=(a, b)), "y": [rng.integers(0, 9, size=(b,))]}
    out = loads(dumps(tree))
    np.testing.assert_array_equal(out["x"], tree["x"])
    np.testing.assert_array_equal(out["y"][0], tree["y"][0])


# Keys crafted to contain the legacy codec's sentinel separator
# (b"\x00TREE\x00"): the old sentinel-scan split corrupted any pytree whose
# pickled treedef embedded those bytes.  The length-prefixed header must
# round-trip them — and arbitrary binary-ish keys — exactly.
_ADVERSARIAL_KEYS = st.one_of(
    st.just("\x00TREE\x00"),
    st.just("pre\x00TREE\x00post"),
    st.text(alphabet="\x00TRE abc", min_size=1, max_size=12),
    st.text(max_size=12),
)


@given(
    st.dictionaries(
        _ADVERSARIAL_KEYS,
        st.integers(0, 4).map(lambda n: np.arange(n, dtype=np.float32)),
        min_size=1,
        max_size=4,
    )
)
@settings(max_examples=60, deadline=None)
def test_serialization_sentinel_adversarial_treedef(tree):
    """Property pin for the PR-9 sentinel fix: pytrees whose treedef pickle
    contains the old b"\\x00TREE\\x00" separator round-trip exactly through
    both the raw codec and the legacy NPZ codec."""
    from repro.storage.serialization import _dumps_npz

    out = loads(dumps(tree))
    assert sorted(out) == sorted(tree)
    for k in tree:
        np.testing.assert_array_equal(out[k], tree[k])
    legacy = loads(_dumps_npz(tree))
    for k in tree:
        np.testing.assert_array_equal(legacy[k], tree[k])


def test_dumps_parts_concatenation_is_dumps():
    """The scatter-gather contract the wire tier rides on: joining the
    segments of ``dumps_parts`` is byte-identical to ``dumps``, and array
    leaves are zero-copy memoryviews over the array memory."""
    from repro.storage.serialization import dumps_parts

    tree = {"w": np.arange(1024, dtype=np.float64), "b": np.ones(3, np.float32)}
    parts = dumps_parts(tree)
    assert b"".join(parts) == dumps(tree)
    views = [p for p in parts if isinstance(p, memoryview)]
    assert len(views) == 2  # one per leaf, no pickling of the payload
    total = sum(v.nbytes for v in views)
    assert total == 1024 * 8 + 3 * 4
    # non-array values collapse to a single pickled segment
    (single,) = dumps_parts({"s": "just pickles"})
    assert loads(single) == {"s": "just pickles"}


def test_content_addressing_dedupes():
    store = ObjectStore()
    k1 = store.put_content_addressed("in", {"a": 1})
    k2 = store.put_content_addressed("in", {"a": 1})
    k3 = store.put_content_addressed("in", {"a": 2})
    assert k1 == k2 and k1 != k3


# ---------------------------------------------------------------------------
# object store
# ---------------------------------------------------------------------------

def test_put_if_absent_first_writer_wins():
    store = ObjectStore()
    assert store.put("k", "first", if_absent=True)
    assert not store.put("k", "second", if_absent=True)
    assert store.get("k") == "first"


def test_put_if_absent_race_single_winner():
    store = ObjectStore()
    wins = []

    def writer(i):
        if store.put("race", i, if_absent=True):
            wins.append(i)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    assert store.get("race") == wins[0]


def test_list_prefix_and_delete():
    store = ObjectStore()
    for i in range(5):
        store.put(f"a/{i}", i)
    store.put("b/0", 0)
    assert len(store.list("a/")) == 5
    store.delete("a/3")
    assert len(store.list("a/")) == 4


def test_file_backend_durability(tmp_path):
    store = ObjectStore(backend=FileBackend(str(tmp_path)))
    store.put("x/y", {"v": np.arange(10)})
    # a second store over the same dir sees the data (process restart model)
    store2 = ObjectStore(backend=FileBackend(str(tmp_path)))
    np.testing.assert_array_equal(store2.get("x/y")["v"], np.arange(10))
    assert store2.list("x/") == ["x/y"]


def test_file_backend_put_if_absent(tmp_path):
    store = ObjectStore(backend=FileBackend(str(tmp_path)))
    assert store.put("k", 1, if_absent=True)
    assert not store.put("k", 2, if_absent=True)
    assert store.get("k") == 1


def test_ledger_accounting():
    store = ObjectStore()
    store.put("k", b"x" * 1000, worker="w0")
    store.get("k", worker="w0")
    per = store.ledger.per_worker()["w0"]
    assert per["put"][0] > 1000  # serialized size >= payload
    assert per["get"][1] > 0  # virtual time charged


# ---------------------------------------------------------------------------
# kv store
# ---------------------------------------------------------------------------

def test_kv_atomic_ops():
    kv = KVStore(num_shards=4)
    assert kv.setnx("a", 1)
    assert not kv.setnx("a", 2)
    assert kv.incr("ctr", 5) == 5
    assert kv.incr("ctr", 2) == 7
    assert kv.cas("a", 1, 10)
    assert not kv.cas("a", 1, 20)
    assert kv.get("a") == 10


def test_kv_eval_server_side_atomic():
    kv = KVStore(num_shards=2)
    kv.set("vec", np.zeros(4))
    n_threads, n_iters = 8, 50

    def worker():
        for _ in range(n_iters):
            kv.eval("vec", lambda v: v + 1.0)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    np.testing.assert_allclose(kv.get("vec"), n_threads * n_iters)


def test_kv_lists():
    kv = KVStore()
    kv.rpush("q", 1, 2, 3)
    assert kv.llen("q") == 3
    assert kv.lpop("q") == 1
    assert kv.lrange("q") == [2, 3]


def test_kv_sharding_spreads_keys():
    kv = KVStore(num_shards=8)
    for i in range(256):
        kv.set(f"key{i}", i)
    used = sum(1 for s in kv.shard_stats() if s.ops > 0)
    assert used >= 6  # crc32 spreads across most shards


# ---------------------------------------------------------------------------
# shuffle
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=200), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_range_partition_complete_and_ordered(values, nparts):
    splitters = shf.sample_splitters(values, nparts)
    parts = shf.range_partition(values, splitters)
    # no loss, no duplication
    flat = sorted(x for p in parts for x in p)
    assert flat == sorted(values)
    # range property: max(part i) <= min(part i+1) boundary via splitters
    for i, part in enumerate(parts[:-1]):
        for x in part:
            assert all(x <= s for s in splitters[i : i + 1]) or True
        if part and parts[i + 1]:
            assert max(part) <= min(x for x in parts[i + 1]) or max(part) <= splitters[i]


@given(st.integers(0, 5), st.integers(1, 6), st.integers(10, 80))
@settings(max_examples=20, deadline=None)
def test_hash_partition_groups_keys(seed, nparts, n):
    rng = np.random.default_rng(seed)
    pairs = [(int(rng.integers(0, 10)), i) for i in range(n)]
    parts = shf.hash_partition(pairs, nparts)
    assert sum(len(p) for p in parts) == n
    # every key lands in exactly one partition
    for key in {k for k, _ in pairs}:
        hit = [i for i, p in enumerate(parts) if any(k == key for k, _ in p)]
        assert len(hit) == 1


def test_sort_records_shape():
    recs = shf.make_sort_records(10, seed=0)
    assert recs.shape == (10, 100)
    assert len(shf.record_sort_key(recs[0])) == 10
