"""Training substrate: optimizer, microbatching, checkpoints, elasticity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.core import WrenExecutor
from repro.data import DataConfig, synthetic_batch
from repro.storage import FileBackend, ObjectStore
from repro.train import (
    ElasticTrainConfig,
    adamw,
    cosine_schedule,
    init_train_state,
    make_train_step,
    train_elastic,
)
from repro.train import checkpoint as ck
from repro.train.optimizer import _q8_decode, _q8_encode, global_norm


CFG = CONFIGS["llama3-8b"].reduced()
DCFG = DataConfig(seq_len=24, global_batch=4, vocab_size=CFG.vocab_size)


def test_adamw_reduces_loss():
    opt = adamw(3e-3, weight_decay=0.0)
    state = init_train_state(CFG, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, opt))
    losses = []
    for i in range(25):
        state, m = step(state, synthetic_batch(DCFG, i % 4, CFG))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_microbatch_equivalence():
    """grad accumulation over microbatches == single big batch (same loss)."""
    opt = adamw(1e-3)
    state = init_train_state(CFG, opt, jax.random.PRNGKey(1))
    batch = synthetic_batch(DCFG, 0, CFG)
    s1, m1 = make_train_step(CFG, opt, microbatches=1)(state, batch)
    s2, m2 = make_train_step(CFG, opt, microbatches=2)(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    p1 = jax.tree_util.tree_leaves(s1.params)[0]
    p2 = jax.tree_util.tree_leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=2e-4)


def test_grad_clip_bounds_update():
    opt = adamw(1e-3)
    state = init_train_state(CFG, opt, jax.random.PRNGKey(0))
    step = make_train_step(CFG, opt, grad_clip=1e-9)
    new_state, m = step(state, synthetic_batch(DCFG, 0, CFG))
    # with a tiny clip the update is ~lr * wd-ish only
    delta = global_norm(
        jax.tree_util.tree_map(lambda a, b: a - b, new_state.params, state.params)
    )
    assert float(delta) < 1.0


def test_q8_quantization_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * rng.uniform(0.01, 10))
    enc = _q8_encode(x)
    dec = _q8_decode(enc, x.shape)
    scale = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(dec - x))) <= scale / 127 + 1e-6


def test_int8_optimizer_trains():
    opt = adamw(3e-3, quantize_moments=True)
    state = init_train_state(CFG, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, opt))
    losses = []
    for i in range(15):
        state, m = step(state, synthetic_batch(DCFG, i % 4, CFG))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) <= 0.11
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-3)


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def _tiny_state():
    opt = adamw(1e-3)
    return opt, init_train_state(CFG, opt, jax.random.PRNGKey(0))


def test_checkpoint_roundtrip_and_versions():
    store = ObjectStore()
    _, state = _tiny_state()
    assert ck.save(store, "r", 0, tuple(state))
    assert not ck.save(store, "r", 0, tuple(state))  # idempotent publish
    assert ck.save(store, "r", 1, tuple(state))
    assert ck.latest_version(store, "r") == 1
    loaded, meta, v = ck.load(store, "r", 0)
    for a, b in zip(
        jax.tree_util.tree_leaves(tuple(state)), jax.tree_util.tree_leaves(loaded)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc():
    store = ObjectStore()
    _, state = _tiny_state()
    for v in range(5):
        ck.save(store, "g", v, tuple(state))
    ck.gc_old_versions(store, "g", keep=2)
    assert ck.latest_version(store, "g") == 4
    with pytest.raises(Exception):
        ck.load(store, "g", 0)


def test_checkpoint_survives_process_restart(tmp_path):
    store = ObjectStore(backend=FileBackend(str(tmp_path)))
    _, state = _tiny_state()
    ck.save(store, "d", 3, tuple(state), meta={"step": 30})
    store2 = ObjectStore(backend=FileBackend(str(tmp_path)))
    loaded, meta, v = ck.load(store2, "d")
    assert v == 3 and meta["step"] == 30


# ---------------------------------------------------------------------------
# elastic training through the serverless runtime
# ---------------------------------------------------------------------------

def test_elastic_train_with_scale_and_resume():
    opt = adamw(2e-3)
    batch_fn = lambda step: synthetic_batch(DCFG, step, CFG)  # noqa: E731
    wex = WrenExecutor(num_workers=2)
    try:
        tcfg = ElasticTrainConfig(run="el", steps_per_chunk=2, total_steps=8)
        hist = train_elastic(wex, CFG, opt, tcfg, batch_fn, scale_plan={2: 3})
        assert len(hist) == 4
        assert ck.latest_version(wex.store, "el") == 4
        # warm-container reuse kicked in after the first chunk
        assert sum(h["warm_start"] for h in hist) >= 2
        # resume: extend the run; driver continues from storage
        tcfg2 = ElasticTrainConfig(run="el", steps_per_chunk=2, total_steps=12)
        hist2 = train_elastic(wex, CFG, opt, tcfg2, batch_fn)
        assert len(hist2) == 2
        assert ck.latest_version(wex.store, "el") == 6
    finally:
        wex.shutdown()


def test_elastic_train_is_deterministic_across_duplicates():
    """Re-running a chunk from the same version writes identical params
    (idempotency of the stateless step chunk)."""
    opt = adamw(1e-3)
    batch_fn = lambda step: synthetic_batch(DCFG, step, CFG)  # noqa: E731
    from repro.train.elastic import WARM_CACHE, make_chunk_fn

    store = ObjectStore()
    tcfg = ElasticTrainConfig(run="det", steps_per_chunk=2, total_steps=4)
    state = init_train_state(CFG, opt, jax.random.PRNGKey(0))
    ck.save(store, "det", 0, tuple(state))
    chunk = make_chunk_fn(CFG, opt, store, tcfg, batch_fn)
    chunk(0)
    v1, _, _ = ck.load(store, "det", 1)
    # wipe warm cache + checkpoint v1, re-execute
    WARM_CACHE.clear()
    for k in store.list("ckpt/det/v00000001/"):
        store.delete(k)
    chunk(0)
    v1b, _, _ = ck.load(store, "det", 1)
    for a, b in zip(jax.tree_util.tree_leaves(v1), jax.tree_util.tree_leaves(v1b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
