"""Wire-protocol fuzz & adversarial-input suite for ``repro-kvd``.

Two layers:

  * **Codec** — ``encode_wire`` / ``FrameDecoder`` round-trip under every
    byte-boundary split (torn frames are the normal state of a socket
    mid-read), plus crafted corruption: truncated headers, CRC flips,
    oversized length claims, garbage payloads.  Property-based cases run
    when ``hypothesis`` is installed and skip cleanly when it is not (the
    crafted cases below cover the same invariants deterministically).
  * **Live server** — a real ``KVDServer`` fed malformed bytes on a raw
    socket.  The contract: malformed input is a clean *per-connection*
    error.  The offending connection is closed; every other client keeps
    working; a half-sent pipeline applies nothing.
"""

import socket
import struct
import time
import zlib

import pytest

from repro.storage import NetKVStore
from repro.storage.kv_store import _FRAME_HDR
from repro.storage.net_kv import (
    MAX_FRAME_LEN,
    ZERO_COPY_MIN,
    FrameDecoder,
    ProtocolError,
    encode_wire,
    encode_wire_parts,
    extract_buffers,
    parse_addr,
    parse_shard_map,
)
from repro.storage.net_server import KVDServer


# ---------------------------------------------------------------------------
# codec: round-trip
# ---------------------------------------------------------------------------

_SAMPLES = [
    ("req", 1, "kv.set", ("k", {"v": [1, 2, 3]}), {}),
    ("res", 7, None),
    ("err", 7, "KeyError", "missing"),
    ("kv", 3, 42, ("a", "b")),
    ("cast", "kv.rpush", ("durs", 0.5), {}),
    ("sub", "client-1", ("kv", "obj")),
    (),
    ("res", 0, b"\x00" * 4096),
]


def test_roundtrip_single_frames():
    for msg in _SAMPLES:
        dec = FrameDecoder()
        assert dec.feed(encode_wire(msg)) == [msg]


def test_roundtrip_pipelined_and_torn():
    """All sample frames concatenated, then fed one byte at a time — every
    possible tear point.  Each message pops out exactly once, in order."""
    blob = b"".join(encode_wire(m) for m in _SAMPLES)
    dec = FrameDecoder()
    out = []
    for i in range(len(blob)):
        out.extend(dec.feed(blob[i : i + 1]))
    assert out == _SAMPLES


def test_roundtrip_random_chunking():
    """Same pipeline under irregular chunk sizes (a socket's recv returns
    arbitrary prefixes)."""
    blob = b"".join(encode_wire(m) for m in _SAMPLES)
    for step in (2, 3, 7, 64, 1000, len(blob)):
        dec = FrameDecoder()
        out = []
        for off in range(0, len(blob), step):
            out.extend(dec.feed(blob[off : off + step]))
        assert out == _SAMPLES, f"chunk size {step}"


def test_hypothesis_roundtrip_any_object_any_chunking():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    values = st.recursive(
        st.none() | st.booleans() | st.integers() | st.floats(allow_nan=False)
        | st.text() | st.binary(),
        lambda children: st.lists(children) | st.tuples(children, children)
        | st.dictionaries(st.text(), children),
        max_leaves=20,
    )

    @hyp.given(msgs=st.lists(values, max_size=6), chunk=st.integers(1, 97))
    @hyp.settings(max_examples=200, deadline=None)
    def check(msgs, chunk):
        blob = b"".join(encode_wire(m) for m in msgs)
        dec = FrameDecoder()
        out = []
        for off in range(0, len(blob), chunk):
            out.extend(dec.feed(blob[off : off + chunk]))
        assert out == msgs

    check()


def test_hypothesis_decoder_never_hangs_or_crashes_on_garbage():
    """Arbitrary bytes fed to the decoder either wait for more input or
    raise ProtocolError — never any other exception, never a wrong decode
    of a frame that was not sent."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(junk=st.binary(max_size=512))
    @hyp.settings(max_examples=300, deadline=None)
    def check(junk):
        dec = FrameDecoder(max_frame=1 << 16)
        try:
            dec.feed(junk)
        except ProtocolError:
            pass

    check()


# ---------------------------------------------------------------------------
# codec: crafted adversarial inputs
# ---------------------------------------------------------------------------

def test_truncated_header_waits_not_raises():
    dec = FrameDecoder()
    assert dec.feed(b"\x01\x02\x03") == []  # 3 of 8 header bytes: torn, fine
    # completing the stream into a real frame still decodes
    frame = encode_wire("hello")
    dec2 = FrameDecoder()
    assert dec2.feed(frame[:5]) == []
    assert dec2.feed(frame[5:]) == ["hello"]


def test_crc_flip_raises_and_poisons():
    frame = bytearray(encode_wire({"k": 1}))
    frame[-1] ^= 0xFF  # flip a payload byte: CRC no longer matches
    dec = FrameDecoder()
    with pytest.raises(ProtocolError, match="CRC"):
        dec.feed(bytes(frame))
    # poisoned: even a pristine frame is refused now (resync inside a
    # corrupt pickle stream is hopeless)
    with pytest.raises(ProtocolError, match="poisoned"):
        dec.feed(encode_wire("fine"))


def test_oversized_length_fails_fast_without_allocating():
    hdr = _FRAME_HDR.pack(MAX_FRAME_LEN + 1, 0)
    dec = FrameDecoder()
    with pytest.raises(ProtocolError, match="exceeds cap"):
        dec.feed(hdr)


def test_undecodable_payload_raises_protocol_error():
    payload = b"\x80\x05not really a pickle"
    frame = _FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload
    dec = FrameDecoder()
    with pytest.raises(ProtocolError, match="undecodable"):
        dec.feed(frame)


def test_crc_collision_resistance_on_length_corruption():
    """Corrupting the length field misaligns the stream; whatever bytes
    then land under the CRC check must not silently decode."""
    frame = bytearray(encode_wire(("req", 1, "kv.get", ("k",), {})))
    good_len = struct.unpack_from("<I", frame, 0)[0]
    struct.pack_into("<I", frame, 0, good_len - 1)
    dec = FrameDecoder()
    try:
        out = dec.feed(bytes(frame))
    except ProtocolError:
        return  # detected — the expected outcome
    assert out == []  # or: short frame now torn, waiting forever — also safe


def _buffer_frame_blob(msg):
    """Encode ``msg`` with its large bytes-likes extracted into buffer
    frames; returns (wire bytes, expected decoded message)."""
    buffers = []
    wire_msg = extract_buffers(msg, buffers)
    assert buffers, "payload should have been extracted into a buffer frame"
    return b"".join(bytes(p) for p in encode_wire_parts(wire_msg, buffers)), msg


def test_torn_buffer_frame_reassembles_across_every_chunking():
    """A buffer frame torn at arbitrary points — including mid-header and
    mid-payload — reassembles into the original message exactly; the raw
    payload bytes are counted on the buffer path, not the pickle path."""
    payload = bytes(range(256)) * (ZERO_COPY_MIN // 256 + 17)
    blob, msg = _buffer_frame_blob(("res", 9, payload))
    for step in (1, 7, 4096, ZERO_COPY_MIN + 3, len(blob)):
        dec = FrameDecoder()
        out = []
        for off in range(0, len(blob), step):
            out.extend(dec.feed(blob[off : off + step]))
        assert out == [msg], f"chunk size {step}"
        assert dec.bytes_buffer == len(payload)
        assert dec.bytes_pickled < 256  # only the tiny control frame


def test_torn_buffer_frame_fill_mode_recv_into_path():
    """The pump's fast path: a torn buffer frame flips the decoder into
    fill mode (``wanted``/``fill_view``/``filled``), and the socket bytes
    land directly in the payload's final buffer."""
    payload = bytes(range(251)) * (ZERO_COPY_MIN // 251 + 5)
    blob, msg = _buffer_frame_blob(("res", 3, payload))
    dec = FrameDecoder()
    pos = _FRAME_HDR.size + 10  # header + first 10 payload bytes
    assert dec.feed(blob[:pos]) == []
    assert dec.wanted() == len(payload) - 10
    while dec.wanted():
        n = min(dec.wanted(), 3333)  # a recv_into returning partial reads
        dec.fill_view()[:n] = blob[pos : pos + n]
        dec.filled(n)
        pos += n
    assert dec.wanted() == 0
    out = dec.feed(blob[pos:])  # the control frame binds the filled buffer
    assert out == [msg]
    assert dec.bytes_buffer == len(payload)


def test_buffer_frame_crc_flip_raises_and_poisons():
    payload = b"\xab" * (ZERO_COPY_MIN + 100)
    blob, _msg = _buffer_frame_blob(("res", 1, payload))
    corrupt = bytearray(blob)
    corrupt[_FRAME_HDR.size + 50] ^= 0xFF  # flip a raw payload byte
    dec = FrameDecoder()
    with pytest.raises(ProtocolError, match="CRC"):
        dec.feed(bytes(corrupt))
    with pytest.raises(ProtocolError, match="poisoned"):
        dec.feed(encode_wire("fine"))
    # same flip, but delivered through the fill-mode path
    dec2 = FrameDecoder()
    dec2.feed(bytes(corrupt[: _FRAME_HDR.size + 8]))
    n = len(payload) - 8
    dec2.fill_view()[:n] = corrupt[_FRAME_HDR.size + 8 : _FRAME_HDR.size + 8 + n]
    with pytest.raises(ProtocolError, match="CRC"):
        dec2.filled(n)


def test_dangling_buffer_placeholder_raises():
    """A control frame referencing a buffer index that never arrived is a
    protocol error, not a silent placeholder leak."""
    from repro.storage.net_kv import _WireBuf

    small = b"x" * (ZERO_COPY_MIN + 1)
    buffers = []
    extract_buffers(small, buffers)  # one real buffer: index 0
    parts = encode_wire_parts(("res", 1, _WireBuf(1)), buffers)  # refers to #1
    dec = FrameDecoder()
    with pytest.raises(ProtocolError, match="without a matching buffer"):
        dec.feed(b"".join(bytes(p) for p in parts))


def test_small_payloads_stay_on_the_pickle_path():
    """Below ZERO_COPY_MIN nothing is extracted — one pickled frame, and
    small memoryviews are normalized to bytes so they still pickle."""
    buffers = []
    msg = extract_buffers(("res", 2, memoryview(b"small")), buffers)
    assert buffers == []
    assert msg == ("res", 2, b"small")
    dec = FrameDecoder()
    assert dec.feed(encode_wire(msg)) == [msg]
    assert dec.bytes_buffer == 0


def test_parse_addr_forms():
    assert parse_addr("127.0.0.1:4000") == ("127.0.0.1", 4000)
    assert parse_addr(("h", 9)) == ("h", 9)
    assert parse_addr("unix:/tmp/kvd.sock") == ("unix:/tmp/kvd.sock", 0)
    with pytest.raises(ValueError):
        parse_addr("no-port-here")


def test_parse_shard_map_forms():
    # single endpoint: the N=1 degenerate case
    assert parse_shard_map("127.0.0.1:4000") == [("127.0.0.1", 4000)]
    assert parse_shard_map(("h", 9)) == [("h", 9)]
    # comma-joined string and list forms; ORDER IS THE TOPOLOGY
    assert parse_shard_map("a:1, b:2") == [("a", 1), ("b", 2)]
    assert parse_shard_map(["a:1", ("b", 2), "unix:/tmp/k.sock"]) == [
        ("a", 1),
        ("b", 2),
        ("unix:/tmp/k.sock", 0),
    ]


# ---------------------------------------------------------------------------
# live server: malformed input is a per-connection error
# ---------------------------------------------------------------------------

@pytest.fixture
def server(tmp_path):
    srv = KVDServer(
        str(tmp_path / "kvd"),
        f"unix:{tmp_path / 'kvd.sock'}",
        num_shards=2,
        fsync="never",
    ).start()
    yield srv
    srv.close()


def _raw_conn(srv):
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(5.0)
    sock.connect(srv.address[len("unix:"):])
    return sock


def _recv_closed(sock):
    """True if the peer closed the connection (EOF) within the timeout."""
    try:
        while True:
            if sock.recv(4096) == b"":
                return True
    except socket.timeout:
        return False
    finally:
        sock.close()


def test_garbage_closes_only_that_connection(server):
    good = NetKVStore(server.address)
    try:
        good.set("k", 1)
        evil = _raw_conn(server)
        evil.sendall(b"\xde\xad\xbe\xef" * 64)  # insane length + junk
        assert _recv_closed(evil), "server must drop the malformed conn"
        # the well-behaved client is completely unaffected
        assert good.get("k") == 1
        good.set("k2", 2)
        assert good.get("k2") == 2
    finally:
        good.close()


def test_corrupt_crc_closes_only_that_connection(server):
    good = NetKVStore(server.address)
    try:
        evil = _raw_conn(server)
        frame = bytearray(encode_wire(("sub", "evil", ("kv",))))
        frame[-1] ^= 0xFF
        evil.sendall(bytes(frame))
        assert _recv_closed(evil)
        good.set("x", "y")
        assert good.get("x") == "y"
    finally:
        good.close()


def test_half_sent_pipeline_applies_nothing(server):
    """A connection that dies mid-frame must leave no partial effects: ops
    execute only on whole, valid frames."""
    good = NetKVStore(server.address)
    try:
        evil = _raw_conn(server)
        # handshake properly so the conn is a real client
        evil.sendall(encode_wire(("sub", "evil-client", ())))
        dec = FrameDecoder()
        while not dec.feed(evil.recv(4096)):
            pass  # hello
        # one whole set + the first half of a second — then vanish
        whole = encode_wire(("req", 1, "kv.set", ("applied", 1), {}))
        torn = encode_wire(("req", 2, "kv.set", ("torn", 1), {}))
        evil.sendall(whole + torn[: len(torn) // 2])
        evil.close()
        deadline = time.monotonic() + 5.0
        while good.get("applied") is None and time.monotonic() < deadline:
            time.sleep(0.01)
        assert good.get("applied") == 1  # the whole frame landed
        assert good.get("torn") is None  # the torn one never executed
    finally:
        good.close()


def test_oversized_length_claim_rejected_without_allocation(server):
    evil = _raw_conn(server)
    evil.sendall(_FRAME_HDR.pack(MAX_FRAME_LEN + 1, 0))
    assert _recv_closed(evil)


def test_req_before_handshake_is_rejected(server):
    """The sub handshake gates everything; a request-first client is
    dropped cleanly."""
    evil = _raw_conn(server)
    evil.sendall(encode_wire(("req", 1, "kv.get", ("k",), {})))
    assert _recv_closed(evil)


def test_unpicklable_payload_closes_conn_not_server(server):
    good = NetKVStore(server.address)
    try:
        payload = b"\x80\x05garbage that is not a pickle"
        frame = _FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload
        evil = _raw_conn(server)
        evil.sendall(frame)
        assert _recv_closed(evil)
        assert good.incr("alive") == 1
    finally:
        good.close()
