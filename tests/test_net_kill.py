"""Crash-recovery suite for ``repro-kvd``: SIGKILL the server process
under live traffic and pin the recovery contract.

The server here is a real subprocess (the ``python -m
repro.storage.net_server`` CLI — the same entry point a deployment
runs), killed with SIGKILL so nothing gets to flush, unwind, or say
goodbye, then restarted over the same root and address.  The pins:

  * **acknowledged writes survive** — any op the client saw complete is
    in the store after restart (the shard logs append before the server
    replies; a SIGKILL loses at most the unacknowledged suffix);
  * **batch atomicity holds across the kill** — a same-shard batched
    write is one log transaction: after recovery it is all-there or
    not-there, never half;
  * **clients reconnect and resync transparently** — in-flight calls
    block through the outage and complete against the new server
    (at-least-once resend; see net_kv's module docstring for where
    exactly-once is layered on top);
  * **no lost wakeups** — a ``blpop`` waiter blocked across the restart
    is woken by a push from a *different* client against the new server
    generation (its per-key watch was re-registered on reconnect);
  * **the executor stack rides it out** — a ``WrenExecutor`` map whose
    control plane lives on the killed server still returns exactly its
    results, no losses, no duplicates.

Churn payloads are sized to force log compaction (64 KiB per-shard
threshold) while the kill lands, so the mid-compaction crash path — the
generation-rename dance in ``file_kv`` — is exercised, not just the
append path.
"""

import os
import signal
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.storage import NetBackend, NetKVStore, ObjectStore

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


class _Server:
    """The repro-kvd subprocess, killable and restartable in place (same
    root, same port — what a supervisor like systemd would do)."""

    def __init__(self, root: str, port: int) -> None:
        self.root = root
        self.port = port
        self.proc = None

    @property
    def address(self) -> str:
        return f"127.0.0.1:{self.port}"

    def start(self) -> "_Server":
        env = dict(os.environ)
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        self.proc = subprocess.Popen(
            [
                sys.executable, "-m", "repro.storage.net_server",
                "--root", self.root, "--port", str(self.port),
                "--num-shards", "4", "--fsync", "never",
            ],
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            text=True,
            env=env,
        )
        line = self.proc.stdout.readline().strip()
        assert line.startswith("LISTENING"), f"server failed to start: {line!r}"
        return self

    def kill(self) -> None:
        self.proc.send_signal(signal.SIGKILL)
        self.proc.wait()

    def stop(self) -> None:
        if self.proc and self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()


@pytest.fixture
def server(tmp_path):
    srv = _Server(str(tmp_path / "kvd"), _free_port()).start()
    yield srv
    srv.stop()


def _same_shard_keys(kv, batch: int, n: int):
    """``n`` keys for ``batch`` that all live in one shard, so a batched
    write of them is a single log transaction (the atomicity unit)."""
    sidx = kv.shard_of(f"batch/{batch}/0")
    keys, i = [], 0
    while len(keys) < n:
        k = f"batch/{batch}/{i}"
        if kv.shard_of(k) == sidx:
            keys.append(k)
        i += 1
    return keys


def test_kill_mid_churn_acknowledged_writes_survive(server):
    """Sequential writer churns fat values (forcing compactions); SIGKILL
    lands mid-stream; the writer's in-flight call completes against the
    restarted server and every acknowledged write is still there."""
    kv = NetKVStore(server.address)
    n, payload = 300, "x" * 2048  # ~600 KiB through 4 shards: compacts often
    acked = []
    failures = []

    def writer():
        try:
            for i in range(n):
                kv.set(f"seq/{i}", (i, payload))
                acked.append(i)
        except Exception as exc:  # pragma: no cover - surfaced below
            failures.append(exc)

    t = threading.Thread(target=writer)
    t.start()
    while len(acked) < 40:
        time.sleep(0.005)
    server.kill()
    time.sleep(0.15)
    server.start()
    t.join(timeout=60)
    assert not t.is_alive(), "writer wedged across the restart"
    assert not failures, failures
    assert len(acked) == n  # every call completed, outage included
    got = kv.mget([f"seq/{i}" for i in range(n)])
    assert got == [(i, payload) for i in range(n)]
    assert kv._client.reconnects >= 1
    kv.close()


def test_kill_mid_batches_every_acked_batch_whole(server):
    """Batched same-shard writes across TWO kill/restart cycles: after
    recovery, acknowledged batches are fully present, and no batch is
    half-present (one log transaction each)."""
    kv = NetKVStore(server.address)
    n_batches, width, payload = 120, 4, "y" * 1024
    acked = set()
    failures = []

    def writer():
        try:
            for b in range(n_batches):
                keys = _same_shard_keys(kv, b, width)
                kv.mset({k: (b, payload) for k in keys})
                acked.add(b)
        except Exception as exc:  # pragma: no cover
            failures.append(exc)

    t = threading.Thread(target=writer)
    t.start()
    for threshold in (20, 60):
        while len(acked) < threshold and t.is_alive():
            time.sleep(0.005)
        server.kill()
        time.sleep(0.15)
        server.start()
    t.join(timeout=60)
    assert not t.is_alive() and not failures, failures
    assert acked == set(range(n_batches))
    for b in range(n_batches):
        keys = _same_shard_keys(kv, b, width)
        got = kv.mget(keys, default=None)
        present = [v for v in got if v is not None]
        assert len(present) in (0, width), f"batch {b} half-applied: {got}"
        assert len(present) == width  # it was acked, so it must be whole
        assert all(v == (b, payload) for v in present)
    assert kv._client.reconnects >= 2
    kv.close()


def test_blpop_waiter_survives_restart_no_lost_wakeup(server):
    """A consumer blocked in ``blpop`` before the kill is woken by a push
    from a DIFFERENT client against the restarted server: its per-key
    watch was re-registered on the new generation during reconnect."""
    kv = NetKVStore(server.address)
    for i in range(50):
        kv.set(f"pre/{i}", i)
    got = {}

    def popper():
        got["v"] = kv.blpop("killq", timeout_s=30.0)

    t = threading.Thread(target=popper)
    t.start()
    time.sleep(0.3)  # waiter registered and blocked
    server.kill()
    time.sleep(0.15)
    server.start()
    # late ops complete transparently; the committed prefix survived
    kv.set("post", "yes")
    assert kv.get("post") == "yes"
    assert kv.mget([f"pre/{i}" for i in range(50)]) == list(range(50))
    # the push comes from a FRESH client: only the re-registered watch on
    # the new server can route this wake to the old waiter
    kv2 = NetKVStore(server.address)
    kv2.rpush("killq", "survived")
    t.join(timeout=30)
    assert got.get("v") == "survived"
    assert kv._client.reconnects >= 1
    kv2.close()
    kv.close()


def _first_key(kv, daemon, prefix):
    i = 0
    while True:
        k = f"{prefix}/{i}"
        if kv._daemon_of(k) == daemon:
            return k
        i += 1


def test_shard_map_kill_one_daemon_partial_outage(tmp_path):
    """SIGKILL one daemon of a 2-daemon shard map under churn.  The pins:
    ops on the surviving daemon's shards stay live through the outage
    (independent connections — one daemon's crash degrades only its own
    shards), acknowledged writes on the killed daemon's shards are all
    present after restart, and watch re-registration wakes waiters on both
    sides of the partial outage."""
    srv_a = _Server(str(tmp_path / "a"), _free_port()).start()
    srv_b = _Server(str(tmp_path / "b"), _free_port()).start()
    shard_map = f"{srv_a.address},{srv_b.address}"
    kv = NetKVStore(shard_map)
    kv2 = NetKVStore(shard_map)  # the waker: a different client
    try:
        all_keys = [f"k/{i}" for i in range(120)]
        a_keys = [k for k in all_keys if kv._daemon_of(k) == 0]
        b_keys = [k for k in all_keys if kv._daemon_of(k) == 1]
        assert len(a_keys) > 10 and len(b_keys) > 10  # the map really splits
        aq = _first_key(kv, 0, "q")  # queue key on the surviving daemon
        bq = _first_key(kv, 1, "p")  # queue key on the daemon we kill
        payload = "z" * 2048  # fat enough to force compactions server-side

        acked = []
        failures = []

        def writer():
            try:
                for i in range(600):
                    k = all_keys[i % len(all_keys)]
                    kv.set(k, (i, payload))
                    acked.append(i)
                    time.sleep(0.002)
            except Exception as exc:  # pragma: no cover - surfaced below
                failures.append(exc)

        wt = threading.Thread(target=writer)
        wt.start()
        # a waiter on the doomed daemon's shard, blocked BEFORE the kill
        b_got = {}
        bt = threading.Thread(
            target=lambda: b_got.update(v=kv.blpop(bq, timeout_s=60.0))
        )
        bt.start()
        while len(acked) < 40:
            time.sleep(0.005)
        time.sleep(0.2)  # the blpop watch is registered by now
        srv_b.kill()
        # --- during the outage: the surviving daemon never blocks --------
        t0 = time.monotonic()
        probe = _first_key(kv, 0, "live")  # owned by the surviving daemon
        kv.set(probe, "up")
        assert kv.get(probe) == "up"
        assert all(
            v is None or v[1] == payload for v in kv.mget(a_keys, default=None)
        )
        assert time.monotonic() - t0 < 2.0, "surviving shards stalled"
        # a waiter on the surviving daemon is woken DURING the outage
        a_got = {}
        at = threading.Thread(
            target=lambda: a_got.update(v=kv.blpop(aq, timeout_s=15.0))
        )
        at.start()
        time.sleep(0.3)
        kv2.rpush(aq, "live")
        at.join(timeout=15)
        assert a_got.get("v") == "live"
        # --- restart: the killed daemon's shards recover ------------------
        srv_b.start()
        wt.join(timeout=120)
        assert not wt.is_alive(), "writer wedged across the partial outage"
        assert not failures, failures
        assert len(acked) == 600  # every call completed, outage included
        got = kv.mget(all_keys)
        expect = [(480 + j, payload) for j in range(120)]  # the final cycle
        assert got == expect
        # the waiter blocked across the restart is woken by a fresh push:
        # its watch was re-registered on the new server generation
        kv2.rpush(bq, "back")
        bt.join(timeout=30)
        assert b_got.get("v") == "back"
        # reconnects stayed per-daemon: only the killed daemon's client redialed
        assert kv._clients[1].reconnects >= 1
        assert kv._clients[0].reconnects == 0
    finally:
        kv2.close()
        kv.close()
        srv_a.stop()
        srv_b.stop()


def test_executor_map_exact_results_across_kill(server):
    """End to end: a WrenExecutor map whose whole control plane (queues,
    leases, results) lives on the killed server still produces exactly
    its results — nothing lost to the outage, nothing duplicated (task
    effects are exactly-once over at-least-once wire ops: deterministic
    task ids, epoch-fenced leases, ``if_absent`` result publishes)."""
    from repro.core import WrenExecutor, get_all

    kv = NetKVStore(server.address)
    store = ObjectStore(backend=NetBackend(server.address))
    with WrenExecutor(store=store, kv=kv, num_workers=4) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        futs = wex.map(lambda x: x * 3, list(range(48)))
        time.sleep(0.2)  # mid-flight
        server.kill()
        time.sleep(0.15)
        server.start()
        results = get_all(futs, timeout_s=120)
    assert results == [x * 3 for x in range(48)]
    assert kv._client.reconnects >= 1
    store.backend.close()
    kv.close()
