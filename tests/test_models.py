"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Each assigned arch instantiates a REDUCED config of the same family and runs
one forward/train step asserting output shapes and finiteness; decode paths
must agree with the full forward (exact for deterministic families,
tolerance for capacity-dropping MoE).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, applicable_shapes
from repro.data import DataConfig, synthetic_batch
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.train import adamw, init_train_state, make_train_step

ARCHS = sorted(CONFIGS)


def _batch_for(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embed"] = (
            jax.random.normal(key, (B, cfg.num_prefix_tokens, cfg.d_model)) * 0.1
        )
    if cfg.family == "encdec":
        batch["audio_frames"] = (
            jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = CONFIGS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch_for(cfg, B, S)
    logits, aux, _ = forward(params, cfg, batch)
    S_total = S + (cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = CONFIGS[arch].reduced()
    opt = adamw(1e-3)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt)
    dcfg = DataConfig(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)
    batch = synthetic_batch(dcfg, 0, cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    before = jax.tree_util.tree_leaves(state.params)[0]
    after = jax.tree_util.tree_leaves(new_state.params)[0]
    assert not np.allclose(before, after)


@pytest.mark.parametrize(
    "arch",
    [a for a in ARCHS if CONFIGS[a].moe is None],  # MoE: capacity drops differ
)
def test_decode_matches_forward_exactly(arch):
    cfg = CONFIGS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S_prompt, n_dec = 2, 12, 3
    total = S_prompt + n_dec
    batch_full = _batch_for(cfg, B, total, seed=1)
    logits_full, _, _ = forward(params, cfg, batch_full)
    prefix = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0

    cache = init_cache(cfg, B, max_len=total + prefix + 4, cache_dtype=jnp.float32)
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :S_prompt]
    lg, cache, clen = prefill(params, cfg, batch_pre, cache)
    np.testing.assert_allclose(
        lg[:, -1], logits_full[:, prefix + S_prompt - 1], atol=2e-3, rtol=1e-3
    )
    for t in range(n_dec):
        lg, cache = decode_step(
            params, cfg, batch_full["tokens"][:, S_prompt + t][:, None], cache, clen
        )
        clen = clen + 1
        np.testing.assert_allclose(
            lg[:, 0], logits_full[:, prefix + S_prompt + t], atol=2e-3, rtol=1e-3
        )


@pytest.mark.parametrize("arch", [a for a in ARCHS if CONFIGS[a].moe is not None])
def test_decode_close_for_moe(arch):
    """Capacity-based MoE may drop different tokens at different batch
    compositions (known train/serve property); require closeness only."""
    cfg = CONFIGS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S_prompt = 2, 12
    batch_full = _batch_for(cfg, B, S_prompt + 1, seed=1)
    logits_full, _, _ = forward(params, cfg, batch_full)
    cache = init_cache(cfg, B, max_len=S_prompt + 8, cache_dtype=jnp.float32)
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :S_prompt]
    lg, cache, clen = prefill(params, cfg, batch_pre, cache)
    # rank correlation of top prediction rather than exact equality
    top_full = np.asarray(jnp.argmax(logits_full[:, S_prompt - 1], -1))
    top_dec = np.asarray(jnp.argmax(lg[:, -1], -1))
    assert (top_full == top_dec).mean() >= 0.5
    err = float(jnp.max(jnp.abs(lg[:, -1] - logits_full[:, S_prompt - 1])))
    assert err < 0.2


def test_param_counts_match_published_sizes():
    expect = {
        "llama3-405b": 405e9,
        "llama3-8b": 8.0e9,
        "gemma2-27b": 27.2e9,
        "qwen3-32b": 32.8e9,
        "deepseek-v3-671b": 671e9,
        "olmoe-1b-7b": 6.9e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expect.items():
        total, _ = CONFIGS[arch].param_count()
        assert abs(total - target) / target < 0.06, (arch, total)


def test_moe_active_params():
    total, active = CONFIGS["deepseek-v3-671b"].param_count()
    assert active < total * 0.08  # ~37B of 671B
    total, active = CONFIGS["olmoe-1b-7b"].param_count()
    assert active < total * 0.25


def test_shape_applicability():
    for arch, cfg in CONFIGS.items():
        names = {s.name for s in applicable_shapes(cfg)}
        if cfg.family in ("hybrid", "ssm"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= names


def test_gemma2_local_global_pattern():
    cfg = CONFIGS["gemma2-27b"]
    kinds = cfg.layer_kinds()
    assert kinds[0] == "attn_local" and kinds[1] == "attn_global"
    assert len(kinds) == 46


def test_vlm_prefix_changes_text_logits():
    cfg = CONFIGS["internvl2-1b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 1, 8, seed=2)
    l1, _, _ = forward(params, cfg, batch)
    batch2 = dict(batch)
    batch2["prefix_embed"] = batch["prefix_embed"] + 1.0
    l2, _, _ = forward(params, cfg, batch2)
    # causal: prefix influences text positions
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 1e-4


def test_whisper_encoder_affects_decoder():
    cfg = CONFIGS["whisper-large-v3"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 1, 8, seed=3)
    l1, _, _ = forward(params, cfg, batch)
    batch2 = dict(batch)
    batch2["audio_frames"] = batch["audio_frames"] * -1.0
    l2, _, _ = forward(params, cfg, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4
