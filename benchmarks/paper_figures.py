"""One benchmark per paper table/figure (virtual-time ledger methodology).

Every byte of the runtime executes for real (scheduler, workers, stores,
shuffles); only the wire-level durations come from the paper-calibrated
storage profiles, so the *relationships* the paper measured (per-worker
bandwidth, aggregate scaling, shard saturation, phase breakdowns) reproduce
deterministically on one CPU.

  table1_storage_bandwidth   Table 1  local-SSD vs remote write bandwidth
  fig2_flops_scaling         Fig 2    aggregate GFLOPS vs worker count
  fig3_storage_scaling       Fig 3    aggregate S3 MB/s vs worker count
  fig4_kv_scaling            Fig 4    KV txns/s vs worker count
  table2_featurization       Table 2  phase breakdown of featurize+fit
  wordcount_vs_baseline      §3.3     BSP wordcount vs dedicated baseline
  fig5_fig6_sort             Fig 5/6  sort cost/time vs workers x shards
  resource_balance           §4       IO:compute proportioning
"""

from __future__ import annotations

import time
from collections import Counter

import numpy as np

from repro.core import (
    WrenExecutor,
    get_all,
    io_compute_balance,
    terasort,
    verify_sorted,
    word_count,
)
from repro.core.executor import COLD_START_MEAN_S, COLD_SETUP_MEAN_S
from repro.data import make_documents
from repro.storage import (
    DISAGG_2026,
    KVStore,
    LOCAL_SSD_C3,
    LOCAL_SSD_I2,
    LOCAL_SSD_I2_RAID,
    ObjectStore,
    REDIS_2017,
    S3_2017,
)
from repro.storage import shuffle as shf
from repro.storage.perf_model import GB, MB, S3_SINGLE_MACHINE_WRITE_BW

from .common import Reporter

# Paper-measured per-Lambda compute (Fig 2): 18 GFLOPS/worker.
LAMBDA_GFLOPS = 18.0


def table1_storage_bandwidth(rep: Reporter) -> None:
    rows = [
        ("SSD on c3.8xlarge", LOCAL_SSD_C3.write_bw_per_conn),
        ("SSD on i2.8xlarge", LOCAL_SSD_I2.write_bw_per_conn),
        ("4 SSDs on i2.8xlarge", LOCAL_SSD_I2_RAID.write_bw_per_conn),
        ("S3 (single machine)", S3_SINGLE_MACHINE_WRITE_BW),
    ]
    for name, bw in rows:
        rep.row(f"table1/{name}", 0.0, write_MBps=round(bw / MB, 2))


def fig2_flops_scaling(rep: Reporter) -> None:
    """Matrix-multiply benchmark inside each worker; aggregate GFLOPS vs N.

    Real numpy matmuls run in a few sampled workers to verify the per-worker
    rate; the sweep itself applies the measured per-worker rate across the
    worker counts of the figure (one CPU can't run 3000 threads of BLAS)."""
    n = 256
    flops_per_call = 2 * n**3
    with WrenExecutor(num_workers=4) as wex:
        a = np.random.default_rng(0).normal(size=(n, n))

        def matmul_bench(_):
            t0 = time.perf_counter()
            reps = 8
            for _ in range(reps):
                a @ a
            dt = time.perf_counter() - t0
            return flops_per_call * reps / dt / 1e9  # GFLOPS measured

        rates = wex.map_get(matmul_bench, list(range(4)))
    measured = float(np.mean(rates))
    for workers in (1, 10, 100, 1000, 2800, 3000):
        agg = LAMBDA_GFLOPS * workers  # paper-calibrated per-worker rate
        rep.row(
            f"fig2/workers={workers}", 0.0,
            aggregate_TFLOPS=round(agg / 1e3, 2),
            per_worker_GFLOPS=LAMBDA_GFLOPS,
            cpu_measured_GFLOPS=round(measured, 1),
        )


def fig3_storage_scaling(rep: Reporter) -> None:
    """Per-worker S3 bandwidth through the real runtime + analytic aggregate."""
    store = ObjectStore(profile=S3_2017)
    payload = np.zeros(20_000_000, np.uint8)  # streaming regime (Fig 3 uses large objects)
    with WrenExecutor(store=store, num_workers=4) as wex:
        def rw(i):
            store.put(f"f3/{i}", payload, worker=f"f3w{i}")
            store.get(f"f3/{i}", worker=f"f3w{i}")
            return i

        wex.map_get(rw, list(range(8)))
    per = store.ledger.per_worker()
    wr = [ops["put"][0] / ops["put"][1] for w, ops in per.items() if w.startswith("f3w")]
    rd = [ops["get"][0] / ops["get"][1] for w, ops in per.items() if w.startswith("f3w")]
    rep.row(
        "fig3/per_worker", 0.0,
        write_MBps=round(float(np.mean(wr)) / MB, 1),
        read_MBps=round(float(np.mean(rd)) / MB, 1),
    )
    for workers in (100, 1000, 2800):
        rep.row(
            f"fig3/workers={workers}", 0.0,
            agg_write_GBps=round(workers * S3_2017.effective_write_bw(workers) / GB, 1),
            agg_read_GBps=round(workers * S3_2017.effective_read_bw(workers) / GB, 1),
        )


def fig4_kv_scaling(rep: Reporter) -> None:
    """Synchronous 128-byte put/gets against the sharded KV store."""
    kv = KVStore(num_shards=2, profile=REDIS_2017)
    blob = b"x" * 128
    with WrenExecutor(num_workers=4, kv=kv) as wex:
        def txn(i):
            wid = f"f4w{i}"
            for j in range(50):
                kv.set(f"k{i}/{j}", blob, worker=wid)
                kv.get(f"k{i}/{j}", worker=wid)
            return i

        wex.map_get(txn, list(range(4)))
    recs = [r for r in kv.ledger.records() if r.worker.startswith("f4w")]
    mean_lat_ms = float(np.mean([r.vtime_s for r in recs])) * 1e3
    rep.row("fig4/latency", 0.0, mean_ms=round(mean_lat_ms, 3), sub_ms=mean_lat_ms < 1.0)
    for workers in (10, 100, 1000, 2000, 4000):
        r = REDIS_2017.effective_ops_per_s(workers, shards=2)
        rep.row(
            f"fig4/workers={workers}", 0.0,
            txn_per_s_per_worker=round(r, 1),
            aggregate_ktxn_s=round(workers * r / 1e3, 1),
        )


def table2_featurization(rep: Reporter) -> None:
    """Featurize (map over image shards) -> fetch -> fit linear classifier.

    Phases in virtual seconds, mirroring Table 2's (start, setup,
    featurization, fetch, fit) breakdown; compute phases are scaled to the
    paper's per-worker GFLOPS so the breakdown is Lambda-calibrated."""
    store = ObjectStore(profile=S3_2017)
    rng = np.random.default_rng(0)
    n_shards, imgs_per_shard = 8, 16
    dim = 32 * (32 // 2 + 1)  # |rfft2| of a 32x32 image, flattened
    for i in range(n_shards):
        store.put(f"imgs/{i}", rng.normal(size=(imgs_per_shard, 32, 32)).astype(np.float32))

    # compute-time calibration: CPU seconds -> Lambda seconds
    cpu_gflops_probe = 30.0
    scale = cpu_gflops_probe / LAMBDA_GFLOPS

    with WrenExecutor(store=store, num_workers=4, compute_time_fn=lambda s: s * scale) as wex:
        def featurize(i):
            w = f"t2w{i}"
            imgs = store.get(f"imgs/{i}", worker=w)
            feats = np.stack([
                np.abs(np.fft.rfft2(im)).reshape(-1) for im in imgs
            ])  # GIST-ish spectral features (dim = 32 * 17)
            store.put(f"feat/{i}", feats.astype(np.float32), worker=w)
            return i

        futs = wex.map(featurize, list(range(n_shards)))
        results = get_all(futs, timeout_s=120)
        phases = Counter()
        counts = Counter()
        for f in futs:
            res = f.peek()
            for k, v in res.phases.items():
                phases[k] += v
                counts[k] += 1

    # fetch to 'one big machine' and fit
    t0 = time.perf_counter()
    X = np.concatenate([store.get(f"feat/{i}", worker="reduce") for i in range(n_shards)])
    fetch_vt = sum(
        r.vtime_s for r in store.ledger.records() if r.worker == "reduce" and r.op == "get"
    )
    y = (rng.normal(size=len(X)) > 0).astype(np.float32)
    w = np.linalg.lstsq(X.T @ X + np.eye(dim), X.T @ y, rcond=None)[0]
    fit_s = (time.perf_counter() - t0) * scale
    rep.row(
        "table2/phases", 0.0,
        start_setup_s=round(phases["setup"] / max(counts["setup"], 1), 1),
        featurization_s=round(phases["compute"] / max(counts["compute"], 1), 2),
        fetch_s=round(fetch_vt, 2),
        fit_s=round(fit_s, 3),
        paper_start_s=COLD_START_MEAN_S + COLD_SETUP_MEAN_S,
    )


def wordcount_vs_baseline(rep: Reporter) -> None:
    """BSP wordcount on the serverless runtime vs an in-process 'dedicated
    cluster' baseline; the paper reports PyWren ~17% slower than Spark."""
    docs = make_documents(24, 40, seed=3)

    # in-process baseline ("dedicated cluster", no storage round trips)
    t0 = time.perf_counter()
    truth: Counter = Counter()
    for d in docs:
        for line in d:
            truth.update(line.split())
    base_s = time.perf_counter() - t0

    store = ObjectStore(profile=S3_2017)
    with WrenExecutor(store=store, num_workers=4) as wex:
        t0 = time.perf_counter()
        wc = word_count(wex, docs, num_reducers=4)
        wall_s = time.perf_counter() - t0
    assert wc == dict(truth)
    # virtual storage time is the PyWren overhead vs the baseline
    totals = store.ledger.totals()
    storage_vt = sum(v[1] for v in totals.values())
    rep.row(
        "wordcount/pywren_vs_baseline", wall_s * 1e6,
        baseline_wall_s=round(base_s, 4),
        runtime_wall_s=round(wall_s, 3),
        storage_virtual_s=round(storage_vt, 3),
        correct=True,
    )


def fig5_fig6_sort(rep: Reporter) -> None:
    """Sort benchmark: workers x Redis shards sweep with phase breakdown and
    prorated cost (Lambda $0.06/GB-hr in 100ms ticks; Redis prorated)."""
    n_files, recs = 8, 400
    for workers, shards in [(2, 1), (4, 1), (4, 4), (8, 4), (8, 8)]:
        store = ObjectStore(profile=S3_2017)
        wex = WrenExecutor(store=store, num_workers=workers)
        try:
            keys = []
            for i in range(n_files):
                k = f"sin/{i}"
                store.put(k, shf.make_sort_records(recs, seed=i))
                keys.append(k)
            kv = KVStore(num_shards=shards, profile=REDIS_2017)
            t0 = time.perf_counter()
            report = terasort(wex, keys, f"sout/{workers}x{shards}", n_files, intermediate=kv)
            wall = time.perf_counter() - t0
            assert verify_sorted(store, f"sout/{workers}x{shards}")
            # cost model (paper Fig 5): GB-seconds of Lambda + prorated Redis
            busy = sum(s.vtime_busy_s for s in wex.pool.stats().values())
            lambda_cost = busy / 3600 * 1.5 * 0.06  # 1.5GB containers
            redis_cost = shards * (wall / 3600) * 4.16  # cache.m4.10xlarge-ish
            rep.row(
                f"fig5/workers={workers},shards={shards}", wall * 1e6,
                hottest_shard_vtime_s=round(report.hottest_shard_vtime, 4),
                intermediate_objects=report.n_intermediate_objects,
                prorated_cost=round(lambda_cost + redis_cost, 5),
            )
        finally:
            wex.shutdown()


def resource_balance(rep: Reporter) -> None:
    out = io_compute_balance(1.5e9, 35e6, 300.0)
    rep.row(
        "resource_balance/lambda2017", 0.0,
        fill_s=round(out["fill_seconds"], 1),
        io_s=round(out["io_seconds"], 1),
        compute_s=round(out["compute_seconds"], 1),
        io_fraction=round(out["io_fraction"], 3),
    )
    out2 = io_compute_balance(16e9, DISAGG_2026.write_bw_per_conn, 300.0)
    rep.row(
        "resource_balance/disagg2026", 0.0,
        fill_s=round(out2["fill_seconds"], 2),
        io_s=round(out2["io_seconds"], 2),
        compute_s=round(out2["compute_seconds"], 1),
    )


ALL = [
    table1_storage_bandwidth,
    fig2_flops_scaling,
    fig3_storage_scaling,
    fig4_kv_scaling,
    table2_featurization,
    wordcount_vs_baseline,
    fig5_fig6_sort,
    resource_balance,
]
