"""Benchmark driver: one function per paper table/figure + runtime
microbenchmarks + the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import glob
import json
import os
import time


def runtime_overheads(rep) -> None:
    """§4 'Launch Overheads': per-task scheduling overhead of this runtime
    (real wall time, excludes the modeled Lambda cold start)."""
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=4) as wex:
        wex.map_get(lambda x: x, [0])  # warm up containers
        n = 200
        t0 = time.perf_counter()
        futs = wex.map(lambda x: x, list(range(n)))
        get_all(futs, timeout_s=120)
        dt = time.perf_counter() - t0
        rep.row("runtime/task_overhead", dt / n * 1e6, tasks=n, wall_s=round(dt, 3))


def kernel_microbench(rep) -> None:
    """Interpret-mode Pallas vs jnp-chunked wall time at small shapes (CPU
    correctness-path cost; TPU perf comes from the roofline analysis)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref
    from repro.kernels.flash_attention import flash_attention_pallas

    rng = np.random.default_rng(0)
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)

    for name, fn in [
        ("flash_pallas_interp", lambda: flash_attention_pallas(q, k, v, causal=True)),
        ("flash_jnp_chunked", lambda: ops._attention_chunked_jnp(
            q, k, v, causal=True, window=None, logit_cap=None, q_offset=0,
            scale=D**-0.5, block_k=128)),
        ("mha_reference", lambda: ref.mha_reference(q, k, v, causal=True)),
    ]:
        fn()  # compile/warm
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn().block_until_ready()
        rep.row(f"kernel/{name}", (time.perf_counter() - t0) / reps * 1e6)


def roofline_summary(rep) -> None:
    """Dry-run roofline table (reads reports/dryrun/*.json if present)."""
    root = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")
    files = sorted(glob.glob(os.path.join(root, "*.json")))
    if not files:
        rep.row("roofline/none", 0.0, note="run python -m repro.launch.dryrun --all first")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        rep.row(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}",
            d["step_bound_s"] * 1e6,
            dominant=d["dominant"],
            compute_ms=round(d["compute_s"] * 1e3, 2),
            memory_ms=round(d["memory_s"] * 1e3, 2),
            collective_ms=round(d["collective_s"] * 1e3, 2),
            useful_ratio=round(d["useful_ratio"], 3),
            roofline_fraction=round(d["roofline_fraction"], 4),
        )


def main() -> None:
    from .common import Reporter
    from .microbench import ALL as MICRO
    from .paper_figures import ALL

    rep = Reporter()
    for bench in ALL:
        bench(rep)
    runtime_overheads(rep)
    for bench in MICRO:
        bench(rep)
    kernel_microbench(rep)
    roofline_summary(rep)
    print(f"\n{len(rep.rows)} benchmark rows emitted")


if __name__ == "__main__":
    main()
