"""Benchmark helpers: CSV emission + timing."""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Dict, List


class Reporter:
    def __init__(self) -> None:
        self.rows: List[Dict[str, Any]] = []

    def row(self, name: str, us_per_call: float, **derived) -> None:
        d = {"name": name, "us_per_call": us_per_call, **derived}
        self.rows.append(d)
        extras = ",".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us_per_call:.2f},{extras}")


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["s"] = time.perf_counter() - t0
