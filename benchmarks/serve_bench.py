"""Open-loop serving benchmark: continuous batching under Poisson traffic.

The serving-plane counterpart of `microbench`: an open-loop generator
submits requests at a configured offered load (Poisson arrivals — the
client does NOT wait for responses, so queueing delay is measured, not
hidden), N `ContinuousEngine` workers lease them off the shared KV queue,
and every row reports the latency distribution a real client would see:

  serve/open_loop{suffix}_e{N}_r{RPS}:
    us_per_call     p50 end-to-end latency (submit -> result published)
    p99_ms          p99 end-to-end latency
    ttft_p50_ms     p50 time-to-first-token (submit -> first token sampled)
    ttft_p99_ms     p99 time-to-first-token
    tokens_per_s    sustained decode throughput over the serving window
    offered_rps     the generator's target arrival rate
    n_engines       engine workers sharing the queue
    speedup_vs_e1   tokens_per_s vs the 1-engine run at the same load

The 1->2->4 engine scale-out curve is the paper's elasticity story told
on the serving plane: engines are stateless workers over shared storage,
so capacity is "start another one".  ``speedup_vs_e1`` is the scale-out
acceptance number on a multi-core host (each engine's jitted decode
releases the GIL, so engines overlap across cores); on a single-core box
the engines share the one CPU and the ratio pins near 1, so — exactly as
with the microbench ``speedup_vs_d1`` column — the scale-out claim is
read from multi-core runs and CI gates only the tokens/s floor, never
the ratio blind.

Usage:
  PYTHONPATH=src python -m benchmarks.serve_bench --quick \
      --backends memory,file --json BENCH_serve.json \
      --floor-serve-tokens-per-s 40

Full curve (slower): --backends file,net --engines 1,2,4 --loads 4,16
"""

from __future__ import annotations

import tempfile
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from .microbench import _make_stores

_BACKEND_SUFFIX = {"memory": "", "file": "_file", "net": "_net"}


def _engine_parts(max_batch: int, max_new: int):
    import jax

    from repro.configs import CONFIGS
    from repro.models import init_params
    from repro.serve import ServeConfig

    cfg = CONFIGS["qwen3-32b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(
        max_batch=max_batch,
        max_len=96,
        max_new_tokens=max_new,
        decode_chunk=4,
        prefill_bucket=8,
        lease_timeout_s=2.0,
    )
    return cfg, params, scfg


def _warm(engine, max_batch: int) -> None:
    """Compile every shape the run will hit outside the measured window.

    Prefill jits per (group_size, bucketed_len) and the slot insert per
    group size, so a single warm request leaves ``max_batch - 1`` compiles
    to land mid-window — each engine owns its own jit wrappers, which is
    exactly the asymmetry that makes multi-engine rows look slow."""
    for g in range(1, max_batch + 1):
        engine.admit([(f"warm{g}-{j}", [1, 2, 3, 4, 5], 2) for j in range(g)])
        while engine.n_live():
            engine.step_chunk()
    for k in engine.stats:
        engine.stats[k] = 0


def _open_loop_once(
    rep,
    *,
    backend: str,
    n_engines: int,
    offered_rps: float,
    n_requests: int,
    prompt_lens=(4, 9),  # one prefill bucket: every shape is pre-warmed
    max_batch: int = 4,
    max_new: int = 16,
    seed: int = 0,
    e1_tokens_per_s: Optional[float] = None,
) -> float:
    from repro.serve import ContinuousEngine
    from repro.serve import request_plane as rp

    cfg, params, scfg = _engine_parts(max_batch, max_new)
    rng = np.random.default_rng(seed)
    ids = [f"q{i:04d}" for i in range(n_requests)]
    prompts = {
        r: rng.integers(0, cfg.vocab_size, size=int(rng.integers(*prompt_lens))).tolist()
        for r in ids
    }

    with tempfile.TemporaryDirectory() as workdir:
        store, kv, cleanup = _make_stores(backend, workdir)
        try:
            engines = [ContinuousEngine(cfg, params, scfg) for _ in range(n_engines)]
            for e in engines:
                _warm(e, max_batch)
            idle_s = max(2.5, 6.0 / offered_rps)
            threads = [
                threading.Thread(
                    target=e.run,
                    args=(store, kv),
                    kwargs=dict(engine_id=f"e{i}", idle_timeout_s=idle_s),
                    daemon=True,
                )
                for i, e in enumerate(engines)
            ]
            for t in threads:
                t.start()

            submit_ts: Dict[str, float] = {}

            def _client() -> None:
                for r in ids:
                    time.sleep(rng.exponential(1.0 / offered_rps))
                    submit_ts[r] = time.time()
                    rp.submit(store, kv, r, prompts[r], n_queues=scfg.n_queues)

            t0 = time.time()
            client = threading.Thread(target=_client, daemon=True)
            client.start()
            client.join()
            res = rp.get_results(store, ids, timeout_s=120.0)
            for t in threads:
                t.join()
        finally:
            if cleanup:
                cleanup()

    lat = np.asarray([res[r]["t_done"] - submit_ts[r] for r in ids])
    ttft = np.asarray([res[r]["t_first"] - submit_ts[r] for r in ids])
    total_tokens = sum(len(res[r]["tokens"]) for r in ids)
    window = max(res[r]["t_done"] for r in ids) - t0
    tokens_per_s = total_tokens / max(window, 1e-9)

    suffix = _BACKEND_SUFFIX[backend]
    name = f"serve/open_loop{suffix}_e{n_engines}_r{offered_rps:g}"
    extra: Dict[str, float] = {}
    if e1_tokens_per_s:
        extra["speedup_vs_e1"] = round(tokens_per_s / e1_tokens_per_s, 2)
    rep.row(
        name,
        float(np.percentile(lat, 50) * 1e6),  # us_per_call = p50 latency
        p99_ms=round(float(np.percentile(lat, 99) * 1e3), 2),
        ttft_p50_ms=round(float(np.percentile(ttft, 50) * 1e3), 2),
        ttft_p99_ms=round(float(np.percentile(ttft, 99) * 1e3), 2),
        tokens_per_s=round(tokens_per_s, 1),
        offered_rps=offered_rps,
        n_requests=n_requests,
        n_engines=n_engines,
        **extra,
    )
    return tokens_per_s


def open_loop(
    rep,
    *,
    backends: List[str],
    engines: List[int],
    loads: List[float],
    n_requests: int,
) -> None:
    for backend in backends:
        for rps in loads:
            e1: Optional[float] = None
            for n in engines:
                tps = _open_loop_once(
                    rep,
                    backend=backend,
                    n_engines=n,
                    offered_rps=rps,
                    n_requests=n_requests,
                    e1_tokens_per_s=e1,
                )
                if n == 1:
                    e1 = tps


def main(argv=None) -> int:
    import argparse
    import json

    from .common import Reporter

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small CI budget")
    ap.add_argument("--json", metavar="PATH", help="write rows as JSON")
    ap.add_argument(
        "--backends",
        default="memory",
        help="comma list of memory,file,net (shared-storage substrate "
        "the request plane rides on)",
    )
    ap.add_argument("--engines", default=None, help="comma list of engine counts")
    ap.add_argument("--loads", default=None, help="comma list of offered rps")
    ap.add_argument("--requests", type=int, default=None, help="requests per row")
    ap.add_argument(
        "--floor-serve-tokens-per-s",
        type=float,
        default=None,
        help="fail (exit 1) if the best serve row's sustained tokens/s is "
        "below this (a stall in the decode hot loop, the admission path, "
        "or the lease plane all collapse it)",
    )
    args = ap.parse_args(argv)

    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    engines = (
        [int(x) for x in args.engines.split(",")]
        if args.engines
        else ([1, 2] if args.quick else [1, 2, 4])
    )
    loads = (
        [float(x) for x in args.loads.split(",")]
        if args.loads
        else ([8.0] if args.quick else [4.0, 16.0])
    )
    n_requests = args.requests or (16 if args.quick else 48)

    rep = Reporter()
    open_loop(rep, backends=backends, engines=engines, loads=loads, n_requests=n_requests)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep.rows, f, indent=2)
        print(f"wrote {len(rep.rows)} rows to {args.json}")

    if args.floor_serve_tokens_per_s is not None:
        best = max((r.get("tokens_per_s", 0.0) for r in rep.rows), default=0.0)
        if best < args.floor_serve_tokens_per_s:
            print(
                f"FLOOR BREACH: best serve tokens/s {best} below floor "
                f"{args.floor_serve_tokens_per_s}"
            )
            return 1
        print(f"serve tokens/s floor ok: {best} >= {args.floor_serve_tokens_per_s}")

    # the scale-out pin: 2 engines must sustain more than 1 at equal load
    pairs = [
        (r["name"], r["speedup_vs_e1"]) for r in rep.rows
        if r.get("n_engines") == 2 and "speedup_vs_e1" in r
    ]
    for name, s in pairs:
        print(f"{name}: 2-engine speedup vs 1 = {s}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
