"""Control-plane microbenchmarks: map throughput, job completion time,
speculation sweeps (legacy factor + quantile rule), multi-driver overhead,
and shuffle request-count accounting.

Measures what the event-driven dispatch + batched data plane target:
per-task scheduling overhead with no-op user functions, so queue/lease/
notify/multi-get traffic dominates.  Reported rows:

  * ``runtime/map_throughput_w{N}`` — sustained tasks/s for a single map of
    ``n`` no-op tasks on N warm containers (derived: tasks/s, wall s);
  * ``runtime/job_completion_w{N}`` — wall time of a small *job* (submit →
    all futures resolved), the end-to-end latency a driver observes;
  * ``runtime/speculation_f{F}`` / ``runtime/speculation_q{Q}_k{K}`` —
    completion wall time of a map with one injected straggler worker,
    across the legacy ``factor × median`` rule and the PR-4
    quantile-adaptive rule (``max(floor, k × q)``): the tuning curves for
    ``SchedulerConfig`` (eager duplicates hide stragglers sooner at the
    cost of wasted work);
  * ``runtime/multi_driver_d{D}_w{W}`` — map throughput through D
    stateless scheduler handles (each its own executor + worker pool)
    sharing one KV/store, vs. one driver with the same total workers: the
    ``overhead_pct`` field is the cost of splitting the control plane
    (epoch-fenced CAS traffic + duplicated control loops);
  * ``runtime/adoption_latency`` — kill-to-resume wall time of the PR-7
    driver-failover path: a mapreduce driver "dies" (heartbeats stop) the
    instant its map barrier commits, and a second executor detects the
    lapsed driver lease, fences the takeover, and replays the job from the
    recorded barrier to the final merged result.  ``adoption_latency_ms``
    covers detect → fence → replay end to end (dominated by the lease
    timeout plus the reduce stage);
  * ``runtime/shuffle_requests_{obj,kv}`` — modeled storage *requests* per
    shuffle stage on the batched write plane vs. the looped (pre-batching,
    PR 2) write path: every ledger record is one modeled request, so the
    row counts exactly the Fig 5/6 bottleneck.  ``write_ratio`` is the
    map-stage request-count drop (looped ÷ batched; the acceptance floor
    is ≥ 2×), ``stage_requests``/``legacy_stage_requests`` cover the whole
    write → read → GC shuffle lifecycle;
  * ``storage/net_bandwidth_{size}_{mode}_d{N}`` (``--backend net``) —
    object-plane MB/s against live ``repro-kvd`` subprocesses, swept over
    payload size (64 KiB/1 MiB/8 MiB), frame mode (``zerocopy`` buffer
    frames vs ``pickled``), and shard-map width (1 vs 4 daemons; the d4
    rows carry ``speedup_vs_d1`` — the multi-daemon scale-out number);
  * ``storage/file_substrate_{engine}_fsync-{policy}`` (``--backend
    file``) — the PR-5 log-structured engine vs. the PR-4 snapshot engine
    under the durability-policy sweep, over a realistic resident state.
    ``ops_per_s`` is the wall-time comparison; ``disk_bytes_per_op`` is
    the deterministic structural one (O(record) appends vs. O(shard)
    rewrites — typically two orders of magnitude apart), immune to the
    host's I/O weather.

Run directly (``python -m benchmarks.microbench``) or via
``python -m benchmarks.run`` which includes these rows in the CSV.

CLI (the CI bench-smoke and multiprocess jobs use all of these):

  python -m benchmarks.microbench --quick --json BENCH_control_plane.json \\
      --floor-tasks-per-s 150 --floor-shuffle-ratio 2.0
  python -m benchmarks.microbench --quick --backend file \\
      --json BENCH_file_substrate.json --floor-tasks-per-s 85

``--quick`` shrinks budgets for CI, ``--json`` writes the rows as a JSON
artifact (CI uploads ``BENCH_control_plane*.json`` and
``BENCH_file_substrate*.json`` so the perf trajectory is tracked per
commit), ``--floor-tasks-per-s`` exits non-zero if the 4-worker map
throughput regresses below the floor (any event-loss stall — a missed
cross-process wake falling back to timeouts — collapses throughput and
trips this), and ``--floor-shuffle-ratio`` exits non-zero if the batched
write plane stops beating the looped path by the given factor.
``--backend file`` runs the map + substrate benches over ``FileKVStore``
+ ``FileBackend`` — every queue pop, lease CAS, and result publish
crosses the filesystem substrate, exercising the cross-process plane end
to end.  The file floor is 85: 5× the snapshot-per-op engine's ~17
tasks/s on the reference box, so a regression to O(shard)-per-op costs is
caught at PR time.
"""

from __future__ import annotations

import os
import time


def _make_stores(backend: str, workdir: str = None):
    """Storage pair for a bench: in-memory (default) or the cross-process
    file substrate (FileKVStore + FileBackend over ``workdir``).

    Both file stores run ``fsync="never"`` here — the PR-4 snapshot engine
    never fsynced the KV (its documented stance: the coordination plane is
    reconstructible), so an equal-durability configuration is the only
    apples-to-apples engine comparison; and durability syscalls measure
    the HOST, not the engine (per-file fsync latency spikes to tens of ms
    on network filesystems, and the object store's group commit is an
    ``os.sync()``, whose cost is dominated by whatever else the machine
    has dirty).  What each durability policy itself costs is priced
    separately (and deliberately) by the ``file_substrate`` rows' fsync
    sweep."""
    from repro.storage import FileBackend, FileKVStore, KVStore, ObjectStore

    if backend == "file":
        return (
            ObjectStore(
                backend=FileBackend(os.path.join(workdir, "obj"), fsync="never")
            ),
            FileKVStore(os.path.join(workdir, "kv"), num_shards=2, fsync="never"),
            None,
        )
    if backend == "net":
        # Same host, same engine, same durability — the delta vs. "file" is
        # purely wire round-trips vs. shared-disk flock/stat transactions.
        # Same-host transport is a Unix socket, as a deployed single-node
        # repro-kvd would run.
        from repro.storage import NetBackend, NetKVStore
        from repro.storage.net_server import KVDServer

        server = KVDServer(
            os.path.join(workdir, "kvd"),
            f"unix:{os.path.join(workdir, 'kvd.sock')}",
            num_shards=2,
            fsync="never",
        ).start()
        kv = NetKVStore(server.address)
        store = ObjectStore(backend=NetBackend(server.address))

        def cleanup():
            kv.close()
            store.backend.close()
            server.close()

        return store, kv, cleanup
    return ObjectStore(), KVStore(num_shards=2), None


_BACKEND_SUFFIX = {"memory": "", "file": "_file", "net": "_net"}


def _throughput(rep, num_workers: int, n_tasks: int, backend: str = "memory") -> None:
    import tempfile

    from repro.core import WrenExecutor, get_all

    with tempfile.TemporaryDirectory() as workdir:
        store, kv, cleanup = _make_stores(backend, workdir)
        suffix = _BACKEND_SUFFIX[backend]
        try:
            with WrenExecutor(store=store, kv=kv, num_workers=num_workers) as wex:
                wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
                t0 = time.perf_counter()
                futs = wex.map(lambda x: x, list(range(n_tasks)))
                get_all(futs, timeout_s=120)
                dt = time.perf_counter() - t0
                rep.row(
                    f"runtime/map_throughput{suffix}_w{num_workers}",
                    dt / n_tasks * 1e6,
                    tasks_per_s=round(n_tasks / dt, 1),
                    tasks=n_tasks,
                    wall_s=round(dt, 3),
                )
        finally:
            if cleanup is not None:
                cleanup()


def _job_completion(rep, num_workers: int, n_tasks: int, reps: int = 3) -> None:
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=num_workers) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            get_all(wex.map(lambda x: x + 1, list(range(n_tasks))), timeout_s=120)
            walls.append(time.perf_counter() - t0)
        best = min(walls)
        rep.row(
            f"runtime/job_completion_w{num_workers}",
            best * 1e6,
            tasks=n_tasks,
            wall_s=round(best, 4),
        )


def _speculation(rep, cfg_kwargs: dict, row_name: str, n_tasks: int) -> None:
    """One straggler worker (heavy injected slowdown) against a map, under
    the given speculation config.  Reports wall time and how many
    duplicates were enqueued — the tuning curve for both the legacy
    ``factor × median`` rule and the quantile-adaptive ``k × q`` rule."""
    from repro.core import FaultPlan, SchedulerConfig, WrenExecutor, get_all

    cfg = SchedulerConfig(
        lease_timeout_s=5.0,
        min_completed_for_speculation=3,
        # The sweep tunes the *rule*: drop the straggler-age floor so the
        # rule (over a no-op distribution) is what decides, not the clamp.
        min_speculation_age_s=0.005,
        **cfg_kwargs,
    )
    fp = FaultPlan(slowdown={"w0000": 400.0})
    wex = WrenExecutor(num_workers=4, scheduler_config=cfg, fault_plan=fp, seed=0)
    try:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm (cold start excluded)
        t0 = time.perf_counter()
        get_all(wex.map(lambda x: x, list(range(n_tasks))), timeout_s=120)
        dt = time.perf_counter() - t0
        rep.row(
            row_name,
            dt * 1e6,
            wall_s=round(dt, 4),
            duplicates=len(wex.scheduler._speculated),
            tasks=n_tasks,
        )
    finally:
        wex.shutdown()


def _multi_driver(rep, total_workers: int, n_tasks: int) -> None:
    """Throughput of one map through 1 driver vs. 2 stateless scheduler
    handles sharing the KV (same total worker count): the two-driver row's
    ``overhead_pct`` is the cost of the fenced, shared control plane —
    epoch CAS traffic plus a second reap/speculate loop."""
    from repro.core import WrenExecutor, get_all
    from repro.storage import KVStore, ObjectStore

    walls = {}
    for n_drivers in (1, 2):
        store = ObjectStore()
        kv = KVStore(num_shards=2)
        per = total_workers // n_drivers
        drivers = [
            WrenExecutor(store=store, kv=kv, num_workers=per, seed=i)
            for i in range(n_drivers)
        ]
        try:
            for d in drivers:
                d.map_get(lambda x: x, [0], timeout_s=60)  # warm all pools
            t0 = time.perf_counter()
            futs = drivers[0].map(lambda x: x, list(range(n_tasks)))
            get_all(futs, timeout_s=120)
            dt = time.perf_counter() - t0
        finally:
            for d in drivers:
                d.shutdown()
        walls[n_drivers] = dt
        extra = {}
        if n_drivers > 1:
            extra["overhead_pct"] = round((dt / walls[1] - 1.0) * 100.0, 1)
        rep.row(
            f"runtime/multi_driver_d{n_drivers}_w{per}",
            dt / n_tasks * 1e6,
            tasks_per_s=round(n_tasks / dt, 1),
            tasks=n_tasks,
            wall_s=round(dt, 3),
            **extra,
        )


def _adoption_latency(rep, lease_timeout_s: float = 0.5) -> None:
    """Kill-to-resume wall time for driver failover (core/jobs.py +
    bsp.adopt_job): driver A's heartbeats stop the instant the map barrier
    commits (a simulated SIGKILL — the lease is left live, exactly as a
    dead process leaves it), and the clock runs from that instant until
    driver B has detected the lapse, fenced the takeover at term + 1, and
    replayed the manifest to the merged result."""
    from repro.core import SchedulerConfig, WrenExecutor, adopt_job
    from repro.core import bsp
    from repro.storage import KVStore, ObjectStore

    class _Killed(Exception):
        pass

    store = ObjectStore()
    kv = KVStore(num_shards=2)
    cfg = SchedulerConfig(driver_lease_timeout_s=lease_timeout_s)
    wex_a = WrenExecutor(store=store, kv=kv, num_workers=2, scheduler_config=cfg, seed=0)
    wex_b = WrenExecutor(store=store, kv=kv, num_workers=2, scheduler_config=cfg, seed=1)
    killed_at = {}
    orig_barrier = bsp._stage_barrier

    def dying_barrier(wex, job, idx, plan, outputs, **kw):
        out = orig_barrier(wex, job, idx, plan, outputs, **kw)
        if idx == 0:
            # Simulated SIGKILL: stop heartbeating but leave the lease live
            # (popping the registry also turns the error-path release into a
            # no-op, so B must wait out the expiry like a real crash).
            with wex._driver_mu:
                wex._driver_jobs.pop(job, None)
            killed_at["t"] = time.perf_counter()
            raise _Killed()
        return out

    bsp._stage_barrier = dying_barrier
    try:
        try:
            bsp.mapreduce(
                wex_a,
                lambda part: [(x % 4, x) for x in part],
                lambda _k, vs: sum(vs),
                [list(range(10)), list(range(10, 20))],
                4,
                job_id="adopt-bench",
            )
        except _Killed:
            pass
        bsp._stage_barrier = orig_barrier
        out = adopt_job(wex_b, "adopt-bench", wait_timeout_s=60.0, timeout_s=60.0)
        dt = time.perf_counter() - killed_at["t"]
        assert out == {k: sum(x for x in range(20) if x % 4 == k) for k in range(4)}
        rep.row(
            "runtime/adoption_latency",
            dt * 1e6,
            adoption_latency_ms=round(dt * 1e3, 1),
            lease_timeout_ms=round(lease_timeout_s * 1e3, 1),
        )
    finally:
        bsp._stage_barrier = orig_barrier
        wex_a.shutdown()
        wex_b.shutdown()


def _shuffle_requests_for(rep, store_kind: str, n_maps: int, n_parts: int) -> None:
    """Count modeled storage requests for one shuffle on the batched write
    plane vs. the looped write path (one request per intermediate object —
    the pre-``put_many`` behavior).  One ledger record == one modeled
    request, so the counts are exact, not timed."""
    from repro.storage import KVStore, ObjectStore
    from repro.storage import shuffle as shf

    def fresh():
        return KVStore(num_shards=2) if store_kind == "kv" else ObjectStore()

    def requests_since(store, mark: int) -> int:
        return len(store.ledger.records()) - mark

    parts = [[(p, i) for i in range(4)] for p in range(n_parts)]

    # --- batched plane: write_partitions / read_partition_column / GC ----
    store = fresh()
    mark = len(store.ledger.records())
    for m in range(n_maps):
        shf.write_partitions(store, "bench", m, parts, worker=f"m{m}")
    write_reqs = requests_since(store, mark)
    mark = len(store.ledger.records())
    for p in range(n_parts):
        shf.read_partition_column(store, "bench", n_maps, p, worker=f"r{p}")
    read_reqs = requests_since(store, mark)
    mark = len(store.ledger.records())
    shf.delete_intermediates(store, "bench", n_maps, n_parts, worker="driver")
    gc_reqs = requests_since(store, mark)

    # --- looped write path (PR 2 and earlier): one request per object ----
    legacy = fresh()
    mark = len(legacy.ledger.records())
    for m in range(n_maps):
        for p, part in enumerate(parts):
            key = shf.intermediate_key("bench", m, p)
            if isinstance(legacy, KVStore):
                legacy.set(key, list(part), worker=f"m{m}")
            else:
                legacy.put(key, list(part), worker=f"m{m}")
    legacy_write_reqs = requests_since(legacy, mark)

    write_ratio = legacy_write_reqs / max(write_reqs, 1)
    rep.row(
        f"runtime/shuffle_requests_{store_kind}",
        float(write_reqs + read_reqs + gc_reqs),
        n_maps=n_maps,
        n_parts=n_parts,
        write_requests=write_reqs,
        legacy_write_requests=legacy_write_reqs,
        # raw, not rounded: the CI floor gates on this value, and rounding
        # 1.95 up to 2.0 would let a breached floor pass silently
        write_ratio=write_ratio,
        read_requests=read_reqs,
        gc_requests=gc_reqs,
        stage_requests=write_reqs + read_reqs + gc_reqs,
        legacy_stage_requests=legacy_write_reqs + read_reqs,
    )


def shuffle_requests(rep, quick: bool = False) -> None:
    # Partition width stays at 8 even in quick mode: the batched write path
    # pays a fixed GC-tombstone existence check per map task, so narrow
    # fan-outs would sit right on the 2x CI floor instead of clearing it.
    n_maps, n_parts = (4, 8) if quick else (8, 8)
    for store_kind in ("obj", "kv"):
        _shuffle_requests_for(rep, store_kind, n_maps, n_parts)


def map_throughput(rep, quick: bool = False) -> None:
    plan = [(4, 200)] if quick else [(4, 400), (16, 400)]
    for num_workers, n_tasks in plan:
        _throughput(rep, num_workers, n_tasks)


def map_throughput_file(rep, quick: bool = False) -> None:
    """Map throughput over the cross-process substrate (FileKVStore +
    FileBackend): every control-plane op is a flock'd log transaction and
    every result publish a file commit, so this is the floor-gated canary
    for both event loss in the watcher plane (a missed wake turns into
    timeout waits) and a regression to snapshot-per-op storage costs —
    either collapses tasks/s."""
    plan = [(4, 64)] if quick else [(4, 128)]
    for num_workers, n_tasks in plan:
        _throughput(rep, num_workers, n_tasks, backend="file")


def map_throughput_net(rep, quick: bool = False) -> None:
    """The three-column backend comparison the wire tier is judged by:
    the same 4-worker map over in-memory stores, the shared-disk file
    substrate, and a live ``repro-kvd`` server on loopback.  All three
    run ``fsync="never"`` (see ``_make_stores``), so the file→net delta
    isolates what the wire tier actually changes — per-op flock + stat
    transactions against shared disk vs. pipelined round-trips to a
    process answering from materialized state.  The CI floor gates the
    ``_net`` row; the acceptance bar is net beating file on this host."""
    plan = [(4, 64)] if quick else [(4, 128)]
    for num_workers, n_tasks in plan:
        _throughput(rep, num_workers, n_tasks, backend="memory")
        _throughput(rep, num_workers, n_tasks, backend="file")
        _throughput(rep, num_workers, n_tasks, backend="net")


def _spawn_kvd(root: str, port: int):
    """A real ``repro-kvd`` subprocess (the deployment entry point), so
    multi-daemon rows measure genuine process parallelism, not threads
    sharing one interpreter."""
    import subprocess
    import sys

    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.storage.net_server",
            "--root", root, "--port", str(port),
            "--num-shards", "2", "--fsync", "never",
        ],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env,
    )
    line = proc.stdout.readline().strip()
    assert line.startswith("LISTENING"), f"kvd failed to start: {line!r}"
    return proc


def net_bandwidth(rep, quick: bool = False) -> None:
    """Wire-tier bandwidth over the object plane: batched ``put_many_bytes``
    + ``get_many_bytes`` of fixed-size blobs against live ``repro-kvd``
    subprocesses, swept over payload size (64 KiB / 1 MiB / 8 MiB), frame
    mode (``zerocopy`` — raw buffer frames, vs ``pickled`` —
    ``zero_copy=False``, every byte through pickle), and shard-map width
    (1 vs 4 daemons).  The zerocopy÷pickled gap prices what the PR-9
    buffer frames buy on each payload size; the d4 rows carry
    ``speedup_vs_d1`` — the scatter (``start_call`` to every daemon, then
    gather) against one daemon, which is the multi-daemon scale-out
    acceptance number on a multi-core host (daemon processing — CRC,
    decode, disk — overlaps across processes; on a single-core box the
    daemons share the one CPU and the ratio pins near 1, so the scale-out
    claim is read from multi-core runs, never gated blind in CI).  The CI
    floor (``--floor-net-mbps``) gates the best
    zerocopy aggregate MB/s: a copy sneaking back into the large-payload
    path collapses it."""
    import socket
    import tempfile

    from repro.storage import NetBackend, ObjectStore

    # Object counts stay >= 2x the daemon count so the 4-daemon scatter has
    # keys to spread (2 objects over 4 daemons caps the speedup at 2x by
    # construction, daemon count notwithstanding).
    sizes = [("64KiB", 64 * 1024, 32), ("1MiB", 1 << 20, 16), ("8MiB", 8 << 20, 8)]
    if quick:
        sizes = [("64KiB", 64 * 1024, 16), ("1MiB", 1 << 20, 8), ("8MiB", 8 << 20, 4)]
    base_mbps = {}  # (label, mode) -> d1 aggregate MB/s
    for n_daemons in (1, 4):
        with tempfile.TemporaryDirectory() as workdir:
            procs, addrs = [], []
            for d in range(n_daemons):
                s = socket.socket()
                s.bind(("127.0.0.1", 0))
                port = s.getsockname()[1]
                s.close()
                procs.append(_spawn_kvd(os.path.join(workdir, f"d{d}"), port))
                addrs.append(f"127.0.0.1:{port}")
            shard_map = ",".join(addrs)
            try:
                for mode, zc in (("zerocopy", True), ("pickled", False)):
                    backend = NetBackend(shard_map, zero_copy=zc)
                    store = ObjectStore(backend=backend)
                    try:
                        for label, size, nkeys in sizes:
                            blobs = {
                                f"bw/{mode}/{label}/{i}": bytes(size)
                                for i in range(nkeys)
                            }
                            store.put_bytes(f"bw/{mode}/{label}/warm", b"x")
                            t0 = time.perf_counter()
                            store.put_many_bytes(blobs, worker="bench")
                            t_put = time.perf_counter() - t0
                            t0 = time.perf_counter()
                            out = store.get_many_bytes(list(blobs), worker="bench")
                            t_get = time.perf_counter() - t0
                            assert all(len(v) == size for v in out.values())
                            mb = size * nkeys / 1e6
                            agg = 2 * mb / (t_put + t_get)
                            extra = {}
                            if n_daemons == 1:
                                base_mbps[(label, mode)] = agg
                            else:
                                extra["speedup_vs_d1"] = round(
                                    agg / base_mbps[(label, mode)], 2
                                )
                            rep.row(
                                f"storage/net_bandwidth_{label}_{mode}"
                                f"_d{n_daemons}",
                                (t_put + t_get) / (2 * nkeys) * 1e6,
                                put_MBps=round(mb / t_put, 1),
                                get_MBps=round(mb / t_get, 1),
                                agg_MBps=round(agg, 1),
                                payload_bytes=size,
                                n_objects=nkeys,
                                daemons=n_daemons,
                                mode=mode,
                                **extra,
                            )
                    finally:
                        backend.close()
            finally:
                for p in procs:
                    p.terminate()
                    p.wait()


def _file_substrate_ops(kv, n_ops: int) -> None:
    """A representative KV op mix: batched staging (mset), queue churn
    (rpush/lpop), counters, and point reads — the shapes the runtime's
    control and data planes actually issue."""
    for i in range(n_ops // 8):
        kv.mset({f"stage/a{i}": i, f"stage/b{i}": [i] * 8}, worker="bench")
        kv.rpush("queue", {"task": i, "payload": "x" * 64}, worker="bench")
        kv.incr(f"ctr/{i % 7}", worker="bench")
        kv.lpop("queue", worker="bench")
        kv.get(f"stage/a{i}", worker="bench")
        kv.eval(f"ev/{i % 5}", lambda v: (v or 0) + 1, worker="bench")
        kv.rpush_many({f"q/{i % 3}": [i], f"q/{(i + 1) % 3}": [i]}, worker="bench")
        kv.mget([f"stage/a{i}", f"stage/b{i}"], worker="bench")


def file_substrate(rep, quick: bool = False) -> None:
    """Price the two file-KV engines against each other under the
    durability-policy sweep: ``engine="log"`` (PR 5, append-only per-shard
    logs + compaction) vs ``engine="snapshot"`` (PR 4, whole-shard pickle
    per transaction), each under at least two fsync policies.  The log
    engine's win is structural — O(record) appends vs O(shard) rewrites —
    while the fsync column isolates what durability itself costs on this
    host (on network filesystems per-commit fsync dominates everything
    else, which is why control keys default to it and data keys don't)."""
    import tempfile

    from repro.storage import FileKVStore

    n_ops = 400 if quick else 1600
    # Resident state sized like a real job's control plane (hundreds of
    # lease-record-shaped values): the snapshot engine rewrites ALL of it
    # on every op, the log engine appends one record — this is exactly the
    # O(shard) vs O(record) gap the rows exist to show.
    resident = 400 if quick else 1000
    policies = ("batch", "commit") if quick else ("batch", "commit", "never")
    for engine in ("log", "snapshot"):
        for policy in policies:
            with tempfile.TemporaryDirectory() as workdir:
                kv = FileKVStore(
                    os.path.join(workdir, "kv"), num_shards=2,
                    engine=engine, fsync=policy,
                )
                try:
                    kv.mset(
                        {
                            f"lease/{i}": {
                                "worker": f"w{i % 16:04d}", "epoch": i,
                                "expires": float(i), "started": float(i),
                                "attempt": 0, "spec": list(range(16)),
                            }
                            for i in range(resident)
                        },
                        worker="bench",
                    )
                    _file_substrate_ops(kv, 64)  # warm (files created)
                    mark = kv.disk_bytes_written()
                    t0 = time.perf_counter()
                    _file_substrate_ops(kv, n_ops)
                    dt = time.perf_counter() - t0
                    disk_bytes = kv.disk_bytes_written() - mark
                finally:
                    kv.close()
            rep.row(
                f"storage/file_substrate_{engine}_fsync-{policy}",
                dt / n_ops * 1e6,
                ops_per_s=round(n_ops / dt, 1),
                engine=engine,
                fsync=policy,
                ops=n_ops,
                resident_keys=resident,
                # Deterministic structural metric: bytes the engine had to
                # write for the same op mix (snapshot engine: O(shard) per
                # commit; log engine: O(record) + occasional compaction).
                disk_bytes_per_op=round(disk_bytes / n_ops, 1),
            )


def job_completion(rep, quick: bool = False) -> None:
    _job_completion(rep, 8, 32, reps=1 if quick else 3)


def speculation_sweep(rep, quick: bool = False) -> None:
    # Legacy static rule (factor × median) …
    factors = [3.0] if quick else [1.5, 3.0, 6.0]
    for f in factors:
        _speculation(
            rep, {"speculation_factor": f}, f"runtime/speculation_f{f:g}", n_tasks=24
        )
    # … vs. the quantile-adaptive rule (k × q over the job's distribution).
    qk = [(0.95, 1.5)] if quick else [(0.9, 1.0), (0.95, 1.5), (0.99, 3.0)]
    for q, k in qk:
        _speculation(
            rep,
            {"speculation_quantile": q, "speculation_k": k},
            f"runtime/speculation_q{q:g}_k{k:g}",
            n_tasks=24,
        )


def multi_driver(rep, quick: bool = False) -> None:
    _multi_driver(rep, total_workers=4, n_tasks=100 if quick else 200)
    _adoption_latency(rep)


ALL = [map_throughput, job_completion, speculation_sweep, multi_driver, shuffle_requests]
FILE_BACKEND_BENCHES = [map_throughput_file, file_substrate]
NET_BACKEND_BENCHES = [map_throughput_net, net_bandwidth]


def main(argv=None) -> int:
    import argparse
    import json

    from .common import Reporter

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small CI budget")
    ap.add_argument("--json", metavar="PATH", help="write rows as JSON")
    ap.add_argument(
        "--backend",
        choices=["memory", "file", "net"],
        default="memory",
        help="'file' runs the map benches over FileKVStore+FileBackend "
        "(the cross-process substrate) instead of the in-memory stores; "
        "'net' runs the three-column memory/file/net map comparison "
        "against a live repro-kvd server on loopback",
    )
    ap.add_argument(
        "--floor-tasks-per-s",
        type=float,
        default=None,
        help="fail (exit 1) if 4-worker map throughput is below this",
    )
    ap.add_argument(
        "--floor-net-mbps",
        type=float,
        default=None,
        help="fail (exit 1) if the best zero-copy net_bandwidth aggregate "
        "MB/s is below this (a copy creeping back into the large-payload "
        "wire path collapses it)",
    )
    ap.add_argument(
        "--floor-shuffle-ratio",
        type=float,
        default=None,
        help="fail (exit 1) if the batched shuffle write plane's "
        "request-count drop vs. the looped path is below this factor",
    )
    args = ap.parse_args(argv)

    rep = Reporter()
    suites = {
        "memory": ALL,
        "file": FILE_BACKEND_BENCHES,
        "net": NET_BACKEND_BENCHES,
    }
    for bench in suites[args.backend]:
        bench(rep, quick=args.quick)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep.rows, f, indent=2)
        print(f"wrote {len(rep.rows)} rows to {args.json}")

    if args.floor_tasks_per_s is not None:
        # Gate the selected backend's OWN column: the net run also emits the
        # memory and file comparison rows, and gating on the max would let a
        # wire-tier regression hide behind the in-memory number.
        gated = f"runtime/map_throughput{_BACKEND_SUFFIX[args.backend]}_w4"
        tput = [r["tasks_per_s"] for r in rep.rows if r["name"] == gated]
        if not tput or max(tput) < args.floor_tasks_per_s:
            print(
                f"FAIL: map throughput {max(tput or [0.0])} tasks/s below "
                f"floor {args.floor_tasks_per_s}"
            )
            return 1
        print(f"throughput floor ok: {max(tput)} >= {args.floor_tasks_per_s} tasks/s")

    if args.floor_net_mbps is not None:
        mbps = [
            r["agg_MBps"]
            for r in rep.rows
            if r["name"].startswith("storage/net_bandwidth_")
            and r["mode"] == "zerocopy"
        ]
        if not mbps or max(mbps) < args.floor_net_mbps:
            print(
                f"FAIL: zero-copy net bandwidth {max(mbps or [0.0])} MB/s "
                f"below floor {args.floor_net_mbps}"
            )
            return 1
        print(f"net bandwidth floor ok: {max(mbps)} >= {args.floor_net_mbps} MB/s")

    if args.floor_shuffle_ratio is not None:
        ratios = [
            r["write_ratio"]
            for r in rep.rows
            if r["name"].startswith("runtime/shuffle_requests_")
        ]
        if not ratios or min(ratios) < args.floor_shuffle_ratio:
            print(
                f"FAIL: shuffle write request ratio {min(ratios or [0.0])}x below "
                f"floor {args.floor_shuffle_ratio}x"
            )
            return 1
        print(
            f"shuffle request floor ok: {min(ratios)}x >= "
            f"{args.floor_shuffle_ratio}x fewer write requests"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
