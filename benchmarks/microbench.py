"""Control-plane microbenchmarks: map throughput, job completion time,
and a speculation-factor sweep against an injected straggler distribution.

Measures what the event-driven dispatch + batched data plane target:
per-task scheduling overhead with no-op user functions, so queue/lease/
notify/multi-get traffic dominates.  Reported rows:

  * ``runtime/map_throughput_w{N}`` — sustained tasks/s for a single map of
    ``n`` no-op tasks on N warm containers (derived: tasks/s, wall s);
  * ``runtime/job_completion_w{N}`` — wall time of a small *job* (submit →
    all futures resolved), the end-to-end latency a driver observes;
  * ``runtime/speculation_f{F}`` — completion wall time of a map with one
    injected straggler worker, across ``speculation_factor`` values: the
    tuning curve for ``SchedulerConfig.speculation_factor`` (low = eager
    duplicates hide stragglers sooner at the cost of wasted work).

Run directly (``python -m benchmarks.microbench``) or via
``python -m benchmarks.run`` which includes these rows in the CSV.

CLI (the CI bench-smoke job uses all three):

  python -m benchmarks.microbench --quick --json bench.json --floor-tasks-per-s 150

``--quick`` shrinks budgets for CI, ``--json`` writes the rows as a JSON
artifact, and ``--floor-tasks-per-s`` exits non-zero if the 4-worker map
throughput regresses below the floor (guarding the batched data plane's
speedup; PR 1 baseline was ~282 tasks/s on 4 warm workers).
"""

from __future__ import annotations

import time


def _throughput(rep, num_workers: int, n_tasks: int) -> None:
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=num_workers) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        t0 = time.perf_counter()
        futs = wex.map(lambda x: x, list(range(n_tasks)))
        get_all(futs, timeout_s=120)
        dt = time.perf_counter() - t0
        rep.row(
            f"runtime/map_throughput_w{num_workers}",
            dt / n_tasks * 1e6,
            tasks_per_s=round(n_tasks / dt, 1),
            tasks=n_tasks,
            wall_s=round(dt, 3),
        )


def _job_completion(rep, num_workers: int, n_tasks: int, reps: int = 3) -> None:
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=num_workers) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            get_all(wex.map(lambda x: x + 1, list(range(n_tasks))), timeout_s=120)
            walls.append(time.perf_counter() - t0)
        best = min(walls)
        rep.row(
            f"runtime/job_completion_w{num_workers}",
            best * 1e6,
            tasks=n_tasks,
            wall_s=round(best, 4),
        )


def _speculation(rep, factor: float, n_tasks: int) -> None:
    """One straggler worker (heavy injected slowdown) against a map; lower
    ``speculation_factor`` duplicates it sooner.  Reports wall time and how
    many duplicates were enqueued."""
    from repro.core import FaultPlan, SchedulerConfig, WrenExecutor, get_all

    cfg = SchedulerConfig(
        lease_timeout_s=5.0,
        speculation_factor=factor,
        min_completed_for_speculation=3,
        # The sweep tunes the *factor*: drop the straggler-age floor so the
        # factor (× a no-op median) is what decides, not the safety clamp.
        min_speculation_age_s=0.005,
    )
    fp = FaultPlan(slowdown={"w0000": 400.0})
    wex = WrenExecutor(num_workers=4, scheduler_config=cfg, fault_plan=fp, seed=0)
    try:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm (cold start excluded)
        t0 = time.perf_counter()
        get_all(wex.map(lambda x: x, list(range(n_tasks))), timeout_s=120)
        dt = time.perf_counter() - t0
        rep.row(
            f"runtime/speculation_f{factor:g}",
            dt * 1e6,
            wall_s=round(dt, 4),
            duplicates=len(wex.scheduler._speculated),
            tasks=n_tasks,
        )
    finally:
        wex.shutdown()


def map_throughput(rep, quick: bool = False) -> None:
    plan = [(4, 200)] if quick else [(4, 400), (16, 400)]
    for num_workers, n_tasks in plan:
        _throughput(rep, num_workers, n_tasks)


def job_completion(rep, quick: bool = False) -> None:
    _job_completion(rep, 8, 32, reps=1 if quick else 3)


def speculation_sweep(rep, quick: bool = False) -> None:
    factors = [3.0] if quick else [1.5, 3.0, 6.0]
    for f in factors:
        _speculation(rep, f, n_tasks=24)


ALL = [map_throughput, job_completion, speculation_sweep]


def main(argv=None) -> int:
    import argparse
    import json

    from .common import Reporter

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small CI budget")
    ap.add_argument("--json", metavar="PATH", help="write rows as JSON")
    ap.add_argument(
        "--floor-tasks-per-s",
        type=float,
        default=None,
        help="fail (exit 1) if 4-worker map throughput is below this",
    )
    args = ap.parse_args(argv)

    rep = Reporter()
    for bench in ALL:
        bench(rep, quick=args.quick)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep.rows, f, indent=2)
        print(f"wrote {len(rep.rows)} rows to {args.json}")

    if args.floor_tasks_per_s is not None:
        tput = [
            r["tasks_per_s"]
            for r in rep.rows
            if r["name"] == "runtime/map_throughput_w4"
        ]
        if not tput or max(tput) < args.floor_tasks_per_s:
            print(
                f"FAIL: map throughput {max(tput or [0.0])} tasks/s below "
                f"floor {args.floor_tasks_per_s}"
            )
            return 1
        print(f"throughput floor ok: {max(tput)} >= {args.floor_tasks_per_s} tasks/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
