"""Control-plane microbenchmarks: map throughput + job completion time.

Measures what the event-driven dispatch rework targets: per-task scheduling
overhead with no-op user functions, so queue/lease/notify traffic dominates.
Reported rows:

  * ``runtime/map_throughput_w{N}`` — sustained tasks/s for a single map of
    ``n`` no-op tasks on N warm containers (derived: tasks/s, wall s);
  * ``runtime/job_completion_w{N}`` — wall time of a small *job* (submit →
    all futures resolved), the end-to-end latency a driver observes.

Run directly (``python -m benchmarks.microbench``) or via
``python -m benchmarks.run`` which includes these rows in the CSV.
"""

from __future__ import annotations

import time


def _throughput(rep, num_workers: int, n_tasks: int) -> None:
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=num_workers) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        t0 = time.perf_counter()
        futs = wex.map(lambda x: x, list(range(n_tasks)))
        get_all(futs, timeout_s=120)
        dt = time.perf_counter() - t0
        rep.row(
            f"runtime/map_throughput_w{num_workers}",
            dt / n_tasks * 1e6,
            tasks_per_s=round(n_tasks / dt, 1),
            tasks=n_tasks,
            wall_s=round(dt, 3),
        )


def _job_completion(rep, num_workers: int, n_tasks: int) -> None:
    from repro.core import WrenExecutor, get_all

    with WrenExecutor(num_workers=num_workers) as wex:
        wex.map_get(lambda x: x, [0], timeout_s=60)  # warm containers
        walls = []
        for _ in range(3):
            t0 = time.perf_counter()
            get_all(wex.map(lambda x: x + 1, list(range(n_tasks))), timeout_s=120)
            walls.append(time.perf_counter() - t0)
        best = min(walls)
        rep.row(
            f"runtime/job_completion_w{num_workers}",
            best * 1e6,
            tasks=n_tasks,
            wall_s=round(best, 4),
        )


def map_throughput(rep) -> None:
    for num_workers, n_tasks in [(4, 400), (16, 400)]:
        _throughput(rep, num_workers, n_tasks)


def job_completion(rep) -> None:
    _job_completion(rep, 8, 32)


ALL = [map_throughput, job_completion]


if __name__ == "__main__":
    from .common import Reporter

    rep = Reporter()
    for bench in ALL:
        bench(rep)
