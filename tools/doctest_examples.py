"""Doctest the module docstrings (file headers) of example scripts.

The examples are runnable programs, some of which import JAX and spin up
real worker pools — importing them just to doctest their headers would be
slow and side-effectful.  So this tool parses each file with ``ast``,
extracts ONLY the module docstring, and runs doctest over it with a clean
namespace (each docstring must import what it uses, exactly what a reader
pasting the snippet would do).

CI runs this over ``examples/*.py`` (docs job): a renamed API or a stale
snippet in an example header fails the build instead of rotting.

Usage:  PYTHONPATH=src python tools/doctest_examples.py examples/*.py
"""

from __future__ import annotations

import ast
import doctest
import sys


def run_file(path: str) -> tuple:
    """(attempted, failed) doctest examples in ``path``'s module docstring."""
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    doc = ast.get_docstring(ast.parse(source))
    if not doc:
        return 0, 0
    parser = doctest.DocTestParser()
    test = parser.get_doctest(doc, {}, name=path, filename=path, lineno=0)
    if not test.examples:
        return 0, 0
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    runner.run(test)
    return runner.tries, runner.failures


def main(paths) -> int:
    total = failed = files_with_tests = 0
    for path in paths:
        tries, fails = run_file(path)
        if tries:
            files_with_tests += 1
            status = "FAIL" if fails else "ok"
            print(f"{status:4s} {path}: {tries} examples, {fails} failures")
        total += tries
        failed += fails
    print(
        f"doctested {files_with_tests} example headers: "
        f"{total} examples, {failed} failures"
    )
    if failed:
        return 1
    if not total:
        print("error: no doctest examples found in any header", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
