#!/usr/bin/env python
"""reprolint CLI: run the control-plane invariant lint over the tree.

Usage:
    python tools/reprolint.py [PATHS...] [--strict] \
        [--baseline tools/reprolint_baseline.json] [--update-baseline]

Exit codes:
    0  clean (no active findings; disable counts within baseline)
    1  active findings, or the per-rule disable count grew past the
       baseline (new `# reprolint: disable=` waivers need a conscious
       baseline update, not a silent merge)

With no PATHS, lints ``src/repro`` relative to the repo root.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))

from repro.analysis import lint  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("paths", nargs="*", help="files or trees to lint")
    parser.add_argument(
        "--strict", action="store_true",
        help="exit nonzero on any active (non-disabled) finding",
    )
    parser.add_argument(
        "--baseline", default=None,
        help="JSON file holding the allowed per-rule disable counts",
    )
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline file from the current tree",
    )
    parser.add_argument(
        "--quiet", action="store_true", help="suppress per-finding output"
    )
    args = parser.parse_args(argv)

    paths = args.paths or [os.path.join(_REPO_ROOT, "src", "repro")]
    findings = []
    for p in paths:
        findings.extend(lint.lint_tree(p))

    bad = lint.active(findings)
    waived = [f for f in findings if f.disabled]
    if not args.quiet:
        for f in bad:
            print(f.format())
            print(f"    fix-it: {f.fixit}")

    failed = False
    if bad:
        print(f"reprolint: {len(bad)} active finding(s) "
              f"({len(waived)} waived by disable comments)")
        if args.strict:
            failed = True
    elif not args.quiet:
        print(f"reprolint: clean ({len(waived)} waived by disable comments)")

    counts = lint.disabled_counts(findings)
    if args.baseline:
        if args.update_baseline:
            with open(args.baseline, "w", encoding="utf-8") as fh:
                json.dump({"disabled_findings": counts}, fh, indent=2, sort_keys=True)
                fh.write("\n")
            print(f"reprolint: baseline updated -> {args.baseline}")
        else:
            try:
                with open(args.baseline, "r", encoding="utf-8") as fh:
                    allowed = json.load(fh).get("disabled_findings", {})
            except FileNotFoundError:
                print(f"reprolint: baseline file {args.baseline} missing "
                      f"(run with --update-baseline to create it)")
                return 1
            for rule, n in sorted(counts.items()):
                cap = int(allowed.get(rule, 0))
                if n > cap:
                    print(
                        f"reprolint: {rule} disable count grew: {n} > "
                        f"baseline {cap} — remove the new waiver or update "
                        f"{args.baseline} deliberately"
                    )
                    failed = True

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
