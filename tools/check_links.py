"""Internal-link checker for the docs suite (CI `docs` job).

Usage:
    python tools/check_links.py README.md docs [more files or dirs ...]

For every markdown file given (directories are walked for ``*.md``), each
inline link or image ``[text](target)`` is checked:

  * external targets (``http://``, ``https://``, ``mailto:``) are skipped —
    CI must not depend on the network;
  * relative targets must exist on disk, resolved against the file's
    directory;
  * ``path#anchor`` / ``#anchor`` targets must also name a real heading in
    the target file, using GitHub's slug rules (lowercase, spaces to
    hyphens, punctuation dropped).

Exit status: 0 when every link resolves, 1 when any is broken (never the
raw count — POSIX truncates exit codes modulo 256, so 256 broken links
would otherwise read as success), 2 on usage error.
"""

from __future__ import annotations

import os
import re
import sys
from typing import Iterator, List, Tuple

# Inline links/images: [text](target) — target may carry a #fragment.
# Nested brackets in text (e.g. badges) are not needed for this repo.
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
_EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code, lowercase, drop
    punctuation, spaces become hyphens."""
    text = re.sub(r"[`*_~]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linkified headings
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def md_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for name in sorted(names):
                    if name.endswith(".md"):
                        yield os.path.join(root, name)
        else:
            yield p


def heading_slugs(md_path: str) -> set:
    slugs = set()
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for line in f:
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING_RE.match(line)
            if m:
                slugs.add(github_slug(m.group(1)))
    return slugs


def iter_links(md_path: str) -> Iterator[Tuple[int, str]]:
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK_RE.finditer(line):
                yield lineno, m.group(1)


def check_file(md_path: str) -> List[str]:
    errors = []
    base = os.path.dirname(os.path.abspath(md_path))
    for lineno, target in iter_links(md_path):
        if target.startswith(_EXTERNAL):
            continue
        path, _, anchor = target.partition("#")
        dest = md_path if not path else os.path.normpath(os.path.join(base, path))
        if path and not os.path.exists(dest):
            errors.append(f"{md_path}:{lineno}: broken link -> {target}")
            continue
        if anchor and dest.endswith(".md"):
            if github_slug(anchor) not in heading_slugs(dest):
                errors.append(f"{md_path}:{lineno}: missing anchor -> {target}")
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    errors: List[str] = []
    checked = 0
    for md_path in md_files(argv):
        checked += 1
        errors.extend(check_file(md_path))
    for e in errors:
        print(e)
    print(f"checked {checked} file(s): {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
