"""Serving example: batched inference with the storage-mediated request
plane (clients and engines only share the object store, PyWren-style).

Run:  PYTHONPATH=src python examples/serve_llm.py
"""

import time

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import Engine, ServeConfig, serve_pending, submit_request
from repro.storage import ObjectStore


def main() -> None:
    cfg = CONFIGS["qwen3-32b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=96, max_new_tokens=16))
    store = ObjectStore()

    # clients drop requests into storage
    rng = np.random.default_rng(0)
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        submit_request(store, f"req-{i:03d}", prompt)
    print(f"submitted {len(store.list('serve/req/'))} requests")

    # the engine leases batches and publishes results atomically; run it
    # twice to show idempotency (second pass finds nothing new to do)
    t0 = time.perf_counter()
    served = 0
    while True:
        n = serve_pending(store, engine, batch_size=4)
        if n == 0:
            break
        served += n
        print(f"served batch of {n} ({time.perf_counter() - t0:.2f}s)")
    done = store.list("serve/done/")
    print(f"total served: {served}; results in storage: {len(done)}")
    sample = store.get(done[0])
    print(f"example continuation: {sample['tokens'][:8]}...")


if __name__ == "__main__":
    main()
