"""Serving: two continuous-batching engines, one SIGKILLed mid-stream.

PR 10 rebuilt serving around a lease-driven request plane: clients
``rpush`` request ids onto ``serve/q/*`` and engines lease them with an
atomic compare-and-take, so any number of engine workers can share one
queue without double-serving.  The whole crash story is the lease
lifecycle — submit, take, fence, reap, re-take — and it runs on a plain
KV, no model required:

>>> import time
>>> from repro.serve import request_plane as rp
>>> from repro.storage import KVStore, ObjectStore
>>> kv, store = KVStore(num_shards=1), ObjectStore()
>>> rp.submit(store, kv, "r1", [1, 2, 3])           # body first, then id
'serve/done/r1'
>>> [r for r, body in rp.lease_requests(store, kv, "e-A", 4)]
['r1']
>>> rp.lease_requests(store, kv, "e-B", 4)          # live lease: e-B waits
[]
>>> rp.reap_expired(store, kv, now=time.time() + 99)   # e-A dies; lapse reaped
1
>>> [r for r, body in rp.lease_requests(store, kv, "e-B", 4)]  # re-served
['r1']
>>> kv.get(rp.lease_key("r1"))["term"]   # fenced takeover: term strictly grows
2

Re-serving is *safe* because generation is deterministic per request: the
sampling key is derived from the request id (``rp.request_seed``), so e-B
reproduces byte-identical tokens and the first-writer-wins result publish
makes the duplicate a no-op.

Below, the real thing: two ``repro.launch.serve`` engine subprocesses
over shared ``FileKVStore``/``FileBackend`` directories, a client that
watches tokens stream in *before* completion, and a SIGKILL landing on
engine A while its slots are mid-decode.  Engine B reaps A's lapsed
leases and finishes the job: every request completes exactly once.

Run:  PYTHONPATH=src python examples/serve_llm.py
"""

import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
N_REQ = 8


def _spawn_engine(kv_root: str, obj_root: str, engine_id: str) -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH=_SRC)
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", "qwen3-32b", "--reduced",
            "--kv-root", kv_root, "--obj-root", obj_root,
            "--engine-id", engine_id,
            "--new-tokens", "24", "--decode-chunk", "1",
            "--lease-timeout", "1.0", "--idle-timeout", "8",
        ],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env,
    )
    line = proc.stdout.readline().strip()
    assert line.startswith("READY"), f"engine failed to start: {line!r}"
    return proc


def main() -> None:
    from repro.serve import request_plane as rp
    from repro.storage import FileBackend, FileKVStore, ObjectStore

    with tempfile.TemporaryDirectory() as root:
        kv_root = os.path.join(root, "kv")
        obj_root = os.path.join(root, "obj")
        kv = FileKVStore(kv_root, num_shards=2)
        store = ObjectStore(backend=FileBackend(obj_root))

        victim = _spawn_engine(kv_root, obj_root, "engine-A")
        survivor = _spawn_engine(kv_root, obj_root, "engine-B")
        print("two engines up (separate processes, shared directories)")

        rng = np.random.default_rng(0)
        ids = [f"req-{i:03d}" for i in range(N_REQ)]
        for r in ids:
            rp.submit(store, kv, r, rng.integers(0, 1000, size=6).tolist())
        print(f"submitted {N_REQ} requests")

        # SIGKILL engine A while results are still outstanding — its slots
        # are mid-decode and its leases are live
        while True:
            done = store.exists_many([rp.done_key(r) for r in ids])
            if done:
                break
            time.sleep(0.05)
        victim.kill()
        victim.wait()
        print(f"SIGKILLed engine-A with {N_REQ - len(done)} requests outstanding")

        # tokens stream as rpush chunks: watch a still-pending request
        # arrive in pieces (served by B — possibly a re-serve of one of
        # A's orphaned leases)
        pending = [r for r in ids if rp.done_key(r) not in done]
        chunks = list(rp.stream_result(store, kv, pending[-1], timeout_s=60.0))
        print(
            f"{pending[-1]} streamed in {len(chunks)} chunks "
            f"({sum(len(c) for c in chunks)} tokens) before its done record"
        )

        # engine B reaps A's lapsed leases and re-serves: nothing is lost,
        # first-writer-wins publish means nothing is duplicated
        results = rp.get_results(store, ids, timeout_s=120.0)
        by_engine: dict = {}
        for r in ids:
            by_engine.setdefault(results[r]["engine"], []).append(r)
        served = {e: len(v) for e, v in sorted(by_engine.items())}
        assert len(results) == N_REQ, served
        assert all(results[r]["tokens"] for r in ids)
        print(f"all {N_REQ} requests completed exactly once: {served}")

        survivor.wait(timeout=60)
        kv.close()


if __name__ == "__main__":
    main()
