"""Net cluster: the whole runtime over a live ``repro-kvd`` daemon.

The file substrate shares one machine's disk; this example runs the same
stack over a storage *service* (PR 8) — the shape the paper assumes, where
S3 and Redis are endpoints every Lambda dials into.  ``repro-kvd``
(``repro.storage.net_server``) owns the log-structured shard files
exclusively and serves both planes over one wire protocol;
``NetKVStore``/``NetBackend`` preserve the full behavioural contract
(batched-verb charging, pushed watched-key wakes, the eval replay rule),
so ``WrenExecutor`` cannot tell the difference:

>>> import tempfile
>>> from repro.storage import NetBackend, NetKVStore, ObjectStore
>>> from repro.storage.net_server import KVDServer
>>> tmp = tempfile.mkdtemp()
>>> srv = KVDServer(tmp + "/data", f"unix:{tmp}/kvd.sock",
...                 fsync="never").start()
>>> a = NetKVStore(srv.address)            # two clients, one server —
>>> b = NetKVStore(srv.address)            # e.g. two driver processes
>>> a.rpush("sched/queue", "task-0")
1
>>> b.lpop("sched/queue")                  # one shared queue
'task-0'
>>> a.close(); b.close(); srv.close()

A **shard map** scales the service horizontally (PR 9): N daemons, one
ordered map every client shares — map order *is* the topology (it fixes
the key → daemon hash ring and the global shard numbering).  Keys hash
across the daemons, batched verbs scatter to every involved daemon in
parallel, and one daemon's outage degrades only its own shards:

>>> srv_a = KVDServer(tmp + "/a", f"unix:{tmp}/a.sock", fsync="never").start()
>>> srv_b = KVDServer(tmp + "/b", f"unix:{tmp}/b.sock", fsync="never").start()
>>> kv = NetKVStore([srv_a.address, srv_b.address])  # ORDER IS THE TOPOLOGY
>>> kv.mset({f"k/{i}": i for i in range(64)})        # one scatter, both daemons
>>> sorted({kv._daemon_of(f"k/{i}") for i in range(64)})  # both really own keys
[0, 1]
>>> kv.mget(["k/3", "k/33"])
[3, 33]
>>> kv.close(); srv_a.close(); srv_b.close()

Below, the daemon runs as a real subprocess (the CLI a deployment uses),
two drivers dial in over TCP and cooperate on one mapreduce, and then the
server is SIGKILLed mid-map and restarted: clients reconnect, re-register
their watches on the new server generation, resend in-flight requests,
and the job completes with exact results — the recovery contract
``tests/test_net_kill.py`` pins.

Run:  PYTHONPATH=src python examples/net_cluster.py
"""

import os
import signal
import socket
import subprocess
import sys
import tempfile
import time

from repro.core import WrenExecutor, get_all, word_count
from repro.storage import NetBackend, NetKVStore, ObjectStore

DOCS = [
    "the cloud is just someone else us computer".split(),
    "occupy the cloud distributed computing for the rest of us".split(),
    "the simplicity of a map over stateless functions".split(),
    "storage is the only channel between functions".split(),
] * 4  # 16 map partitions


def spawn_kvd(root: str, port: int) -> subprocess.Popen:
    """The deployment entry point: ``python -m repro.storage.net_server``."""
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.storage.net_server",
            "--root", root, "--port", str(port), "--fsync", "never",
        ],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    banner = proc.stdout.readline().strip()
    assert banner.startswith("LISTENING"), banner
    return proc


def main() -> None:
    with tempfile.TemporaryDirectory() as root:
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
        s.close()
        proc = spawn_kvd(f"{root}/kvd", port)
        addr = f"127.0.0.1:{port}"

        kv = NetKVStore(addr)
        store = ObjectStore(backend=NetBackend(addr))
        driver_a = WrenExecutor(store=store, kv=kv, num_workers=2, seed=1)
        driver_b = WrenExecutor(store=store, kv=kv, num_workers=2, seed=2)
        try:
            # Two drivers, one daemon: B's workers lease tasks of the job
            # only A submitted, exactly as over the shared-disk substrate.
            counts = word_count(driver_a, [[" ".join(d)] for d in DOCS], num_reducers=4)
            top = sorted(counts.items(), key=lambda kv_: -kv_[1])[:3]
            print(f"word count over {len(DOCS)} partitions: top {top}")
            b_done = sum(s.tasks_ok for s in driver_b.pool.stats().values())
            print(f"driver B executed {b_done} tasks of A's job")

            # Kill the daemon mid-map; restart it; the map still completes.
            futs = driver_a.map(lambda x: x * x, list(range(32)))
            time.sleep(0.1)
            proc.send_signal(signal.SIGKILL)
            proc.wait()
            print("SIGKILLed repro-kvd mid-map; restarting on the same root")
            proc = spawn_kvd(f"{root}/kvd", port)
            results = get_all(futs, timeout_s=120)
            assert results == [x * x for x in range(32)]
            print(f"map of 32 tasks survived the restart "
                  f"(client reconnects: {kv._client.reconnects})")
        finally:
            driver_a.shutdown()
            driver_b.shutdown()
            kv.close()
            store.backend.close()
            proc.send_signal(signal.SIGTERM)
            proc.wait(timeout=10)


if __name__ == "__main__":
    main()
