"""Parameter server on the KV store (paper §3.3 'Parameter Servers').

HOGWILD! SGD where the ONLY coordination between stateless workers is the
low-latency KV store: pull blocks, compute a gradient, push deltas via
server-side range updates.  Demonstrates the paper's flexible-consistency
point with a staleness bound, and int8 gradient compression on the wire.

Run:  PYTHONPATH=src python examples/hogwild_ps.py
"""

import numpy as np

from repro.core import ParameterServer, PSConfig, WrenExecutor, hogwild_sgd


def main() -> None:
    rng = np.random.default_rng(0)
    dim, n_shards, n_per = 64, 8, 128
    w_true = rng.normal(size=dim)
    shards = []
    for _ in range(n_shards):
        X = rng.normal(size=(n_per, dim))
        y = X @ w_true + 0.01 * rng.normal(size=n_per)
        shards.append((X, y))

    def grad_fn(w, shard):
        X, y = shard
        return 2.0 * X.T @ (X @ w - y) / len(y)

    for label, cfg in [
        ("hogwild (fully async)", PSConfig(num_blocks=8)),
        ("staleness<=4", PSConfig(num_blocks=8, max_staleness=4)),
        ("hogwild + int8 grads", PSConfig(num_blocks=8, compress_int8=True)),
    ]:
        with WrenExecutor(num_workers=6) as wex:
            ps = ParameterServer(wex.kv, np.zeros(dim), cfg)
            w = hogwild_sgd(
                wex, ps, grad_fn, shards, steps_per_worker=60, lr=0.01
            )
            err = float(np.linalg.norm(w - w_true) / np.linalg.norm(w_true))
            kv_ops = wex.kv.total_ops()
            print(f"{label:24s} rel-err={err:.4f} kv_ops={kv_ops}")


if __name__ == "__main__":
    main()
