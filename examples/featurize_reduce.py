"""Map + monolithic Reduce (paper §3.3, Table 2).

The ImageNet-GIST workflow shape: a wide stateless map featurizes image
shards into S3, then a single 'big machine' fetches the (small) features and
fits a linear classifier with a closed-form solve — 'a single node is
sufficient (and most efficient) for model building'.

Run:  PYTHONPATH=src python examples/featurize_reduce.py
"""

import time

import numpy as np

from repro.core import WrenExecutor, get_all
from repro.storage import ObjectStore, S3_2017


def main() -> None:
    store = ObjectStore(profile=S3_2017)
    rng = np.random.default_rng(0)

    # stage synthetic "images" with a linearly separable structure
    n_shards, per_shard, hw = 12, 32, 24
    w_true = rng.normal(size=(hw * (hw // 2 + 1),))
    for i in range(n_shards):
        imgs = rng.normal(size=(per_shard, hw, hw)).astype(np.float32)
        store.put(f"imgs/{i}", imgs, worker="stage")

    def featurize(i: int) -> str:
        w = f"fw{i}"
        imgs = store.get(f"imgs/{i}", worker=w)
        feats = np.stack([np.abs(np.fft.rfft2(im)).reshape(-1) for im in imgs])
        labels = (feats @ w_true + rng.normal(size=len(feats)) * 0.1 > 0).astype(np.float32)
        store.put(f"feat/{i}", (feats.astype(np.float32), labels), worker=w)
        return f"feat/{i}"

    with WrenExecutor(store=store, num_workers=6) as wex:
        t0 = time.perf_counter()
        futs = wex.map(featurize, list(range(n_shards)))
        keys = get_all(futs, timeout_s=120)
        # per-phase virtual times (Table 2 shape)
        phases = {}
        for f in futs:
            for k, v in f.peek().phases.items():
                phases[k] = phases.get(k, 0.0) + v
        print("map phase (virtual s):",
              {k: round(v / n_shards, 2) for k, v in phases.items()})

    # ---- monolithic reduce ------------------------------------------------
    Xs, ys = [], []
    for k in keys:
        X, y = store.get(k, worker="reduce")
        Xs.append(X)
        ys.append(y)
    X = np.concatenate(Xs)
    y = np.concatenate(ys)
    lam = 1e-1
    w = np.linalg.solve(X.T @ X + lam * np.eye(X.shape[1]), X.T @ (2 * y - 1))
    acc = float((((X @ w) > 0) == y.astype(bool)).mean())
    print(f"featurized {len(X)} images across {n_shards} stateless maps")
    print(f"single-node fit accuracy: {acc:.3f} "
          f"(wall {time.perf_counter() - t0:.2f}s)")


if __name__ == "__main__":
    main()
