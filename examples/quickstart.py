"""Quickstart: the paper's 'cloud button'.

Take existing single-machine code (a plain Python function) and run it at
scale with one call — no cluster, no config.  Mirrors the PyWren README:

>>> from repro.core import WrenExecutor, get_all
>>> with WrenExecutor(num_workers=2) as wex:
...     futures = wex.map(lambda x: x * x, [1, 2, 3])
...     get_all(futures, timeout_s=60)
[1, 4, 9]

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import WrenExecutor, get_all


def my_function(x: float) -> float:
    """Existing, optimized, single-machine code (per §2.1)."""
    rng = np.random.default_rng(int(x))
    m = rng.normal(size=(128, 128))
    return float(np.linalg.eigvalsh(m @ m.T).max() * x)


def main() -> None:
    with WrenExecutor(num_workers=8) as wex:
        # hyperparameter-sweep shape: one stateless function per point
        grid = list(np.linspace(0.1, 2.0, 32))
        futures = wex.map(my_function, grid)
        results = get_all(futures, timeout_s=120)
        best = int(np.argmax(results))
        print(f"swept {len(grid)} points on {wex.pool.alive_count()} workers")
        print(f"best point: x={grid[best]:.3f} -> {results[best]:.2f}")

        # elasticity: scale the pool mid-session, run a second sweep
        wex.scale_to(4)
        more = wex.map_get(my_function, list(np.linspace(2.0, 4.0, 16)))
        print(f"second sweep done on {wex.pool.alive_count()} workers; "
              f"max={max(more):.2f}")

        stats = wex.pool.stats()
        cold = sum(s.cold_starts for s in stats.values())
        ok = sum(s.tasks_ok for s in stats.values())
        print(f"tasks={ok} cold_starts={cold} (containers stay warm, §4)")


if __name__ == "__main__":
    main()
