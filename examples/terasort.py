"""BSP shuffle: the Daytona-sort workload (paper §3.3, Figs 5/6).

Two-stage TeraSort over stateless functions with Redis-class intermediate
storage; sweeps Redis shard counts to reproduce the paper's bottleneck
analysis ('fully leveraging this parallelism requires more Redis shards').
The shuffle's range partitioner is loss-free and ordered across partitions:

>>> from repro.storage import shuffle as shf
>>> splitters = shf.sample_splitters([5, 1, 9, 3, 7], 2)
>>> parts = shf.range_partition([5, 1, 9, 3, 7], splitters)
>>> sorted(x for p in parts for x in p)
[1, 3, 5, 7, 9]
>>> max(parts[0]) <= min(parts[1])
True

Run:  PYTHONPATH=src python examples/terasort.py
"""

import time

import numpy as np

from repro.core import WrenExecutor, terasort, verify_sorted
from repro.storage import KVStore, REDIS_2017, S3_2017, ObjectStore
from repro.storage import shuffle as shf


def main() -> None:
    n_files, recs_per_file, n_parts = 10, 500, 10

    for shards in (1, 4, 8):
        store = ObjectStore(profile=S3_2017)
        wex = WrenExecutor(store=store, num_workers=6)
        try:
            keys = []
            for i in range(n_files):
                key = f"input/part{i:04d}"
                store.put(key, shf.make_sort_records(recs_per_file, seed=i), worker="gen")
                keys.append(key)

            kv = KVStore(num_shards=shards, profile=REDIS_2017)
            t0 = time.perf_counter()
            report = terasort(wex, keys, f"sorted/{shards}", n_parts, intermediate=kv)
            wall = time.perf_counter() - t0
            ok = verify_sorted(store, f"sorted/{shards}")
            print(
                f"shards={shards}: sorted {report.n_records} records "
                f"({report.n_intermediate_objects} intermediate objects = "
                f"{n_files}x{n_parts}), globally ordered: {ok}, "
                f"hottest-shard virtual time {report.hottest_shard_vtime:.3f}s, "
                f"wall {wall:.2f}s"
            )
        finally:
            wex.shutdown()
    print("note the quadratic intermediate-object count and the hotspot "
          "relief from added shards — the paper's Fig 5/6 story")


if __name__ == "__main__":
    main()
