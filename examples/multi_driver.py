"""Multi-driver: two stateless drivers cooperate on one mapreduce.

The scheduler is a *handle over the KV*, not a server (PR 4): any number of
drivers sharing a store/KV pair work one queue, and fenced epoch leases
keep their concurrent reap/speculate/complete transitions exactly-once.
Here both the storage planes are **file-backed** (`FileBackend` +
`FileKVStore`), the substrate that also works across real OS processes —
driver B could be another process on the same filesystem and nothing below
would change (`tests/test_multidriver.py` runs exactly that topology with
a spawned subprocess; the cross-process wake is the log-file watch
described in docs/ARCHITECTURE.md).  Since PR 5 the file KV is
log-structured: two handles over one directory see one keyspace, and each
mutation is one appended record, not a shard rewrite:

>>> import tempfile
>>> from repro.storage import FileKVStore
>>> root = tempfile.mkdtemp()
>>> a = FileKVStore(root, num_shards=1)   # "driver A"
>>> b = FileKVStore(root, num_shards=1)   # "driver B", same directory
>>> a.rpush("sched/queue", "task-0", worker="A")
1
>>> b.lpop("sched/queue", worker="B")     # B replays A's appended frame
'task-0'
>>> a.close(); b.close()

Driver A submits a word-count mapreduce; driver B never sees the submit —
its workers lease map and reduce tasks straight off the shared queue, and
its control loop reaps/speculates the same job.

Run:  PYTHONPATH=src python examples/multi_driver.py
"""

import tempfile

from repro.core import WrenExecutor, word_count
from repro.storage import FileBackend, FileKVStore, ObjectStore

DOCS = [
    "the cloud is just someone else us computer".split(),
    "occupy the cloud distributed computing for the rest of us".split(),
    "the simplicity of a map over stateless functions".split(),
    "storage is the only channel between functions".split(),
] * 4  # 16 map partitions


def main() -> None:
    with tempfile.TemporaryDirectory() as root:
        store = ObjectStore(backend=FileBackend(f"{root}/obj"))
        kv = FileKVStore(f"{root}/kv", num_shards=2)

        # Two independent drivers: each has its own scheduler handle and
        # worker pool, but every byte of control-plane state they act on
        # lives in the shared KV/store.
        driver_a = WrenExecutor(store=store, kv=kv, num_workers=2, seed=1)
        driver_b = WrenExecutor(store=store, kv=kv, num_workers=2, seed=2)
        try:
            # Driver A runs the job; driver B's workers just... find work.
            counts = word_count(driver_a, [[" ".join(d)] for d in DOCS], num_reducers=4)
            top = sorted(counts.items(), key=lambda kv_: -kv_[1])[:3]
            print(f"word count over {len(DOCS)} partitions: top {top}")

            for name, wex in (("A", driver_a), ("B", driver_b)):
                done = sum(s.tasks_ok for s in wex.pool.stats().values())
                print(f"driver {name} executed {done} tasks")
            b_done = sum(s.tasks_ok for s in driver_b.pool.stats().values())
            assert b_done > 0, "driver B never leased from the shared queue"
            print("both drivers executed tasks of a job only A submitted")
        finally:
            driver_a.shutdown()
            driver_b.shutdown()
            kv.close()
            store.backend.close()


if __name__ == "__main__":
    main()
