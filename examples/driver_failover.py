"""Driver failover: SIGKILL the submitting driver mid-mapreduce; adopt it.

PR 7 put the *job* plane in the KV: a manifest under ``sched/job/{job}``
records the stage graph, per-stage plans, and barrier outputs, all written
first-writer-wins, while the submitting driver holds a term-fenced
**driver lease** it heartbeats from its control loop.  A driver that dies
simply stops heartbeating; any other handle detects the lapsed lease,
fences a takeover at ``term + 1``, and *replays* the manifest — recorded
barriers return instantly, so only the unfinished suffix of the job runs.

The lease fencing in two lines — a release keeps the record (term intact),
so the next owner always draws a strictly higher term and the dead
driver's in-flight heartbeats fail:

>>> from repro.core import jobs
>>> from repro.storage import KVStore
>>> kv = KVStore(num_shards=1)
>>> jobs.acquire_driver(kv, "job", "drv-A", 30.0)["term"]   # first owner
1
>>> jobs.release_driver(kv, "job", "drv-A", 1)              # expire, keep record
True
>>> jobs.acquire_driver(kv, "job", "drv-B", 30.0)["term"]   # takeover: term + 1
2
>>> jobs.heartbeat_drivers(kv, {"job": 1}, "drv-A", 30.0)   # zombie: fenced out
['job']

Below, a *real* subprocess driver submits a word-count mapreduce over
shared ``FileKVStore``/``FileBackend`` directories and is SIGKILLed the
instant its map barrier commits — between the map and reduce stages, the
worst moment short of mid-barrier.  This process waits out the driver
lease, adopts, and finishes: the map stage is skipped (its barrier is
recorded), only the reduce stage runs, and the terminal GC leaves the
``sched/job/`` and ``shuffle/`` keyspaces empty.

Run:  PYTHONPATH=src python examples/driver_failover.py
"""

import os
import signal
import subprocess
import sys
import tempfile

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

DOCS = [
    "the cloud is just someone else us computer".split(),
    "occupy the cloud distributed computing for the rest of us".split(),
    "no process is special not even the driver".split(),
    "storage is the only channel between functions".split(),
] * 4  # 16 map partitions
JOB = "failover-demo"
NUM_REDUCERS = 4


def _map_fn(doc):
    return [(w, 1) for w in doc]


def _reduce_fn(_word, counts):
    return sum(counts)


def submit_and_die(kv_root: str, obj_root: str) -> None:
    """Subprocess entry: submit the mapreduce, then SIGKILL ourselves the
    instant the map barrier commits — no release, no cleanup, exactly what
    a crashed driver leaves behind."""
    from repro.core import SchedulerConfig, WrenExecutor, bsp
    from repro.storage import FileBackend, FileKVStore, ObjectStore

    kv = FileKVStore(kv_root, num_shards=2)
    store = ObjectStore(backend=FileBackend(obj_root))
    wex = WrenExecutor(
        store=store, kv=kv, num_workers=2,
        scheduler_config=SchedulerConfig(driver_lease_timeout_s=1.0),
    )

    orig_barrier = bsp._stage_barrier

    def dying_barrier(wex_, job, idx, plan, outputs, **kw):
        out = orig_barrier(wex_, job, idx, plan, outputs, **kw)
        if idx == 0:  # the map barrier just committed: die now
            print(f"[child] map barrier committed for {job!r}; SIGKILL", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        return out

    bsp._stage_barrier = dying_barrier
    bsp.mapreduce(wex, _map_fn, _reduce_fn, DOCS, NUM_REDUCERS, job_id=JOB)


def main() -> None:
    from repro.core import SchedulerConfig, WrenExecutor, adopt_job
    from repro.storage import FileBackend, FileKVStore, ObjectStore

    with tempfile.TemporaryDirectory() as root:
        kv_root, obj_root = f"{root}/kv", f"{root}/obj"
        env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
        child = subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "child", kv_root, obj_root],
            env=env,
        )
        child.wait()
        assert child.returncode == -signal.SIGKILL, "child was supposed to die by SIGKILL"
        print("[parent] child driver died (SIGKILL) mid-job")

        kv = FileKVStore(kv_root, num_shards=2)
        store = ObjectStore(backend=FileBackend(obj_root))
        wex = WrenExecutor(
            store=store, kv=kv, num_workers=2,
            scheduler_config=SchedulerConfig(driver_lease_timeout_s=1.0),
        )
        try:
            # detect (wait out the dead driver's lease) → fence → replay.
            counts = adopt_job(wex, JOB, wait_timeout_s=30.0)
            top = sorted(counts.items(), key=lambda kv_: -kv_[1])[:3]
            print(f"[parent] adopted and finished {JOB!r}: top {top}")
            assert counts["the"] == 20, counts  # 5 per 4-doc block x 4 blocks
            # the terminal GC left no trace: manifest and shuffle gone
            assert kv.scan(f"sched/job/{JOB}/") == []
            assert store.list("shuffle/") == []
            print("[parent] sched/job/ and shuffle/ keyspaces empty after GC")
        finally:
            wex.shutdown()
            kv.close()
            store.backend.close()


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "child":
        submit_and_die(sys.argv[2], sys.argv[3])
    else:
        main()
