"""Elastic remesh: resume training on a DIFFERENT device mesh.

The PyWren property applied to distributed training: because ALL durable
state lives in storage and steps are stateless, scaling the mesh is just
checkpoint -> re-place on the new mesh -> continue.  This script runs on 8
fake host devices: trains on a (4 data x 2 model) mesh, checkpoints,
reloads the same run on (2 data x 4 model), and keeps training — losses
continue smoothly across the remesh.

Run:  python examples/elastic_remesh.py     (sets its own XLA_FLAGS)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import CONFIGS
from repro.data import DataConfig, synthetic_batch
from repro.launch.mesh import make_mesh
from repro.launch.shardings import state_pspec, to_shardings
from repro.storage import ObjectStore
from repro.train import TrainState, adamw, init_train_state, make_train_step
from repro.train import checkpoint as ck


def place(state, mesh):
    sh = to_shardings(mesh, state_pspec(mesh, state))
    return jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), state, sh)


def run_steps(state, cfg, opt, dcfg, mesh, start, n):
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    with mesh:
        state = place(state, mesh)
        for i in range(start, start + n):
            state, m = step(state, synthetic_batch(dcfg, i, cfg))
            losses.append(float(m["loss"]))
    return state, losses


def main() -> None:
    cfg = dataclasses.replace(
        CONFIGS["llama3-8b"].reduced(), n_layers=2, d_model=128, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32, vocab_size=512,
    )
    opt = adamw(3e-3, weight_decay=0.0)
    dcfg = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
    store = ObjectStore()

    mesh_a = make_mesh(dp=4, tp=2)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    state, losses_a = run_steps(state, cfg, opt, dcfg, mesh_a, 0, 10)
    ck.save(store, "remesh", 1, tuple(state), meta={"step": 10})
    print(f"mesh (4x2): losses {losses_a[0]:.3f} -> {losses_a[-1]:.3f}")

    # ---- elastic remesh: reload the run on a different mesh --------------
    mesh_b = make_mesh(dp=2, tp=4)
    loaded, meta, _ = ck.load(store, "remesh")
    state_b = TrainState(*loaded)
    state_b, losses_b = run_steps(state_b, cfg, opt, dcfg, mesh_b, meta["step"], 10)
    print(f"mesh (2x4): losses {losses_b[0]:.3f} -> {losses_b[-1]:.3f}")
    assert losses_b[0] < losses_a[0], "training must continue, not restart"
    print("remesh resume OK: storage-resident state + stateless steps "
          "(the PyWren contract) make mesh shape a per-task detail")


if __name__ == "__main__":
    main()
