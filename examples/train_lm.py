"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
stateless runtime, with checkpoint/restart, a mid-run worker kill, and an
elastic resize — the full 'PyWren for training' story.

The model is the llama3-8b config scaled to ~100M params (same family/code
path as the full config; the full sizes are exercised by the dry-run).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import CONFIGS
from repro.core import WrenExecutor
from repro.data import DataConfig, synthetic_batch
from repro.train import ElasticTrainConfig, adamw, cosine_schedule, train_elastic
from repro.train import checkpoint as ck


def make_100m_config():
    base = CONFIGS["llama3-8b"]
    return dataclasses.replace(
        base,
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        vocab_size=2048,
        dtype="float32",
        param_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = make_100m_config()
    n_params = cfg.param_count()[0]
    print(f"model: {cfg.name}-100m derivative, {n_params/1e6:.1f}M params")

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch, vocab_size=cfg.vocab_size)
    opt = adamw(
        cosine_schedule(1.5e-3, warmup=20, total=args.steps),
        weight_decay=0.0,
    )
    batch_fn = lambda step: synthetic_batch(dcfg, step, cfg)  # noqa: E731

    wex = WrenExecutor(num_workers=2)
    try:
        tcfg = ElasticTrainConfig(
            run="lm100m", steps_per_chunk=10, total_steps=args.steps,
        )
        t0 = time.perf_counter()
        # elastic plan: grow the pool at chunk 5, shrink at chunk 12
        hist = train_elastic(
            wex, cfg, opt, tcfg, batch_fn, scale_plan={5: 4, 12: 2}
        )
        dt = time.perf_counter() - t0
        print(f"chunk losses: {[round(h['loss'], 3) for h in hist]}")
        print(
            f"{args.steps} steps in {dt:.1f}s "
            f"({args.steps * args.batch * args.seq / dt:.0f} tok/s on CPU); "
            f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}"
        )
        assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"

        # ---- kill a worker and keep going (fault tolerance) --------------
        wex.pool.kill_worker(0)
        more = train_elastic(
            wex, cfg, opt,
            ElasticTrainConfig(run="lm100m", steps_per_chunk=10,
                               total_steps=args.steps + 30),
            batch_fn,
        )
        print(f"after worker kill, trained 3 more chunks: "
              f"{[round(h['loss'], 3) for h in more]}")
        print(f"final checkpoint version: {ck.latest_version(wex.store, 'lm100m')}")
    finally:
        wex.shutdown()


if __name__ == "__main__":
    main()
