"""Dependency-free utilities shared across layers."""

import os


def scan_unroll():
    """Scan unroll factor for layer/block scans.

    Default 1 (rolled: fast compiles, tiny HLO).  The dry-run sets
    REPRO_SCAN_UNROLL=full so `compiled.cost_analysis()` counts every layer
    (XLA costs a while-loop body ONCE regardless of trip count — rolled
    compiles undercount FLOPs/collective bytes by ~n_layers)."""
    v = os.environ.get("REPRO_SCAN_UNROLL", "1")
    return True if v == "full" else max(int(v), 1)


def inner_unroll():
    """Unroll factor for kernel-level inner scans (attention KV blocks, SSD
    chunks, mLSTM blocks).  Kept separate from layer-scan unroll: inner scans
    contain no collectives, so the dry-run can keep them rolled in compiled
    probes (small graphs, fast CPU codegen) while counting their FLOPs from
    fully-unrolled *lowered* modules."""
    v = os.environ.get("REPRO_INNER_UNROLL", "1")
    return True if v == "full" else max(int(v), 1)
