"""The stateless training step: (train_state, batch) -> (train_state', metrics).

This is the unit the serverless runtime schedules.  It is *pure*: given the
same state and batch it produces the same result, which is what makes
PyWren-style idempotent re-execution correct for training.

Features: CE loss with ignore index, MoE aux loss, MTP aux loss (DeepSeek),
grad clipping, microbatch gradient accumulation (scan), remat, metrics.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import os

from repro.configs.base import ModelConfig
from repro.models import forward, forward_hidden, head_weight
from repro.models.sharding import shard

from .fused_ce import fused_cross_entropy

from .optimizer import AdamWState, Optimizer, apply_updates, clip_by_global_norm

IGNORE = -1


class TrainState(NamedTuple):
    params: Any
    opt_state: AdamWState


def cross_entropy(
    logits: jnp.ndarray,  # (B, S, V) fp32
    labels: jnp.ndarray,  # (B, S) int32, IGNORE = masked
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (summed nll, token count)."""
    V = logits.shape[-1]
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, logz - gold, 0.0)
    return jnp.sum(nll), jnp.sum(mask)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = False, fused_ce: Optional[bool] = None):
    """fused_ce=True uses chunked-vocab CE (never materializes (N, V) fp32
    logits — see train/fused_ce.py); requires the head's vocab dim to be
    unsharded (fsdp_all axis scheme).  Default: REPRO_FUSED_CE env."""
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    mtp_w = 0.3 if cfg.mtp_depth else 0.0
    if fused_ce is None:
        fused_ce = os.environ.get("REPRO_FUSED_CE", "0") == "1"

    def _labels(batch):
        labels = batch["labels"]
        if cfg.frontend == "vision_stub" and "prefix_embed" in batch:
            # prefix positions carry no LM loss
            P = batch["prefix_embed"].shape[1]
            pad = jnp.full((labels.shape[0], P), IGNORE, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return labels

    def loss_fn_fused(params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        h, aux, extras = forward_hidden(params, cfg, batch, remat=remat)
        labels = _labels(batch)
        W = head_weight(params, cfg)
        B, S, D = h.shape
        nll, count = fused_cross_entropy(
            h.reshape(B * S, D), W, labels.reshape(-1),
            final_softcap=cfg.final_softcap,
        )
        loss = nll / jnp.maximum(count, 1)
        metrics = {"nll": loss, "tokens": count}
        if aux_w:
            loss = loss + aux_w * aux
            metrics["router_aux"] = aux
        if mtp_w and "mtp_hidden" in extras:
            hm = extras["mtp_hidden"]
            mtp_labels = labels[:, 2:]
            hm = hm[:, : mtp_labels.shape[1]]
            Bm, Sm, _ = hm.shape
            mtp_nll, mtp_count = fused_cross_entropy(
                hm.reshape(Bm * Sm, D), W, mtp_labels.reshape(-1),
                final_softcap=cfg.final_softcap,
            )
            mtp_loss = mtp_nll / jnp.maximum(mtp_count, 1)
            loss = loss + mtp_w * mtp_loss
            metrics["mtp_nll"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def loss_fn(params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux, extras = forward(params, cfg, batch, remat=remat)
        labels = _labels(batch)
        nll, count = cross_entropy(logits, labels)
        loss = nll / jnp.maximum(count, 1)
        metrics = {"nll": loss, "tokens": count}
        if aux_w:
            loss = loss + aux_w * aux
            metrics["router_aux"] = aux
        if mtp_w and "mtp_logits" in extras:
            # MTP predicts token t+2 from position t
            mtp_labels = labels[:, 2:]
            mtp_logits = extras["mtp_logits"][:, : mtp_labels.shape[1]]
            mtp_nll, mtp_count = cross_entropy(mtp_logits, mtp_labels)
            mtp_loss = mtp_nll / jnp.maximum(mtp_count, 1)
            loss = loss + mtp_w * mtp_loss
            metrics["mtp_nll"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn_fused if fused_ce else loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    *,
    remat: bool = False,
    grad_clip: float = 1.0,
    microbatches: int = 1,
):
    """Build the jit-able stateless step.

    With microbatches > 1, the global batch is split on the batch axis and
    gradients are accumulated with a scan — the standard trick to fit large
    global batches; accumulation happens in fp32.
    """
    loss_fn = make_loss_fn(cfg, remat=remat)
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params

        if microbatches == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0, (B, microbatches)
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mb_i):
                g_acc, met_acc = carry
                g, met = grad_fn(params, mb_i)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                met_acc = jax.tree_util.tree_map(lambda a, b: a + b, met_acc, met)
                return (g_acc, met_acc), None

            # first microbatch outside the scan fixes the metric structure
            g_first, met_first = grad_fn(
                params, jax.tree_util.tree_map(lambda x: x[0], mb)
            )
            g_first = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), g_first
            )
            (grads, metrics), _ = jax.lax.scan(
                acc_body,
                (g_first, met_first),
                jax.tree_util.tree_map(lambda x: x[1:], mb),
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / microbatches, metrics)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, new_opt = opt.update(grads, state.opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return TrainState(params=new_params, opt_state=new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt: Optimizer, key) -> TrainState:
    from repro.models import init_params

    params = init_params(cfg, key)
    return TrainState(params=params, opt_state=opt.init(params))
