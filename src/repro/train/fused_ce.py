"""Memory-efficient (chunked-vocab) cross-entropy.

The naive LM loss materializes fp32 logits (N, V) — for llama3-8b train_4k
that is 1M x 128k x 4B = 0.5 PB-touched globally once read for softmax,
gather, and grad: ~25% of all HLO bytes.  This computes

    nll_t = logsumexp_V(h_t W) - (h_t W)[y_t]

by scanning vocab chunks with running (max, sum) online-logsumexp stats and
a gold-logit accumulator.  Each chunk body is jax.checkpoint'ed, so the
backward pass recomputes the chunk's (N, c) logits instead of saving them:
peak logits memory drops V/c-fold (flops on the head grow ~1.5x — the
classic Liger/flash-CE trade, a bargain when the head is bytes-bound).

Requires the vocab dim of W to be unsharded (the fsdp_all axis scheme);
under vocab-sharded TP the caller should keep the standard CE.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

IGNORE = -1


def fused_cross_entropy(
    h: jnp.ndarray,  # (N, D)  final hidden states (already normed)
    W: jnp.ndarray,  # (D, V)  head weight
    labels: jnp.ndarray,  # (N,) int32, IGNORE = masked
    *,
    final_softcap: Optional[float] = None,
    vocab_chunk: int = 8192,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (summed nll, token count); never materializes (N, V)."""
    N, D = h.shape
    V = W.shape[-1]
    c = min(vocab_chunk, V)
    nc = -(-V // c)
    pad = nc * c - V
    if pad:
        W = jnp.pad(W, ((0, 0), (0, pad)))
    Wc = W.reshape(D, nc, c).transpose(1, 0, 2)  # (nc, D, c)

    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_body(carry, inp):
        m, s, gold = carry  # (N,), (N,), (N,)
        W_blk, off = inp  # (D, c), scalar
        logits = (h @ W_blk).astype(jnp.float32)  # (N, c)
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        if pad:  # mask the padded tail of the last chunk
            col = off + jnp.arange(c)
            logits = jnp.where(col[None, :] < V, logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        s_new = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=1)
        in_chunk = (safe >= off) & (safe < off + c)
        idx = jnp.clip(safe - off, 0, c - 1)
        g = jnp.take_along_axis(logits, idx[:, None], axis=1)[:, 0]
        gold_new = gold + jnp.where(in_chunk, g, 0.0)
        return (m_new, s_new, gold_new), None

    m0 = jnp.full((N,), -1e30, jnp.float32)
    s0 = jnp.zeros((N,), jnp.float32)
    g0 = jnp.zeros((N,), jnp.float32)
    (m, s, gold), _ = jax.lax.scan(
        chunk_body, (m0, s0, g0), (Wc, jnp.arange(nc) * c)
    )
    nll = jnp.where(mask, jnp.log(s) + m - gold, 0.0)
    return jnp.sum(nll), jnp.sum(mask)
