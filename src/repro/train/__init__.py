"""Training substrate: optimizer, stateless train step, storage-backed
checkpoints, elastic driver."""

from . import checkpoint, elastic, optimizer, train_step
from .elastic import ElasticTrainConfig, train_elastic
from .optimizer import adamw, apply_updates, clip_by_global_norm, cosine_schedule
from .train_step import TrainState, init_train_state, make_loss_fn, make_train_step

__all__ = [
    "checkpoint", "elastic", "optimizer", "train_step",
    "adamw", "apply_updates", "clip_by_global_norm", "cosine_schedule",
    "TrainState", "init_train_state", "make_loss_fn", "make_train_step",
    "ElasticTrainConfig", "train_elastic",
]
