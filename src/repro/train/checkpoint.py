"""Storage-backed checkpoints: the durable state plane of stateless training.

PyWren contract applied to training state:
  * every checkpoint is an immutable *version*: ``ckpt/<run>/v<NNNN>/...``;
  * leaves are chunked into objects (bounded object size — the paper's
    Lambda/S3 granularity constraints) and written in parallel-friendly keys;
  * the version becomes *visible* only when its manifest publishes via
    atomic ``put_if_absent`` — a speculative/duplicate trainer task racing on
    the same step writes identical content and loses the publish harmlessly;
  * ``latest_version`` scans manifests, so any worker can recover the run
    state from storage alone (scheduler-free restart);
  * loading accepts a *different mesh* than the writer's: leaves are placed
    with jax.device_put against the reader's NamedSharding — elastic remesh.

Storage layout:
  ckpt/<run>/v<step>/manifest      {spec: tree of (key, shape, dtype), ...}
  ckpt/<run>/v<step>/leaf/<idx>/<chunk>
"""

from __future__ import annotations

import math
import pickle
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.storage import ObjectStore

CHUNK_BYTES = 64 * 1024 * 1024  # bounded object size


@dataclass
class CkptManifest:
    run: str
    version: int
    tree: Any  # treedef-compatible structure of leaf descriptors
    n_leaves: int
    meta: Dict[str, Any]


def _leaf_key(run: str, version: int, idx: int, chunk: int) -> str:
    return f"ckpt/{run}/v{version:08d}/leaf/{idx:05d}/{chunk:04d}"


def _manifest_key(run: str, version: int) -> str:
    return f"ckpt/{run}/v{version:08d}/manifest"


def save(
    store: ObjectStore,
    run: str,
    version: int,
    state: Any,
    *,
    meta: Optional[Dict[str, Any]] = None,
    worker: str = "ckpt",
) -> bool:
    """Write a checkpoint version; returns True if this call won the publish
    (False = another writer already published this version — idempotent)."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    descs = []
    chunks: Dict[str, bytes] = {}
    # Backends that consume a put before returning (file, net) take the
    # checkpoint shards as memoryviews over the live array memory — the
    # wire/disk write is then zero-copy end to end.  Reference-storing
    # backends (in-memory) still get a private bytes copy.
    zero_copy = getattr(store.backend, "zero_copy_puts", False)
    for i, leaf in enumerate(leaves):
        arr = np.ascontiguousarray(np.asarray(leaf))
        blob = memoryview(arr).cast("B") if zero_copy else arr.tobytes()
        n_chunks = max(1, math.ceil(len(blob) / CHUNK_BYTES))
        for c in range(n_chunks):
            chunks[_leaf_key(run, version, i, c)] = (
                blob[c * CHUNK_BYTES : (c + 1) * CHUNK_BYTES]
            )
        descs.append(
            {"shape": arr.shape, "dtype": str(arr.dtype), "chunks": n_chunks, "idx": i}
        )
    # One batched write for the whole version (the state already resides in
    # memory, so staging the chunk map costs no extra copy of consequence);
    # N chunk objects land in one amortized round-trip instead of N.
    store.put_many_bytes(chunks, worker=worker)
    manifest = {
        "run": run,
        "version": version,
        "treedef": pickle.dumps(treedef),
        "descs": descs,
        "meta": meta or {},
    }
    return store.put(_manifest_key(run, version), manifest, worker=worker, if_absent=True)


def latest_version(store: ObjectStore, run: str) -> Optional[int]:
    keys = store.list(f"ckpt/{run}/")
    versions = sorted(
        int(k.split("/v")[1].split("/")[0]) for k in keys if k.endswith("/manifest")
    )
    return versions[-1] if versions else None


def load(
    store: ObjectStore,
    run: str,
    version: Optional[int] = None,
    *,
    shardings: Optional[Any] = None,  # pytree of NamedSharding (reader's mesh)
    worker: str = "ckpt",
) -> Tuple[Any, Dict[str, Any], int]:
    """Returns (state, meta, version).  With `shardings`, leaves are placed
    per the *reader's* mesh — checkpoint-level resharding for elasticity."""
    if version is None:
        version = latest_version(store, run)
        if version is None:
            raise FileNotFoundError(f"no checkpoints for run '{run}'")
    manifest = store.get(_manifest_key(run, version), worker=worker)
    treedef = pickle.loads(manifest["treedef"])
    # One batched fetch for every chunk of every leaf (a missing chunk
    # surfaces as KeyError below, as the per-chunk gets used to raise).
    blobs = store.get_many_bytes(
        [
            _leaf_key(run, version, d["idx"], c)
            for d in manifest["descs"]
            for c in range(d["chunks"])
        ],
        worker=worker,
    )
    leaves = []
    for d in manifest["descs"]:
        blob = b"".join(
            blobs[_leaf_key(run, version, d["idx"], c)] for c in range(d["chunks"])
        )
        arr = np.frombuffer(blob, dtype=np.dtype(d["dtype"])).reshape(d["shape"])
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), state, shardings
        )
    else:
        state = jax.tree_util.tree_map(jnp.asarray, state)
    return state, manifest["meta"], version


def gc_old_versions(store: ObjectStore, run: str, keep: int = 3) -> int:
    """Delete all but the newest `keep` versions; returns #objects deleted."""
    keys = store.list(f"ckpt/{run}/")
    versions = sorted(
        {int(k.split("/v")[1].split("/")[0]) for k in keys if "/v" in k}
    )
    doomed = versions[:-keep] if keep else versions
    doomed_keys = [
        k for v in doomed for k in store.list(f"ckpt/{run}/v{v:08d}/")
    ]
    if doomed_keys:
        store.delete_many(doomed_keys)
    return len(doomed_keys)
