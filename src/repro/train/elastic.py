"""Elastic, fault-tolerant training on the stateless-function runtime.

This is the paper's model applied to the workload it said didn't fit
(§4 'Other applications': long-running coordinated processes).  The unit of
work is a **step chunk**: run K training steps from checkpoint version v,
publish version v+1.  Properties inherited from the PyWren contract:

  * *stateless*: a chunk task reads (version, K) as input; params/optimizer
    state come from storage; nothing depends on which worker runs it;
  * *idempotent*: data batches are a pure function of the step index
    (deterministic pipeline), so duplicate executions write byte-identical
    checkpoints; the manifest's atomic publish makes re-execution and
    speculation safe;
  * *warm containers*: a worker that just produced v keeps (params, opt) in
    memory; if it picks up the chunk for v+1 it skips the storage load
    (cache keyed by version hash) — PyWren's container reuse;
  * *elastic remesh*: between chunks the driver may change worker count or
    mesh shape; the checkpoint loader reshards on read.

The driver below runs chunks through the WrenExecutor so scheduling,
retries, lease recovery and speculation come from repro.core unchanged.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import WrenExecutor, get_all
from repro.storage import ObjectStore

from . import checkpoint as ckpt
from .optimizer import Optimizer
from .train_step import TrainState, init_train_state, make_train_step


@dataclass
class ElasticTrainConfig:
    run: str = "run0"
    steps_per_chunk: int = 10
    total_steps: int = 100
    keep_checkpoints: int = 3
    grad_clip: float = 1.0
    microbatches: int = 1
    remat: bool = False


# per-process warm cache: version -> TrainState (the container-reuse trick).
# Resolved via runtime import inside the task body: cloudpickle captures
# referenced globals BY VALUE, which would snapshot (and ship!) the cache —
# importing the module at call time reaches the live per-process dict, which
# is exactly a warm container's local scratch.
WARM_CACHE: Dict[Tuple[str, int], TrainState] = {}


def _live_warm_cache() -> Dict[Tuple[str, int], TrainState]:
    import repro.train.elastic as _el

    return _el.WARM_CACHE


def make_chunk_fn(
    cfg: ModelConfig,
    opt: Optimizer,
    store: ObjectStore,
    tcfg: ElasticTrainConfig,
    batch_fn: Callable[[int], Dict[str, jnp.ndarray]],
):
    """Builds the stateless chunk function shipped through the runtime."""
    step_fn = jax.jit(
        make_train_step(
            cfg, opt,
            remat=tcfg.remat, grad_clip=tcfg.grad_clip, microbatches=tcfg.microbatches,
        )
    )

    def chunk_fn(version: int) -> Dict[str, float]:
        cache = _live_warm_cache()
        key = (tcfg.run, version)
        if key in cache:  # warm container: skip the storage load
            state = cache.pop(key)
            warm = True
        else:
            state, _, _ = ckpt.load(store, tcfg.run, version)
            state = TrainState(*state) if not isinstance(state, TrainState) else state
            warm = False
        base_step = version * tcfg.steps_per_chunk
        metrics: Dict[str, float] = {}
        for i in range(tcfg.steps_per_chunk):
            batch = batch_fn(base_step + i)
            state, m = step_fn(state, batch)
            metrics = {k: float(v) for k, v in m.items()}
        ckpt.save(
            store, tcfg.run, version + 1, tuple(state),
            meta={"step": base_step + tcfg.steps_per_chunk, "metrics": metrics},
        )
        cache[(tcfg.run, version + 1)] = state
        metrics["warm_start"] = 1.0 if warm else 0.0
        return metrics

    return chunk_fn


def train_elastic(
    wex: WrenExecutor,
    cfg: ModelConfig,
    opt: Optimizer,
    tcfg: ElasticTrainConfig,
    batch_fn: Callable[[int], Dict[str, jnp.ndarray]],
    *,
    seed: int = 0,
    scale_plan: Optional[Dict[int, int]] = None,  # chunk idx -> worker count
    timeout_s: float = 600.0,
) -> List[Dict[str, float]]:
    """Run total_steps in chunks through the serverless runtime."""
    store = wex.store
    if ckpt.latest_version(store, tcfg.run) is None:
        state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
        ckpt.save(store, tcfg.run, 0, tuple(state), meta={"step": 0})

    chunk_fn = make_chunk_fn(cfg, opt, store, tcfg, batch_fn)
    n_chunks = tcfg.total_steps // tcfg.steps_per_chunk
    history: List[Dict[str, float]] = []
    start_v = ckpt.latest_version(store, tcfg.run) or 0
    for chunk_idx in range(start_v, n_chunks):
        if scale_plan and chunk_idx in scale_plan:
            wex.scale_to(scale_plan[chunk_idx])  # elastic resize mid-run
        [metrics] = get_all(wex.map(chunk_fn, [chunk_idx]), timeout_s=timeout_s)
        history.append(metrics)
        ckpt.gc_old_versions(store, tcfg.run, keep=tcfg.keep_checkpoints)
    return history
