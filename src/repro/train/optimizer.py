"""AdamW from scratch (pytree-native) with optional int8-quantized moments.

The int8 moments are a distributed-optimization trick that matters doubly in
this framework: optimizer state is (a) HBM-resident during a step and (b)
*storage-resident between stateless tasks* (the PyWren model), so quantizing
m/v to int8 with per-block scales cuts both the HBM footprint and the
checkpoint bytes ~4x vs fp32 moments (~2x vs bf16).

API mirrors optax loosely:
    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return fn


# ---------------------------------------------------------------------------
# int8 block quantization
# ---------------------------------------------------------------------------

_BLOCK = 256


def _q8_encode(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q8_decode(enc: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any  # pytree (fp32 or q8-encoded)
    v: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], AdamWState]
    update: Callable[[Any, AdamWState, Any], Tuple[Any, AdamWState]]


def adamw(
    lr: Schedule | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    quantize_moments: bool = False,
    moment_dtype=jnp.float32,
) -> Optimizer:
    sched: Schedule = lr if callable(lr) else constant_schedule(lr)

    def _enc(x):
        return _q8_encode(x) if quantize_moments else x.astype(moment_dtype)

    def _dec(x, shape):
        return _q8_decode(x, shape) if quantize_moments else x.astype(jnp.float32)

    # v (second moment) is quantized in sqrt space: linear int8 on v zeroes
    # small entries within a block (one large |g| dominates the scale), and
    # sqrt(0)+eps in the denominator then produces huge updates.  sqrt-space
    # doubles the effective dynamic range for small values.
    def _enc_v(x):
        return _q8_encode(jnp.sqrt(x)) if quantize_moments else x.astype(moment_dtype)

    def _dec_v(x, shape):
        if quantize_moments:
            r = _q8_decode(x, shape)
            return r * r
        return x.astype(jnp.float32)

    def init(params) -> AdamWState:
        zeros = jax.tree_util.tree_map(lambda p: _enc(jnp.zeros_like(p, jnp.float32)), params)
        zeros2 = jax.tree_util.tree_map(lambda p: _enc_v(jnp.zeros_like(p, jnp.float32)), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)

    def update(grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        is_q8 = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}  # noqa: E731

        def upd(g, m_enc, v_enc, p):
            g = g.astype(jnp.float32)
            m = _dec(m_enc, g.shape) if quantize_moments else m_enc.astype(jnp.float32)
            v = _dec_v(v_enc, g.shape) if quantize_moments else v_enc.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if quantize_moments:
                # Adafactor-style update clipping guards against residual
                # quantization noise in near-zero blocks
                rms = jnp.sqrt(jnp.mean(delta * delta) + 1e-12)
                delta = delta / jnp.maximum(1.0, rms)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), _enc(m), _enc_v(v)

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.m) if not quantize_moments else jax.tree_util.tree_leaves(
            state.m, is_leaf=is_q8
        )
        flat_v = tdef.flatten_up_to(state.v) if not quantize_moments else jax.tree_util.tree_leaves(
            state.v, is_leaf=is_q8
        )
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * factor, grads), norm
