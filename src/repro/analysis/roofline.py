"""Three-term roofline from compiled XLA artifacts.

  compute term    = per-device HLO FLOPs / peak FLOP/s
  memory term     = per-device HLO bytes accessed / HBM bandwidth
  collective term = per-device collective wire bytes / ICI link bandwidth

`cost_analysis()` on the partitioned executable is already per-device.
Collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO text
(`compiled.as_text()`) where every collective op carries its per-device
result shape and replica groups, and apply standard ring-algorithm wire
accounting per op kind.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(?P<ng>\d+),(?P<gs>\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group("gs"))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split(",")
        return max(len([x for x in first if x.strip() != ""]), 1)
    return default


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0  # per device
    by_op: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op").replace("-start", "")
        nbytes = _type_bytes(m.group("type"))
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op == "all-gather":
            wire = (n - 1) / n * nbytes  # result is the gathered buffer
        elif op == "reduce-scatter":
            wire = (n - 1) * nbytes  # result is the scattered shard
        elif op == "all-to-all":
            wire = (n - 1) / n * nbytes
        else:  # collective-permute
            wire = float(nbytes)
        stats.wire_bytes += wire
        stats.by_op[op] = stats.by_op.get(op, 0.0) + wire
        stats.counts[op] = stats.counts.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float  # 6*N*D (or 6*N_active*D), global per step
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    collective_by_op: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    memory_stats: Dict[str, float] = field(default_factory=dict)

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops_per_device / PEAK_FLOPS
        self.memory_s = self.hlo_bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / LINK_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        total_hlo = self.hlo_flops_per_device * self.n_devices
        self.useful_ratio = self.model_flops / total_hlo if total_hlo else 0.0
        return self

    def step_time_bound_s(self) -> float:
        """Roofline lower bound on step time (no overlap assumption: max)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Achievable-MFU proxy: useful FLOPs at peak vs roofline-bound time."""
        ideal_s = self.model_flops / (self.n_devices * PEAK_FLOPS)
        bound = self.step_time_bound_s()
        return ideal_s / bound if bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction(),
            "step_bound_s": self.step_time_bound_s(),
            "collective_by_op": self.collective_by_op,
            "collective_counts": self.collective_counts,
            "memory_stats": self.memory_stats,
        }


def model_flops_per_step(total_params: int, active_params: int, tokens: int, kind: str) -> float:
    """6ND for training (fwd+bwd), 2ND for inference (fwd only)."""
    n = active_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens
