"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
reports/dryrun/*.json (and §Perf rows from reports/perf/*.json).

Usage: PYTHONPATH=src python -m repro.analysis.report [--update-experiments]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
HBM_PER_CHIP = 16e9  # v5e


def load(dirname: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, "reports", dirname, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_bytes(b: float) -> str:
    if b < 0:
        return "-"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table(cells: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | compile s | args/dev | temp/dev | fits 16G "
        "(args) | HLO flops/dev | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        ms = d["memory_stats"]
        fits = "yes" if 0 <= ms["argument_bytes"] <= HBM_PER_CHIP else "NO"
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['compile_s']:.1f} "
            f"| {fmt_bytes(ms['argument_bytes'])} | {fmt_bytes(ms['temp_bytes'])} "
            f"| {fits} | {d['hlo_flops_per_device']:.2e} "
            f"| {d['collective_bytes_per_device']:.2e} |"
        )
    return "\n".join(rows)


def roofline_table(cells: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| 6ND/HLO | roofline frac | bound s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['compute_s']:.3f} | {d['memory_s']:.3f} | {d['collective_s']:.3f} "
            f"| **{d['dominant']}** | {d['useful_ratio']:.2f} "
            f"| {d['roofline_fraction']:.3f} | {d['step_bound_s']:.3f} |"
        )
    return "\n".join(rows)


def perf_table(cells: List[Dict]) -> str:
    rows = [
        "| cell | variant | compute s | memory s | collective s | dominant | roofline frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        rows.append(
            f"| {d['arch']}/{d['shape']}/{d['mesh']} | {d.get('variant','baseline')} "
            f"| {d['compute_s']:.3f} | {d['memory_s']:.3f} | {d['collective_s']:.3f} "
            f"| {d['dominant']} | {d['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=["dryrun", "roofline", "perf", "all"], default="all")
    args = ap.parse_args()
    cells = load("dryrun")
    perf = load("perf")
    if args.section in ("dryrun", "all"):
        print("## §Dry-run\n")
        print(dryrun_table(cells))
        print()
    if args.section in ("roofline", "all"):
        print("## §Roofline\n")
        print(roofline_table(cells))
        print()
    if args.section in ("perf", "all") and perf:
        print("## §Perf variants\n")
        print(perf_table(perf))


if __name__ == "__main__":
    main()
