"""Runtime sanitizer: interpose on every KV/store op and check, live, the
invariants ``reprolint`` can only approximate statically.

Four detectors (ISSUE 6 / docs/ARCHITECTURE.md "Design decision 6"):

  * **unfenced-write** — a bare ``set``/``mset`` on ``sched/lease/`` or
    ``sched/epoch/`` (lease records install only through epoch-compared
    ``eval``; epochs only move through ``incr``), or a ``delete``/``mdel``
    of lease/epoch/attempt keys for a job whose ``sched/finished/``
    tombstone this process has not written — i.e. GC-order violations a
    zombie could exploit;
  * **lock-order** — a cycle in the acquired-lock graph over the tracked
    locks (KV shard locks, the scheduler handle lock);
  * **blocked-under-lock** — any KV/store round-trip *entered* while the
    calling thread already holds a tracked lock (the lexical LOCK001 rule,
    enforced dynamically and interprocedurally);
  * **torn-read** — a reader's ``mget`` observes, within one shard, part
    of a multi-key ``mset``/``eval_many`` batch applied and part not:
    per-shard batch atomicity (the PR 3 contract every fenced transition
    leans on) was violated.

Wrapping is an in-place ``__class__`` swap to a generated subclass, so
``isinstance`` checks (``shuffle`` dispatches on ``KVStore``) and the
``_Endpoint`` by-reference pickling both keep working::

    kv = SanitizingKVStore(KVStore())        # same object, instrumented
    store = SanitizingBackend(ObjectStore()) # ditto (wraps backend too)

``install()`` hooks the constructors of every built-in KV/store/backend
class plus ``Scheduler`` so an *existing test suite* runs fully sanitized
without edits; ``tests/conftest.py`` calls it when ``REPRO_SANITIZE=1``
and fails any test that produced reports.  Sanitizer bookkeeping never
touches the op ledgers, so round-trip-count assertions are unaffected.
"""

from __future__ import annotations

import pickle
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

_SCHED_LEASE = "sched/lease/"
_SCHED_EPOCH = "sched/epoch/"
_SCHED_ATTEMPTS = "sched/attempts/"
_SCHED_FINISHED = "sched/finished/"
_SCHED_JOB = "sched/job/"  # job-manifest keyspace (core/jobs.py)

# Values bigger than this are not digested for torn-read tracking (the
# check degrades to "unknown", which never reports): keeps soak tests fast.
_DIGEST_CAP_BYTES = 1 << 20
_SHADOW_HISTORY = 8
_MAX_REPORTS = 64
_OPLOG_LEN = 512


@dataclass
class Report:
    kind: str  # unfenced-write | lock-order | blocked-under-lock | torn-read
    message: str
    thread: str

    def __str__(self) -> str:
        return f"[{self.kind}] ({self.thread}) {self.message}"


@dataclass
class OpEvent:
    """One interposed operation: the ``(thread, locks-held, key, op,
    epoch-if-sched)`` tuple the sanitizer records for every op."""
    thread: str
    locks: Tuple[str, ...]
    op: str
    key: str
    epoch: Optional[int] = None


class _TLS(threading.local):
    def __init__(self) -> None:
        self.held: List[Tuple[int, str]] = []  # (lock id, lock name)
        self.depth = 0


class SanitizerState:
    def __init__(self) -> None:
        self.enabled = False
        self._mu = threading.Lock()
        self.reports: List[Report] = []
        self._seen_msgs: Set[str] = set()
        self.oplog: List[OpEvent] = []
        self._tls = _TLS()
        # acquired-lock graph: edges held-lock-id -> acquired-lock-id
        self._edges: Dict[int, Set[int]] = {}
        self._lock_names: Dict[int, str] = {}
        self._stamp = 0

    # -- reports ---------------------------------------------------------
    def report(self, kind: str, message: str) -> None:
        t = threading.current_thread().name
        with self._mu:
            if message in self._seen_msgs or len(self.reports) >= _MAX_REPORTS:
                return
            self._seen_msgs.add(message)
            self.reports.append(Report(kind, message, t))

    def snapshot(self) -> List[Report]:
        with self._mu:
            return list(self.reports)

    def clear(self) -> None:
        with self._mu:
            self.reports.clear()
            self._seen_msgs.clear()
            self.oplog.clear()

    # -- op log ----------------------------------------------------------
    def log_op(self, op: str, key: str, epoch: Optional[int]) -> None:
        ev = OpEvent(
            thread=threading.current_thread().name,
            locks=tuple(n for _i, n in self._tls.held),
            op=op,
            key=key,
            epoch=epoch,
        )
        with self._mu:
            self.oplog.append(ev)
            if len(self.oplog) > _OPLOG_LEN:
                del self.oplog[: len(self.oplog) - _OPLOG_LEN]

    # -- lock tracking ---------------------------------------------------
    def note_acquire(self, lock_id: int, name: str) -> None:
        held = self._tls.held
        with self._mu:
            self._lock_names[lock_id] = name
            for hid, _hname in held:
                if hid == lock_id:
                    continue  # re-entrant acquire, no edge
                self._edges.setdefault(hid, set()).add(lock_id)
                if self._reachable(lock_id, hid):
                    self.reports_unlocked_lock_order(hid, lock_id)
        held.append((lock_id, name))

    def reports_unlocked_lock_order(self, hid: int, lock_id: int) -> None:
        # caller holds self._mu
        msg = (
            f"lock-order inversion: {self._lock_names.get(hid, hid)} -> "
            f"{self._lock_names.get(lock_id, lock_id)} closes a cycle in "
            f"the acquired-lock graph"
        )
        if msg not in self._seen_msgs and len(self.reports) < _MAX_REPORTS:
            self._seen_msgs.add(msg)
            self.reports.append(
                Report("lock-order", msg, threading.current_thread().name)
            )

    def _reachable(self, src: int, dst: int) -> bool:
        # caller holds self._mu
        stack, seen = [src], set()
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self._edges.get(n, ()))
        return False

    def note_release(self, lock_id: int, all_counts: bool = False) -> None:
        held = self._tls.held
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] == lock_id:
                del held[i]
                if not all_counts:
                    return

    def held_locks(self) -> List[str]:
        return [n for _i, n in self._tls.held]

    def next_stamp(self) -> int:
        with self._mu:
            self._stamp += 1
            return self._stamp


state = SanitizerState()


# ---------------------------------------------------------------------------
# tracked locks
# ---------------------------------------------------------------------------

class TrackedLock:
    """Proxy over a ``threading.Lock``/``RLock`` that records per-thread
    holds and feeds the acquired-lock graph.  Implements the private
    ``Condition`` hooks so a ``threading.Condition`` built over it keeps
    working — and so ``Condition.wait`` correctly *untracks* the lock for
    the duration of the wait (waiting on a condition releases its lock;
    that is the sanctioned blocking-under-lock idiom)."""

    def __init__(self, inner: Any, name: str) -> None:
        self._inner = inner
        self._name = name

    # -- plain lock protocol --------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            state.note_acquire(id(self), self._name)
        return got

    def release(self) -> None:
        state.note_release(id(self))
        self._inner.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # -- Condition integration ------------------------------------------
    def _release_save(self) -> Tuple[str, Any]:
        # An RLock fully releases (all recursion levels); mirror that in
        # the tracking so a waiting thread shows no held lock.
        state.note_release(id(self), all_counts=True)
        if hasattr(self._inner, "_release_save"):
            return ("rlock", self._inner._release_save())
        self._inner.release()
        return ("lock", None)

    def _acquire_restore(self, saved: Tuple[str, Any]) -> None:
        kind, inner_state = saved
        if kind == "rlock":
            self._inner._acquire_restore(inner_state)
        else:
            self._inner.acquire()
        state.note_acquire(id(self), self._name)

    def _is_owned(self) -> bool:
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def __repr__(self) -> str:
        return f"<TrackedLock {self._name}>"


def track_lock(lock: Any, name: str) -> TrackedLock:
    """Wrap an arbitrary lock so the sanitizer sees its holds."""
    return TrackedLock(lock, name)


# ---------------------------------------------------------------------------
# value digests (torn-read shadow store)
# ---------------------------------------------------------------------------

_DELETED = "<deleted>"


def _digest(value: Any) -> Optional[int]:
    """Cheap content digest, or None when the value can't participate in
    torn-read tracking (unpicklable / too large)."""
    try:
        if isinstance(value, (bytes, bytearray)):
            blob = bytes(value)
        else:
            blob = pickle.dumps(value, protocol=4)
    except Exception:
        return None
    if len(blob) > _DIGEST_CAP_BYTES:
        return None
    return zlib.crc32(blob)


class _KvShadow:
    """Per-KV-instance write-provenance: key -> recent (stamp, digest)
    history, plus the multi-key batches whose per-shard atomicity the
    reader-side check verifies.  All mutation happens under one mutex, so
    a reader either sees a batch fully recorded or not at all (not-at-all
    degrades to 'unknown', which never reports)."""

    def __init__(self) -> None:
        self.mu = threading.Lock()
        self.hist: Dict[str, List[Tuple[int, Optional[int]]]] = {}
        # batch stamp -> {key: shard}; only batches with >=2 keys in some
        # shard are interesting, but recording all is simpler and cheap.
        self.batches: Dict[int, Dict[str, int]] = {}

    def record_batch(self, stamped: Dict[str, Tuple[int, Any]], shards: Dict[str, int]) -> None:
        with self.mu:
            for key, (stamp, value) in stamped.items():
                h = self.hist.setdefault(key, [])
                h.append((stamp, _digest(value)))
                if len(h) > _SHADOW_HISTORY:
                    del h[: len(h) - _SHADOW_HISTORY]
            if stamped:
                stamp = next(iter(stamped.values()))[0]
                self.batches[stamp] = dict(shards)
                if len(self.batches) > 256:
                    for s in sorted(self.batches)[: len(self.batches) - 256]:
                        self.batches.pop(s, None)

    def record_single(self, key: str, value: Any, stamp: int) -> None:
        with self.mu:
            if key not in self.hist:
                return  # only batch-touched keys are tracked
            h = self.hist[key]
            h.append((stamp, _digest(value)))
            if len(h) > _SHADOW_HISTORY:
                del h[: len(h) - _SHADOW_HISTORY]

    def invalidate(self, key: str) -> None:
        with self.mu:
            self.hist.pop(key, None)

    def check_read(self, keys: List[str], values: List[Any], shard_of: Callable[[str], int]) -> Optional[str]:
        """Classify each observed value against the shadow history; report
        a batch whose same-shard keys straddle 'applied' and 'pre-batch'."""
        with self.mu:
            if not self.batches:
                return None
            observed: Dict[str, Optional[int]] = {}
            for k, v in zip(keys, values):
                if k in self.hist:
                    observed[k] = _digest(v)
            for stamp, members in self.batches.items():
                group = [k for k in observed if k in members]
                if len(group) < 2:
                    continue
                by_shard: Dict[int, List[str]] = {}
                for k in group:
                    by_shard.setdefault(shard_of(k), []).append(k)
                for shard, g in by_shard.items():
                    if len(g) < 2:
                        continue
                    applied, stale = [], []
                    for k in g:
                        dig = observed[k]
                        stamps = [s for s, d in self.hist.get(k, []) if d == dig and d is not None]
                        if not stamps:
                            continue  # unknown provenance: never report
                        if max(stamps) >= stamp:
                            applied.append(k)
                        else:
                            stale.append(k)
                    if applied and stale:
                        return (
                            f"torn read: batch@{stamp} on shard {shard} — "
                            f"{applied[0]!r} observed applied but {stale[0]!r} "
                            f"observed pre-batch (per-shard batch atomicity broken)"
                        )
        return None


def _shadow(kv: Any) -> _KvShadow:
    sh = kv.__dict__.get("_san_shadow")
    if sh is None:
        sh = kv.__dict__["_san_shadow"] = _KvShadow()
    return sh


def _finished_mirror(kv: Any) -> Set[str]:
    m = kv.__dict__.get("_san_finished")
    if m is None:
        m = kv.__dict__["_san_finished"] = set()
    return m


# ---------------------------------------------------------------------------
# op interposition
# ---------------------------------------------------------------------------

def _first_key(args: tuple) -> str:
    return args[0] if args and isinstance(args[0], str) else "?"


def _keys_of(op: str, args: tuple) -> List[str]:
    if not args:
        return []
    a0 = args[0]
    if op in ("mget", "mdel") and isinstance(a0, (list, tuple)):
        return [k for k in a0 if isinstance(k, str)]
    if op in ("mset", "eval_many", "rpush_many") and isinstance(a0, dict):
        return [k for k in a0 if isinstance(k, str)]
    if isinstance(a0, str):
        return [a0]
    return []


def _epoch_of(keys: List[str], value: Any) -> Optional[int]:
    if not any(k.startswith((_SCHED_LEASE, _SCHED_EPOCH)) for k in keys):
        return None
    if isinstance(value, dict) and "epoch" in value:
        try:
            return int(value["epoch"])
        except Exception:
            return None
    if isinstance(value, int):
        return value
    return None


def _job_of_task_key(key: str) -> str:
    # manifest keys are "sched/job/<job_id>/{manifest,driver,stage/i,...}" —
    # the job id is the FIRST path segment, unlike task keys below where a
    # job id may itself contain '/' (stage jobs like "mr-x/s0") and the
    # task suffix is the LAST segment.
    if key.startswith(_SCHED_JOB):
        return key[len(_SCHED_JOB):].split("/", 1)[0]
    # task keys are "<prefix><job_id>/t<idx>-<hash>"
    for p in (_SCHED_LEASE, _SCHED_EPOCH, _SCHED_ATTEMPTS):
        if key.startswith(p):
            return key[len(p):].rsplit("/", 1)[0]
    return ""


def _check_blocked_under_lock(op: str, key: str) -> None:
    held = state.held_locks()
    if held:
        state.report(
            "blocked-under-lock",
            f"KV/store round-trip .{op}({key!r}) entered while holding "
            f"{', '.join(held)} — lock scopes must not block",
        )


def _check_unfenced(kv: Any, op: str, args: tuple) -> None:
    keys = _keys_of(op, args)
    if op in ("set", "mset", "cas"):
        bad = [k for k in keys if k.startswith((_SCHED_LEASE, _SCHED_EPOCH))]
        if bad:
            state.report(
                "unfenced-write",
                f"bare .{op} on {bad[0]!r}: lease records install only "
                f"through epoch-compared eval/eval_many; epochs only "
                f"through incr",
            )
        badjob = [k for k in keys if k.startswith(_SCHED_JOB)]
        if badjob:
            state.report(
                "unfenced-write",
                f"bare .{op} on {badjob[0]!r}: manifest/stage/barrier "
                f"records land only through first-writer-wins eval_many "
                f"(jobs.commit_records); the driver lease only through "
                f"term-compared evals",
            )
    elif op in ("delete", "mdel"):
        finished = _finished_mirror(kv)
        for k in keys:
            if not k.startswith(
                (_SCHED_LEASE, _SCHED_EPOCH, _SCHED_ATTEMPTS, _SCHED_JOB)
            ):
                continue
            job = _job_of_task_key(k)
            if job not in finished:
                state.report(
                    "unfenced-write",
                    f".{op} of {k!r} with no sched/finished/{job} tombstone "
                    f"written first — GC must tombstone before deleting",
                )

    # Feed the tombstone mirror.
    if op == "set" and keys and keys[0].startswith(_SCHED_FINISHED):
        _finished_mirror(kv).add(keys[0][len(_SCHED_FINISHED):])
    elif op == "mset" and isinstance(args[0], dict):
        for k in args[0]:
            if isinstance(k, str) and k.startswith(_SCHED_FINISHED):
                _finished_mirror(kv).add(k[len(_SCHED_FINISHED):])


_KV_OPS = (
    "get", "mget", "set", "mset", "setnx", "incr", "cas", "delete", "mdel",
    "exists", "scan", "eval", "eval_many", "rpush", "rpush_many", "lpop",
    "lpop_n", "blpop", "lrange", "llen", "wait_key",
)
_KV_WRITES = {
    "set", "mset", "setnx", "incr", "cas", "delete", "mdel", "eval",
    "eval_many", "rpush", "rpush_many",
}
_STORE_OPS = (
    "put_bytes", "put_many_bytes", "get_bytes", "get_many_bytes", "exists",
    "exists_many", "delete", "delete_many", "delete_prefix", "list", "put",
    "get", "get_many", "put_many", "publish_result", "wait_keys", "wait_put",
)
_BACKEND_OPS = (
    "put", "put_many", "get", "get_many", "exists", "exists_many", "delete",
    "list", "wait_put",
)


def _kv_post(kv: Any, op: str, args: tuple, kwargs: dict, result: Any) -> None:
    """Shadow-store maintenance + torn-read check, after the inner op."""
    shadow = _shadow(kv)
    if op in ("mset", "eval_many"):
        mapping = args[0] if args and isinstance(args[0], dict) else {}
        if len(mapping) >= 2:
            stamp = state.next_stamp()
            if op == "mset":
                values = mapping
            else:
                values = result if isinstance(result, dict) else {}
            stamped = {k: (stamp, values.get(k)) for k in mapping if k in values}
            shards = {k: kv.shard_of(k) for k in stamped}
            shadow.record_batch(stamped, shards)
        else:
            for k in mapping:
                if isinstance(k, str):
                    shadow.invalidate(k)
    elif op == "set" and args:
        shadow.record_single(args[0], args[1] if len(args) > 1 else None, state.next_stamp())
    elif op == "delete" and args:
        shadow.record_single(args[0], _DELETED, state.next_stamp())
    elif op == "mdel" and args and isinstance(args[0], (list, tuple)):
        stamp = state.next_stamp()
        for k in args[0]:
            if isinstance(k, str):
                shadow.record_single(k, _DELETED, stamp)
    elif op in _KV_WRITES:
        # incr/cas/setnx/eval/rpush*: value not cheaply knowable -> the key
        # leaves torn-read tracking rather than risk a stale digest.
        for k in _keys_of(op, args):
            shadow.invalidate(k)
    elif op == "mget" and args and isinstance(args[0], (list, tuple)):
        keys = [k for k in args[0] if isinstance(k, str)]
        if isinstance(result, list) and len(result) == len(keys) and len(keys) >= 2:
            msg = shadow.check_read(keys, result, kv.shard_of)
            if msg:
                state.report("torn-read", msg)


def _record(op: str, args: tuple, result: Any) -> None:
    keys = _keys_of(op, args)
    key = keys[0] if len(keys) == 1 else f"[{len(keys)} keys]" if keys else "?"
    epoch = _epoch_of(keys, result if op in ("eval",) else (args[1] if len(args) > 1 else None))
    state.log_op(op, key, epoch)


def _make_kv_wrapper(cls: type, name: str) -> Callable:
    orig = getattr(cls, name)

    def wrapper(self, *args, **kwargs):
        if not state.enabled:
            return orig(self, *args, **kwargs)
        tls = state._tls
        _check_blocked_under_lock(name, _first_key(args))
        if name in _KV_WRITES:
            _check_unfenced(self, name, args)
        tls.depth += 1
        try:
            result = orig(self, *args, **kwargs)
        finally:
            tls.depth -= 1
        if tls.depth == 0:
            _record(name, args, result)
            _kv_post(self, name, args, kwargs, result)
        return result

    wrapper.__name__ = name
    wrapper.__qualname__ = f"Sanitizing{cls.__name__}.{name}"
    return wrapper


def _make_passthrough_wrapper(cls: type, name: str) -> Callable:
    orig = getattr(cls, name)

    def wrapper(self, *args, **kwargs):
        if not state.enabled:
            return orig(self, *args, **kwargs)
        tls = state._tls
        _check_blocked_under_lock(name, _first_key(args))
        tls.depth += 1
        try:
            result = orig(self, *args, **kwargs)
        finally:
            tls.depth -= 1
        if tls.depth == 0:
            _record(name, args, result)
        return result

    wrapper.__name__ = name
    wrapper.__qualname__ = f"Sanitizing{cls.__name__}.{name}"
    return wrapper


_dyn_cache: Dict[Tuple[type, str], type] = {}


def _dyn_subclass(cls: type, ops: tuple, kind: str) -> type:
    cached = _dyn_cache.get((cls, kind))
    if cached is not None:
        return cached
    make = _make_kv_wrapper if kind == "kv" else _make_passthrough_wrapper
    ns = {
        name: make(cls, name)
        for name in ops
        if name in {n for k in cls.__mro__ for n in k.__dict__}
    }
    ns["_sanitized_"] = True
    dyn = type(f"_Sanitized{cls.__name__}", (cls,), ns)
    # Register under the module so by-value pickling of instances (e.g. a
    # backend handle shipped to a worker) can resolve the class.
    dyn.__module__ = __name__
    dyn.__qualname__ = dyn.__name__
    globals()[dyn.__name__] = dyn
    _dyn_cache[(cls, kind)] = dyn
    return dyn


def _swap(obj: Any, ops: tuple, kind: str) -> Any:
    if getattr(type(obj), "_sanitized_", False):
        return obj
    obj.__class__ = _dyn_subclass(type(obj), ops, kind)
    return obj


# ---------------------------------------------------------------------------
# public wrappers
# ---------------------------------------------------------------------------

def SanitizingKVStore(kv: Any) -> Any:
    """Instrument a ``KVStore``/``FileKVStore`` *in place* (class swap) and
    put its shard locks under tracking.  Returns the same object."""
    state.enabled = True
    _swap(kv, _KV_OPS, "kv")
    for i, sh in enumerate(getattr(kv, "_shards", [])):
        if not isinstance(sh.lock, TrackedLock):
            tracked = TrackedLock(sh.lock, f"kv@{id(kv):x}.shard{i}")
            sh.lock = tracked
            sh.cond = threading.Condition(tracked)
    return kv


def SanitizingBackend(backend: Any) -> Any:
    """Instrument a storage backend (or a whole ``ObjectStore``) in place."""
    state.enabled = True
    from repro.storage.object_store import ObjectStore  # local import: no cycle

    if isinstance(backend, ObjectStore):
        _swap(backend, _STORE_OPS, "store")
        SanitizingBackend(backend.backend)
        return backend
    _swap(backend, _BACKEND_OPS, "backend")
    return backend


def sanitize_scheduler(sched: Any) -> Any:
    """Put a ``Scheduler`` handle's internal lock under tracking."""
    state.enabled = True
    if not isinstance(sched._lock, TrackedLock):
        sched._lock = TrackedLock(sched._lock, f"scheduler@{id(sched):x}._lock")
    return sched


# ---------------------------------------------------------------------------
# blanket install (conftest / REPRO_SANITIZE=1)
# ---------------------------------------------------------------------------

_installed = False


def _hook_init(cls: type, fn: Callable[[Any], Any]) -> None:
    orig = cls.__init__

    def __init__(self, *args, **kwargs):  # noqa: N807
        orig(self, *args, **kwargs)
        # Only the most-derived constructor sanitizes (super().__init__
        # chains pass through untouched; the leaf call finishes the swap).
        if type(self) is cls:
            fn(self)

    __init__.__wrapped_by_sanitizer__ = True
    cls.__init__ = __init__


def install() -> None:
    """Patch every built-in KV/store/backend/scheduler constructor so all
    instances created afterwards are sanitized.  Idempotent."""
    global _installed
    if _installed:
        state.enabled = True
        return
    _installed = True
    state.enabled = True

    from repro.core.scheduler import Scheduler
    from repro.storage.file_kv import FileKVStore
    from repro.storage.kv_store import KVStore
    from repro.storage.net_kv import NetBackend, NetKVStore
    from repro.storage.object_store import FileBackend, InMemoryBackend, ObjectStore

    _hook_init(KVStore, SanitizingKVStore)
    _hook_init(FileKVStore, SanitizingKVStore)
    _hook_init(NetKVStore, SanitizingKVStore)
    _hook_init(NetBackend, SanitizingBackend)
    _hook_init(ObjectStore, SanitizingBackend)
    _hook_init(InMemoryBackend, SanitizingBackend)
    _hook_init(FileBackend, SanitizingBackend)
    _hook_init(Scheduler, sanitize_scheduler)
