"""Analysis tooling: roofline modeling, the ``reprolint`` invariant
checker, and the runtime sanitizer.

``lint`` and ``sanitizer`` are imported lazily (via ``__getattr__``) so
importing :mod:`repro.analysis` for roofline work never pays for them,
and vice versa.
"""

from . import roofline

__all__ = ["roofline", "lint", "sanitizer"]


def __getattr__(name: str):
    if name in ("lint", "sanitizer"):
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
