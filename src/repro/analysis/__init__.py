"""Roofline analysis from compiled XLA artifacts."""

from . import roofline

__all__ = ["roofline"]
