"""reprolint: static invariant checks for the fenced, batched control plane.

PRs 2-5 made the runtime correct and fast through *disciplines* that
nothing enforced until now:

  * every authoritative ``sched/`` mutation is an epoch-compared KV
    transaction (``eval``/``eval_many``/``cas``/``incr``), never a bare
    ``set``/``delete`` — zombies must lose every race (PR 2/4);
  * every fan-out goes through the batched verbs (``mget``/``mset``/
    ``eval_many``/``put_many``/``get_many``/``exists_many``) — request
    count, not bandwidth, is the bottleneck the paper measures (PR 3).
    PR 9's shard-map client surface is held to the same discipline: a
    constant ``kv.``/``ob.`` op through the raw wire verbs
    (``.call``/``.cast``/``.call_rid``) in a loop is the same N-round-trip
    mistake, and a fenced op name (``kv.set`` on ``sched/``) through the
    wire verb is the same fence violation — only the pipelined
    ``start_call``/``finish_call`` scatter and per-key ``watch.*``
    registration are sanctioned;
  * no blocking call (sleep, wait, KV/store round-trip, file I/O) runs
    while a lock is held — the shard condition-wait idiom is the one
    sanctioned exception because ``Condition.wait`` releases its lock;
  * waiting is event-driven (shard watch / store watch), never a naked
    ``time.sleep`` polling loop (PR 2/5);
  * GC writes its tombstone *before* the batched delete, so a concurrent
    writer observes the tombstone instead of resurrecting freed state
    (PR 3/4).

Each rule carries an ID and a fix-it message, and can be waived per line
with an inline escape hatch (same line or the line directly above)::

    # reprolint: disable=RULE001(reason why this site is deliberate)

``lint_source`` / ``lint_path`` / ``lint_tree`` return every
:class:`Finding`, suppressed ones flagged via ``Finding.disabled`` so the
CLI (``tools/reprolint.py``) can hold the disable count against a
baseline file: invariant waivers are allowed to exist but not to grow
silently.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

RULES: Dict[str, str] = {
    "FENCE001": (
        "direct write to the fenced 'sched/' keyspace — authoritative "
        "scheduler state only moves through epoch-compared transactions"
    ),
    "BATCH001": (
        "per-key KV/store round-trip inside a loop — request count is the "
        "bottleneck; one batched call replaces N round-trips"
    ),
    "LOCK001": (
        "blocking call while a lock is held — lock scopes must only touch "
        "local state (Condition.wait is the sanctioned exception)"
    ),
    "EVENT001": (
        "naked time.sleep polling loop — the control plane is event-driven; "
        "wait on a shard/store watch instead"
    ),
    "GC001": (
        "batched delete of shared job state without a preceding tombstone "
        "write in the same function — zombies could resurrect freed keys"
    ),
}

FIXITS: Dict[str, str] = {
    "FENCE001": "use kv.eval/eval_many (epoch-compared CAS), kv.cas, or "
    "kv.incr; bare writes belong only in the blessed Scheduler helpers "
    "(Scheduler.finish_job's tombstone-then-GC path)",
    "BATCH001": "hoist out of the loop and batch: mget/mset/eval_many/"
    "rpush_many (KV) or get_many/put_many/exists_many/delete_many (store)",
    "LOCK001": "move the blocking call outside the `with <lock>` scope, or "
    "wait on a Condition built over the same lock",
    "EVENT001": "block on kv.wait_key/blpop or store.wait_put/wait_keys; "
    "polling belongs only in the watcher fallback (_PollWatcher)",
    "GC001": "write the GC tombstone (sched/finished/ or shuffle-gc/) "
    "before the batched delete, as shuffle.delete_intermediates does",
}

# The one place bare sched/ writes are part of the protocol: finish_job
# writes the sched/finished/ tombstone (idempotent marker, not fenced
# state) and then batch-deletes the job's keys behind it.
_FENCE_BLESSED: Set[Tuple[str, str]] = {("core/scheduler.py", "Scheduler.finish_job")}

_SCHED_PREFIX = "sched/"
# The job-manifest keyspace (core/jobs.py) gets a manifest-specific FENCE001
# message: its blessed mutation paths are jobs.commit_records (first-writer-
# wins eval_many) for manifest/stage/barrier records and the term-compared
# driver-lease evals — plus the same tombstone-then-GC finish_job path.
_JOB_PREFIX = "sched/job/"
_GC_PREFIXES = ("shuffle/", "result/", "input/")
_TOMBSTONE_PREFIXES = ("sched/finished/", "shuffle-gc/")

# Per-key verbs that have a batched counterpart (BATCH001).
_KV_PERKEY = {"get", "set", "rpush", "eval", "delete", "exists"}
_STORE_PERKEY = {
    "put", "get", "exists", "delete",
    "put_bytes", "get_bytes", "publish_result",
}
_BATCH_SUGGEST = {
    "get": "mget / get_many",
    "set": "mset / put_many",
    "rpush": "rpush_many",
    "eval": "eval_many",
    "delete": "mdel / delete_many",
    "exists": "exists_many",
    "put": "put_many",
    "put_bytes": "put_many_bytes",
    "get_bytes": "get_many_bytes",
    "publish_result": "put_many(..., if_absent=True)",
}

# The raw wire surface of the repro-kvd client (net_kv).  A constant
# "kv."/"ob." op through .call/.cast/.call_rid is the same round-trip the
# kv/store verbs wrap, so BATCH001 and FENCE001 see through it.
# `start_call`/`finish_call` are the sanctioned scatter half of a
# shard-map fan-out (N daemons in flight at once, not N serialized
# round-trips) and are never flagged; `watch.*` registration is per-key
# by protocol (refcounted, one op per wait session).
_WIRE_VERBS = {"call", "cast", "call_rid"}
_WIRE_PLANES = ("kv.", "ob.")
_WIRE_FENCED_OPS = {"kv.set", "kv.mset", "kv.delete", "kv.mdel"}

# Every KV/store method that is a storage round-trip (LOCK001).
_ROUNDTRIP_METHODS = {
    "get", "set", "mget", "mset", "setnx", "incr", "cas", "delete", "mdel",
    "exists", "scan", "eval", "eval_many", "rpush", "rpush_many", "lpop",
    "lpop_n", "blpop", "lrange", "llen", "put", "put_bytes", "put_many",
    "put_many_bytes", "get_bytes", "get_many", "get_many_bytes",
    "exists_many", "delete_many", "delete_prefix", "list", "publish_result",
}
_WAIT_METHODS = {"blpop", "wait_key", "wait_keys", "wait_put"}

# Batched delete verbs GC001 watches.
_GC_DELETE_METHODS = {"mdel", "delete_many", "delete_prefix"}
# Write verbs that can plant a tombstone.
_TOMBSTONE_WRITE_METHODS = {"set", "put", "put_bytes", "mset", "put_many"}


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    fixit: str = ""
    disabled: bool = False
    disable_reason: str = ""

    def format(self) -> str:
        tag = " [disabled: %s]" % (self.disable_reason or "no reason") if self.disabled else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}{tag}"


# ---------------------------------------------------------------------------
# disable-comment parsing
# ---------------------------------------------------------------------------

_DISABLE_ITEM = re.compile(r"([A-Z]+\d+)\s*(?:\(([^)]*)\))?")
_DISABLE_RE = re.compile(r"#\s*reprolint:\s*disable=(.+)$")


def _parse_disables(source: str) -> Dict[int, Dict[str, str]]:
    """Map line number -> {rule: reason} for every disable annotation.
    An annotation covers its own line; a comment-only line also covers the
    next line (the common above-the-statement placement)."""
    out: Dict[int, Dict[str, str]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _DISABLE_RE.search(line)
        if not m:
            continue
        rules = {r: (reason or "").strip() for r, reason in _DISABLE_ITEM.findall(m.group(1))}
        if not rules:
            continue
        out.setdefault(lineno, {}).update(rules)
        if line.lstrip().startswith("#"):
            out.setdefault(lineno + 1, {}).update(rules)
    return out


# ---------------------------------------------------------------------------
# small AST helpers
# ---------------------------------------------------------------------------

def _name_chain(node: ast.AST) -> List[str]:
    """``self.kv.set`` -> ["self", "kv", "set"]; unresolvable roots -> "?"."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        parts.append("?")
    return list(reversed(parts))


def _receiver_kind(recv_leaf: str) -> Optional[str]:
    """Classify a call receiver by its trailing identifier."""
    if recv_leaf == "kv" or recv_leaf.endswith("_kv"):
        return "kv"
    if recv_leaf == "store" or recv_leaf.endswith("store"):
        return "store"
    if recv_leaf == "backend":
        return "backend"
    return None


def _is_lockish_name(leaf: str) -> bool:
    return leaf == "lock" or leaf.endswith("lock") or leaf == "cond"


def _is_condish(leaf: str) -> bool:
    return leaf == "cond" or leaf.endswith("cond") or leaf.endswith("condition")


class _FileLinter(ast.NodeVisitor):
    def __init__(self, source: str, path: str) -> None:
        self.source = source
        self.path = path.replace(os.sep, "/")
        self.findings: List[Finding] = []
        self.consts: Dict[str, str] = {}  # module-level string constants
        self.class_stack: List[str] = []
        self.func_stack: List[dict] = []  # {name, tombstone, acquired:set}
        self.loop_depth = 0
        self.while_depth = 0
        self.lock_stack: List[str] = []  # descriptions of held `with` locks
        self.disables = _parse_disables(source)

    # -- entry ----------------------------------------------------------
    def run(self) -> List[Finding]:
        tree = ast.parse(self.source, filename=self.path)
        # First pass: module-level string constants (key-prefix resolution).
        for node in tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name) and isinstance(node.value, ast.Constant) \
                        and isinstance(node.value.value, str):
                    self.consts[tgt.id] = node.value.value
        self.visit(tree)
        return self.findings

    # -- reporting ------------------------------------------------------
    def _report(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        here = self.disables.get(line, {})
        disabled = rule in here
        self.findings.append(
            Finding(
                rule=rule,
                path=self.path,
                line=line,
                col=col,
                message=message,
                fixit=FIXITS[rule],
                disabled=disabled,
                disable_reason=here.get(rule, ""),
            )
        )

    # -- prefix resolution ----------------------------------------------
    def _resolve_prefix(self, node: Optional[ast.AST]) -> Optional[str]:
        """Best-effort static string prefix of a key expression."""
        if node is None:
            return None
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value
        if isinstance(node, ast.Name):
            return self.consts.get(node.id)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            return self._resolve_prefix(node.left)
        if isinstance(node, ast.JoinedStr) and node.values:
            first = node.values[0]
            if isinstance(first, ast.Constant) and isinstance(first.value, str):
                return first.value
        return None

    def _iter_key_exprs(self, arg: Optional[ast.AST]) -> Iterator[ast.AST]:
        """Key expressions reachable in a keys/mapping argument."""
        if arg is None:
            return
        if isinstance(arg, (ast.List, ast.Tuple, ast.Set)):
            yield from arg.elts
        elif isinstance(arg, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
            yield arg.elt
        elif isinstance(arg, ast.Dict):
            for k in arg.keys:
                if k is not None:
                    yield k
        elif isinstance(arg, ast.DictComp):
            yield arg.key
        elif isinstance(arg, ast.BinOp) and isinstance(arg.op, ast.Add):
            yield from self._iter_key_exprs(arg.left)
            yield from self._iter_key_exprs(arg.right)
        else:
            yield arg

    def _key_prefixes(self, arg: Optional[ast.AST]) -> List[str]:
        out = []
        for expr in self._iter_key_exprs(arg):
            p = self._resolve_prefix(expr)
            if p is not None:
                out.append(p)
        return out

    # -- context tracking ------------------------------------------------
    def _qualname(self) -> str:
        names = list(self.class_stack)
        names += [f["name"] for f in self.func_stack]
        return ".".join(names)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_func(self, node) -> None:
        # A nested def/lambda body does not run under the enclosing
        # function's lexical locks (it runs when called), so reset the
        # blocking-context stacks for its body.
        saved = (self.loop_depth, self.while_depth, self.lock_stack)
        self.loop_depth, self.while_depth, self.lock_stack = 0, 0, []
        self.func_stack.append({"name": node.name, "tombstone": False, "acquired": []})
        self.generic_visit(node)
        self.func_stack.pop()
        self.loop_depth, self.while_depth, self.lock_stack = saved

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_For(self, node: ast.For) -> None:
        self.visit(node.target)
        self.visit(node.iter)
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_While(self, node: ast.While) -> None:
        self.visit(node.test)
        self.loop_depth += 1
        self.while_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        self.while_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def _visit_comp(self, node) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            desc = self._lock_desc(item.context_expr)
            if desc is not None:
                self.lock_stack.append(desc)
                pushed += 1
            self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.lock_stack.pop()

    def _lock_desc(self, expr: ast.AST) -> Optional[str]:
        """Is this `with` context a lock scope? Knows attribute locks
        (`self._lock`, `sh.lock`), bare Lock()/RLock()/Condition()
        constructions, and the FileKVStore flock transaction helper
        (`self._txn(...)` = shard thread lock + cross-process flock)."""
        if isinstance(expr, (ast.Attribute, ast.Name)):
            chain = _name_chain(expr)
            if _is_lockish_name(chain[-1]):
                return ".".join(chain)
        if isinstance(expr, ast.Call):
            chain = _name_chain(expr.func)
            if chain[-1] in ("Lock", "RLock", "Condition"):
                return f"{chain[-1]}()"
            if chain[-1] == "_txn":
                return "_txn (shard lock + flock)"
        return None

    def visit_Expr(self, node: ast.Expr) -> None:
        # Track bare X.acquire()/X.release() statements: the scope between
        # them is a held-lock region for the rest of this function body.
        call = node.value
        if isinstance(call, ast.Call) and isinstance(call.func, ast.Attribute):
            chain = _name_chain(call.func)
            recv = ".".join(chain[:-1])
            if chain[-1] == "acquire" and self.func_stack and _is_lockish_name(
                chain[-2] if len(chain) >= 2 else ""
            ):
                self.func_stack[-1]["acquired"].append(recv)
            elif chain[-1] == "release" and self.func_stack:
                acq = self.func_stack[-1]["acquired"]
                if recv in acq:
                    acq.remove(recv)
        self.generic_visit(node)

    # -- the rules -------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        chain = _name_chain(func)
        method = chain[-1]
        recv_leaf = chain[-2] if len(chain) >= 2 else ""
        kind = _receiver_kind(recv_leaf) if len(chain) >= 2 else None

        self._check_fence(node, method, kind)
        self._check_batch(node, method, kind)
        self._check_lock(node, chain, method, recv_leaf, kind)
        self._check_event(node, chain)
        self._check_gc(node, method, kind)
        self._note_tombstone(node, method, kind)

        self.generic_visit(node)

    # FENCE001 ----------------------------------------------------------
    def _check_fence(self, node: ast.Call, method: str, kind: Optional[str]) -> None:
        verb: Optional[str] = None
        key_arg: Optional[ast.AST] = None
        if kind == "kv" and method in ("set", "delete", "mset", "mdel"):
            verb = f"kv.{method}"
            key_arg = node.args[0] if node.args else None
        elif method in ("call", "cast"):
            # The same write reaching the daemon through the raw wire verb
            # bypasses nothing: sched/ stays fenced on every surface.
            op = self._resolve_prefix(node.args[0] if node.args else None)
            if op in _WIRE_FENCED_OPS:
                verb = f'{op} (via .{method})'
                key_arg = node.args[1] if len(node.args) >= 2 else None
        if verb is None:
            return
        prefixes = self._key_prefixes(key_arg)
        if not any(p.startswith(_SCHED_PREFIX) for p in prefixes):
            return
        qual = self._qualname()
        for mod, blessed_qual in _FENCE_BLESSED:
            if self.path.endswith(mod) and qual.startswith(blessed_qual):
                return
        if any(p.startswith(_JOB_PREFIX) for p in prefixes):
            self._report(
                "FENCE001",
                node,
                f"bare {verb} on the job-manifest keyspace "
                f"(prefix {prefixes[0]!r}) — manifest/stage/barrier records "
                "move only through jobs.commit_records (first-writer-wins "
                "eval_many) and the driver lease only through term-compared "
                "evals (jobs.acquire_driver/heartbeat_drivers/release_driver); "
                "deletion only behind Scheduler.finish_job's tombstone",
            )
            return
        self._report(
            "FENCE001",
            node,
            f"bare {verb} on the fenced 'sched/' keyspace "
            f"(prefix {prefixes[0]!r}) — {RULES['FENCE001']}. Fix: {FIXITS['FENCE001']}",
        )

    # BATCH001 ----------------------------------------------------------
    def _check_batch(self, node: ast.Call, method: str, kind: Optional[str]) -> None:
        if self.loop_depth == 0:
            return
        if method in _WIRE_VERBS:
            # One blocking .call per iteration serializes the round-trips
            # the shard map exists to overlap.  watch.* is per-key by
            # protocol; start_call/finish_call (not in _WIRE_VERBS) are
            # the sanctioned pipelined scatter.
            op = self._resolve_prefix(node.args[0] if node.args else None)
            if op is None or not op.startswith(_WIRE_PLANES):
                return
            self._report(
                "BATCH001",
                node,
                f"raw wire .{method}({op!r}) inside a loop — "
                f"{RULES['BATCH001']}. Fix: pipeline the scatter with "
                "start_call/finish_call across daemons, or use the "
                "batched op",
            )
            return
        if kind == "kv" and method in _KV_PERKEY:
            pass
        elif kind in ("store", "backend") and method in _STORE_PERKEY:
            pass
        else:
            return
        suggest = _BATCH_SUGGEST.get(method, "a batched verb")
        self._report(
            "BATCH001",
            node,
            f"per-key .{method} inside a loop — {RULES['BATCH001']}. "
            f"Fix: use {suggest} outside the loop",
        )

    # LOCK001 -----------------------------------------------------------
    def _in_lock_scope(self) -> Optional[str]:
        if self.lock_stack:
            return self.lock_stack[-1]
        if self.func_stack and self.func_stack[-1]["acquired"]:
            return self.func_stack[-1]["acquired"][-1] + " (acquired)"
        return None

    def _check_lock(
        self,
        node: ast.Call,
        chain: List[str],
        method: str,
        recv_leaf: str,
        kind: Optional[str],
    ) -> None:
        held = self._in_lock_scope()
        if held is None:
            return
        blocker: Optional[str] = None
        if chain[-2:] == ["time", "sleep"] or (len(chain) == 1 and method == "sleep"):
            blocker = "time.sleep"
        elif method in _WAIT_METHODS:
            blocker = f".{method}"
        elif method == "wait" and not _is_condish(recv_leaf):
            # Condition.wait releases its lock — the sanctioned idiom; an
            # Event/other .wait under a lock genuinely blocks.
            blocker = ".wait"
        elif kind is not None and method in _ROUNDTRIP_METHODS:
            blocker = f"{kind} round-trip .{method}"
        elif chain[-2:] in (["os", "fsync"], ["os", "sync"]):
            blocker = ".".join(chain)
        elif len(chain) == 1 and method == "open":
            blocker = "open()"
        elif chain[-2:] == ["fcntl", "flock"]:
            # LOCK_UN never blocks; LOCK_EX/LOCK_SH can.
            if not (
                len(node.args) >= 2
                and isinstance(node.args[1], ast.Attribute)
                and node.args[1].attr == "LOCK_UN"
            ):
                blocker = "fcntl.flock"
        if blocker is None:
            return
        self._report(
            "LOCK001",
            node,
            f"{blocker} while holding {held} — {RULES['LOCK001']}. "
            f"Fix: {FIXITS['LOCK001']}",
        )

    # EVENT001 ----------------------------------------------------------
    def _check_event(self, node: ast.Call, chain: List[str]) -> None:
        if self.while_depth == 0:
            return
        if not (chain[-2:] == ["time", "sleep"] or chain == ["sleep"]):
            return
        # The watcher fallback is the one module allowed to poll (it IS the
        # poll-to-event converter); inotify backoff likewise.
        if any("Watcher" in c for c in self.class_stack):
            return
        if self.path.endswith("storage/inotify.py"):
            return
        self._report(
            "EVENT001",
            node,
            f"time.sleep inside a while loop — {RULES['EVENT001']}. "
            f"Fix: {FIXITS['EVENT001']}",
        )

    # GC001 -------------------------------------------------------------
    def _note_tombstone(self, node: ast.Call, method: str, kind: Optional[str]) -> None:
        if not self.func_stack or method not in _TOMBSTONE_WRITE_METHODS:
            return
        arg = node.args[0] if node.args else None
        for expr in self._iter_key_exprs(arg):
            p = self._resolve_prefix(expr)
            if p is not None and p.startswith(_TOMBSTONE_PREFIXES):
                self.func_stack[-1]["tombstone"] = True
                return
            # `store.set(gc_tombstone_key(job), 1)`: the helper names itself.
            target = expr
            if isinstance(target, ast.BinOp) and isinstance(target.op, ast.Add):
                target = target.left
            if isinstance(target, ast.Call):
                fchain = _name_chain(target.func)
                if "tombstone" in fchain[-1]:
                    self.func_stack[-1]["tombstone"] = True
                    return

    def _check_gc(self, node: ast.Call, method: str, kind: Optional[str]) -> None:
        if kind is None or method not in _GC_DELETE_METHODS:
            return
        arg = node.args[0] if node.args else None
        prefixes = self._key_prefixes(arg)
        hit = [p for p in prefixes if p.startswith(_GC_PREFIXES)]
        if not hit:
            return
        if self.func_stack and self.func_stack[-1]["tombstone"]:
            return
        self._report(
            "GC001",
            node,
            f"batched .{method} on {hit[0]!r} with no earlier tombstone "
            f"write in this function — {RULES['GC001']}. Fix: {FIXITS['GC001']}",
        )


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string; returns every finding (disabled included)."""
    return _FileLinter(source, path).run()


def lint_path(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (or a single file)."""
    if os.path.isfile(root):
        return lint_path(root)
    findings: List[Finding] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for name in sorted(filenames):
            if name.endswith(".py"):
                findings.extend(lint_path(os.path.join(dirpath, name)))
    return findings


def active(findings: List[Finding]) -> List[Finding]:
    return [f for f in findings if not f.disabled]


def disabled_counts(findings: List[Finding]) -> Dict[str, int]:
    """Suppressed-finding tally per rule (the baseline currency)."""
    out: Dict[str, int] = {}
    for f in findings:
        if f.disabled:
            out[f.rule] = out.get(f.rule, 0) + 1
    return out
