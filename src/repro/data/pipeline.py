"""Deterministic data pipeline.

Training batches MUST be a pure function of the step index for the stateless
training contract to hold (idempotent re-execution).  We use a counter-mode
PRNG (threefry via jax.random, keyed by (seed, step)) over a synthetic
Zipf-ish corpus, plus a real-text path that tokenizes documents stored in
the object store (used by the word-count/featurization benchmarks and the
e2e example).

Also provides `shard_corpus`: split documents into object-store partitions,
the input format of the BSP jobs.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.storage import ObjectStore


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2  # skew of the synthetic token distribution


def synthetic_batch(dcfg: DataConfig, step: int, cfg: Optional[ModelConfig] = None) -> Dict[str, jnp.ndarray]:
    """Pure function of (config, step): (tokens, labels) + modality stubs.

    Tokens follow a noisy affine Markov chain — next = (31*cur + 17) mod V
    with prob ~0.85, else a zipf-skewed random draw — so there is real,
    learnable sequence structure at any vocab size (a pure-zipf stream is
    nearly uniform for large V and gives models nothing to learn)."""
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    B, S, V = dcfg.global_batch, dcfg.seq_len, dcfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish random draws: exponentiate uniform to skew token ids low
    u = jax.random.uniform(k1, (B, S + 1), minval=1e-6, maxval=1.0)
    rand_toks = jnp.minimum((u ** dcfg.zipf_a * V).astype(jnp.int32), V - 1)
    keep = jax.random.uniform(k2, (B, S + 1)) < 0.85
    x0 = jax.random.randint(k3, (B,), 0, V)

    def chain(x, inp):
        r, k = inp
        nxt = jnp.where(k, (31 * x + 17) % V, r)
        return nxt, nxt

    _, seq = jax.lax.scan(chain, x0, (rand_toks.T, keep.T))
    tokens_all = seq.T  # (B, S+1)
    batch: Dict[str, jnp.ndarray] = {
        "tokens": tokens_all[:, :S],
        "labels": tokens_all[:, 1:],
    }
    if cfg is not None and cfg.frontend == "vision_stub":
        kp = jax.random.fold_in(key, 1)
        batch["prefix_embed"] = (
            jax.random.normal(kp, (B, cfg.num_prefix_tokens, cfg.d_model)) * 0.02
        )
    if cfg is not None and cfg.family == "encdec":
        ka = jax.random.fold_in(key, 2)
        batch["audio_frames"] = (
            jax.random.normal(ka, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
        )
    return batch


# ---------------------------------------------------------------------------
# text corpus utilities (benchmarks / examples)
# ---------------------------------------------------------------------------

_WORDS = (
    "the quick brown fox jumps over lazy dog cloud lambda function stateless "
    "storage elastic server data process compute worker map reduce shuffle "
    "model train serve batch token layer attention expert state scan kernel"
).split()


def make_documents(n_docs: int, lines_per_doc: int, seed: int = 0) -> List[List[str]]:
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        lines = []
        for _ in range(lines_per_doc):
            n = rng.integers(4, 12)
            lines.append(" ".join(rng.choice(_WORDS, size=n)))
        docs.append(lines)
    return docs


def shard_corpus(
    store: ObjectStore, prefix: str, docs: Sequence[List[str]]
) -> List[str]:
    # One batched write for the whole corpus: N document objects land in
    # one amortized round-trip instead of one modeled request each.
    items = {f"{prefix}/doc{i:06d}": list(doc) for i, doc in enumerate(docs)}
    store.put_many(items)
    return list(items.keys())


def tokenize_line(line: str, vocab_size: int) -> List[int]:
    """Stable hash tokenizer (featurization stand-in)."""
    return [
        int.from_bytes(hashlib.sha1(w.encode()).digest()[:4], "little") % vocab_size
        for w in line.split()
    ]
