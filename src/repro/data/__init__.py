"""Deterministic data pipeline (stateless-training contract)."""

from .pipeline import DataConfig, make_documents, shard_corpus, synthetic_batch, tokenize_line

__all__ = ["DataConfig", "synthetic_batch", "make_documents", "shard_corpus", "tokenize_line"]
