"""Assigned architecture registry: `get_config(arch_id)`."""

from typing import Dict

from .base import SHAPES, SUBQUADRATIC_FAMILIES, ModelConfig, ShapeSpec
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .gemma2_27b import CONFIG as gemma2_27b
from .internvl2_1b import CONFIG as internvl2_1b
from .llama3_405b import CONFIG as llama3_405b
from .llama3_8b import CONFIG as llama3_8b
from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .qwen3_32b import CONFIG as qwen3_32b
from .whisper_large_v3 import CONFIG as whisper_large_v3
from .xlstm_1p3b import CONFIG as xlstm_1p3b
from .zamba2_1p2b import CONFIG as zamba2_1p2b

CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        internvl2_1b,
        whisper_large_v3,
        llama3_405b,
        gemma2_27b,
        qwen3_32b,
        llama3_8b,
        zamba2_1p2b,
        deepseek_v3_671b,
        olmoe_1b_7b,
        xlstm_1p3b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in CONFIGS:
        raise KeyError(f"unknown arch '{arch}'; available: {sorted(CONFIGS)}")
    return CONFIGS[arch]


def applicable_shapes(cfg: ModelConfig):
    """The benchmark cells that apply to this arch (long_500k only for
    sub-quadratic families; see DESIGN.md §Arch-applicability)."""
    out = []
    for s in SHAPES.values():
        if s.kind == "long_decode" and cfg.family not in SUBQUADRATIC_FAMILIES:
            continue
        out.append(s)
    return out


__all__ = [
    "CONFIGS",
    "get_config",
    "applicable_shapes",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
]
