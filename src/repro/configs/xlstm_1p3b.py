"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks at 7:1.  48L d_model=2048 4H
d_ff=0 (projections live inside the blocks) vocab=50304.
[arXiv:2405.04517; unverified]"""

from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, slstm_proj_factor=4.0 / 3.0),
)
