"""whisper-large-v3 [audio]: encoder-decoder, conv frontend stubbed
(precomputed 1500-frame embeddings).  32L(+32 enc) d_model=1280 20H (MHA)
d_ff=5120 vocab=51866.  [arXiv:2212.04356; unverified]

Whisper uses absolute positions (sinusoidal enc / learned dec) and full MHA
(kv=20 == heads); no RoPE.  The "32L" of the assignment is the decoder; the
real model pairs it with a 32-layer encoder, included here (override
`n_encoder_layers` to shrink)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    act="gelu",
    pos_embedding="learned",
    tie_embeddings=True,
    frontend="audio_stub",
    encoder_seq=1500,
    max_target_positions=448,
)
