"""qwen3-32b [dense]: GQA kv=8 with per-head q/k RMSNorm.  64L d_model=5120
64H d_ff=25600 vocab=151936.  [hf:Qwen/Qwen3-8B; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
)
