"""gemma2-27b [dense]: local(4096-window)/global alternating attention,
logit softcaps, sandwich norms, tied embeddings.  46L d_model=4608 32H
(kv=16) d_ff=36864 vocab=256000.  [arXiv:2408.00118; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    rope_theta=10_000.0,
    sliding_window=4096,
    global_every=2,          # layers alternate local, global
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    act="gelu",
    tie_embeddings=True,
    norm_scale_offset=True,
    attn_scale=144.0 ** -0.5,  # query_pre_attn_scalar = d_model / n_heads
    embed_scale=True,
)
