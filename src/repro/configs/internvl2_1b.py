"""internvl2-1b [vlm]: InternViT frontend (stub) + InternLM2/Qwen2-class
backbone.  24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
[arXiv:2404.16821; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    rope_theta=1_000_000.0,
    attn_bias=True,          # Qwen2-style QKV bias in the backbone
    tie_embeddings=True,     # 0.5B-class backbones tie embeddings
    frontend="vision_stub",
    num_prefix_tokens=256,   # precomputed ViT patch embeddings per image
)
