"""olmoe-1b-7b [moe]: 64 experts top-8, QK-norm.  16L d_model=2048 16H
(kv=16) d_ff_expert=1024 vocab=50304.  [arXiv:2409.02060; hf]"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    rope_theta=10_000.0,
    qk_norm=True,
    moe=MoEConfig(
        num_experts=64,
        num_shared=0,
        top_k=8,
        d_ff_expert=1024,
        num_dense_layers=0,
        capacity_factor=1.25,
    ),
)
