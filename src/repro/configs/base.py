"""Model/arch configuration system.

One `ModelConfig` describes any architecture in the assigned pool: dense
GQA transformers, MoE (incl. MLA), Mamba2 hybrids, xLSTM, enc-dec, and
modality-stub variants.  `reduced()` derives the CPU smoke-test config.

Input shapes (the assigned benchmark cells) are `ShapeSpec`s; `input_specs`
in launch/dryrun.py turns (config, shape) into ShapeDtypeStructs.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared: int = 0  # shared (always-on) experts
    top_k: int = 1
    d_ff_expert: int = 0
    num_dense_layers: int = 0  # leading layers that stay dense (deepseek: 3)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    group_size: int = 4096  # dispatch group (bounds one-hot memory)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64  # N
    head_dim: int = 64  # P
    expand: int = 2
    conv_kernel: int = 4
    num_groups: int = 2  # B/C groups (G)
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8  # one sLSTM per 8 blocks (7:1 mLSTM:sLSTM)
    proj_factor: float = 2.0  # mLSTM up-projection
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # positional / norm / activation details
    rope_theta: float = 500000.0
    rms_eps: float = 1e-6
    qk_norm: bool = False  # qwen3
    attn_bias: bool = False  # qwen2-style qkv bias (internvl2 backbone)
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None  # gemma2 local layers: 4096
    global_every: int = 0  # gemma2: every 2nd layer is global
    sandwich_norm: bool = False  # gemma2 pre+post norms
    act: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    norm_scale_offset: bool = False  # gemma RMSNorm (1 + w)
    pos_embedding: str = "rope"  # rope | learned (whisper)
    attn_scale: Optional[float] = None  # gemma2 query_pre_attn_scalar
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)

    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # hybrid (zamba2): shared attention block every k ssm layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper frames after conv stub
    max_target_positions: int = 448  # whisper learned pos table (decoder)

    # modality stub
    frontend: str = "none"  # none | audio_stub | vision_stub
    num_prefix_tokens: int = 0  # vlm: patch embeddings prepended

    # MTP (deepseek): extra multi-token-prediction head(s); off in dry-run
    mtp_depth: int = 0

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ---- derived -------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attention_kind(self) -> str:
        if self.mla is not None:
            return "mla"
        return "gqa"

    def layer_kinds(self) -> List[str]:
        """Per-layer block kind, len == n_layers (+ encoder handled apart)."""
        kinds: List[str] = []
        for i in range(self.n_layers):
            if self.family in ("dense", "vlm", "encdec"):
                if self.sliding_window and self.global_every:
                    kinds.append("attn_local" if i % self.global_every != self.global_every - 1 else "attn_global")
                else:
                    kinds.append("attn_global")
            elif self.family == "moe":
                nd = self.moe.num_dense_layers if self.moe else 0
                kinds.append("attn_dense" if i < nd else "attn_moe")
            elif self.family == "hybrid":
                kinds.append("mamba")
            elif self.family == "ssm":
                per = self.xlstm.slstm_every if self.xlstm else 8
                kinds.append("slstm" if i % per == per - 1 else "mlstm")
            else:
                raise ValueError(self.family)
        return kinds

    # ---- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_count(self) -> Tuple[int, int]:
        """Returns (total_params, active_params) — differ only for MoE."""
        D, F, V, H, K, hd = (
            self.d_model, self.d_ff, self.vocab_size,
            self.n_heads, self.n_kv_heads, self.hd,
        )
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind.startswith("attn"):
                if self.mla is not None:
                    m = self.mla
                    a = (
                        D * m.q_lora_rank
                        + m.q_lora_rank * H * (m.nope_head_dim + m.rope_head_dim)
                        + D * (m.kv_lora_rank + m.rope_head_dim)
                        + m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
                        + H * m.v_head_dim * D
                    )
                else:
                    a = D * H * hd + 2 * D * K * hd + H * hd * D
                total += a
                active += a
                if kind == "attn_moe":
                    m = self.moe
                    fe = m.d_ff_expert
                    router = D * m.num_experts
                    experts = m.num_experts * 3 * D * fe
                    shared = m.num_shared * 3 * D * fe
                    total += router + experts + shared
                    active += router + m.top_k * 3 * D * fe + shared
                else:
                    total += 3 * D * F
                    active += 3 * D * F
            elif kind == "mamba":
                s = self.ssm
                d_in = s.expand * D
                nh = d_in // s.head_dim
                conv_dim = d_in + 2 * s.num_groups * s.state_dim
                a = (
                    D * (2 * d_in + 2 * s.num_groups * s.state_dim + nh)
                    + conv_dim * s.conv_kernel
                    + 3 * nh
                    + d_in
                    + d_in * D
                )
                total += a
                active += a
            elif kind == "mlstm":
                x = self.xlstm
                d_in = int(x.proj_factor * D)
                hd_in = d_in // self.n_heads
                # headwise (block-diagonal) q/k/v projections, xLSTM-style
                a = D * 2 * d_in + 3 * d_in * hd_in + 2 * d_in + d_in * D
                total += a
                active += a
            elif kind == "slstm":
                x = self.xlstm
                nh = self.n_heads
                hd_s = D // nh
                f = int(x.slstm_proj_factor * D)
                a = 4 * D * D + 4 * nh * hd_s * hd_s + 3 * D * f
                total += a
                active += a
        # hybrid shared attention block (one set of weights)
        if self.shared_attn_every:
            a = (2 * D) * H * hd + 2 * (2 * D) * K * hd + H * hd * D + 3 * D * self.d_ff
            total += a
            active += a
        # encoder
        if self.n_encoder_layers:
            per = 4 * D * D + 3 * D * F  # MHA + (gelu MLP ~2 mats) approx 3
            cross = 4 * D * D * self.n_layers  # decoder cross-attn
            total += self.n_encoder_layers * per + cross
            active += self.n_encoder_layers * per + cross
        return int(total), int(active)

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/features, tiny dims."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=16 if self.n_encoder_layers else self.encoder_seq,
            num_prefix_tokens=4 if self.frontend == "vision_stub" else 0,
            dtype="float32",
            param_dtype="float32",
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe, num_experts=8, top_k=2, d_ff_expert=64,
                num_dense_layers=min(self.moe.num_dense_layers, 1), group_size=64,
            )
        if self.mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, rope_head_dim=16,
                nope_head_dim=32, v_head_dim=32,
            )
        if self.ssm:
            kw["ssm"] = replace(self.ssm, state_dim=16, head_dim=16, chunk=16)
        if self.xlstm:
            kw["xlstm"] = replace(self.xlstm, slstm_every=4)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 8
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long_decode"),
}

# archs for which long_500k is applicable (sub-quadratic sequence mixing)
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")
