"""zamba2-1.2b [hybrid]: Mamba2 backbone + one *shared* attention block
(weights reused) invoked every 6 layers on concat(hidden, embeddings).
38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
[arXiv:2411.15242; hf]"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4, num_groups=2),
    shared_attn_every=6,
)
