"""deepseek-v3-671b [moe]: MLA (compressed-latent KV, decoupled RoPE),
1 shared + 256 routed experts top-8, first 3 layers dense, MTP.
61L d_model=7168 128H d_ff_expert=2048 vocab=129280.
[arXiv:2412.19437; hf]

d_ff=18432 is the dense-layer/shared-path MLP width (DeepSeek-V3 config);
the assigned `d_ff=2048` is the per-expert width (`moe.d_ff_expert`)."""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=256,
        num_shared=1,
        top_k=8,
        d_ff_expert=2048,
        num_dense_layers=3,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    mtp_depth=1,
)
