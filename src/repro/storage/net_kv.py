"""Wire-protocol KV/object tier: the ``repro-kvd`` client side.

The paper's closing bet is that stateless functions over fast
*disaggregated storage* is the natural substrate (§5), and Cloudburst
shows FaaS becomes viable for stateful work exactly when the shared KV
tier is low-latency.  PR 5 made the file substrate fast on shared disk;
this module takes the next step: a real socket server (``repro-kvd``,
see :mod:`.net_server`) with the log-structured engine as its
persistence, and :class:`NetKVStore` / :class:`NetBackend` clients that
preserve the batched contract — one frame per ``mset`` / ``mget`` /
``eval_many`` / ``rpush_many`` / ``get_many`` / ``put_many``, same
request-charging model, so the perf ledger and the BATCH001 reasoning
carry over unchanged.

Framing
-------
Every message is one PR-5 frame: ``[u32 payload length][u32 crc32]``
followed by a pickled payload (``_FRAME_HDR`` from :mod:`.kv_store` —
the exact bytes the shard logs use).  Messages:

==================================================  =======================================
``("req",  rid, op, args, kwargs)``                  client → server request
``("res",  rid, value)``                             server → client response
``("err",  rid, etype, msg)``                        server → client op failure
``("sub",  client_id, topics[, opts])``              client → server handshake/subscribe
``("hello", info)``                                  server → client handshake reply
``("kv",   shard, srv_seq, keys|None)``              pushed KV watch event (keyed wake)
``("obj",  srv_seq, keys|None)``                     pushed object-store watch event
==================================================  =======================================

Requests are pipelined: any number may be in flight on one socket, each
carrying a client-unique ``rid``; worker threads share one connection
and block only on their own response.  Requests are cloudpickled (they
carry ``eval`` closures); responses and events are plain pickles.

Zero-copy buffer frames
-----------------------
Large bytes-like payloads (ndarray blobs, checkpoint shards, KV-cache
blocks) never travel through the pickle codec.  A message whose args or
result carry a bytes-like value of at least :data:`ZERO_COPY_MIN` is
split: each large payload becomes a **buffer frame** — the same
``[u32 length][u32 crc32]`` header with :data:`~.kv_store.BUF_FLAG`
(bit 31) set on the length, followed by the raw bytes — sent *before*
its control frame, whose pickle holds a tiny :class:`_WireBuf` index in
the payload's place.  The sender gathers header + raw ``memoryview``
segments with ``socket.sendmsg`` (no join, no copy); the receiver's
decoder, on seeing a torn buffer frame, allocates the payload's final
bytearray once and the pump ``recv_into``\\ s the socket straight into
it.  ``bind_buffers`` splices the raw payloads back into the decoded
message, so both ends hand the bytes over without ever copying them
through pickle.  Bit 31 is unambiguous: real lengths are capped at
``MAX_FRAME_LEN`` (1 << 30).

Shard maps: multi-daemon scale-out
----------------------------------
:class:`NetKVStore` / :class:`NetBackend` accept a **shard map** — a
comma-joined address string or list of addresses naming N ``repro-kvd``
daemons.  Keys route to a daemon by a hash decorrelated from the
server-side shard hash, and the client's global shard space is the
concatenation of every daemon's shards (daemon d's shard s is global
shard ``base[d] + s``), so the per-shard charging/watch machinery is
unchanged.  Each daemon gets its own connection pair with independent
reconnect/resync: one daemon's crash degrades only its shards — calls
touching the survivors never block, and watch re-registration on the
dead daemon resumes when it returns.  A single address is the N=1
degenerate case and routes byte-for-byte like PR 8.

Pushed watch events replace client-side polling entirely: the server
tracks per-shard sequences and streams *keyed* wake frames —
``puts_since``-style for the KV too — so ``wait_key`` / ``blpop`` /
``wait_keys`` / futures stay event-driven across machines with zero
fallback ticks.

``eval`` over the wire: deterministic replay
--------------------------------------------
Scheduler transactions pass closures that *mutate captured state*
(``out["rec"] = cur``) — shipping the closure one way would lose those
side effects.  The protocol therefore runs every update function twice
on the same input: the server applies ``fn(old)`` atomically inside the
shard transaction and returns ``old`` (post-``default``); the client
replays ``fn(old)`` locally, reproducing side effects and the return
value exactly.  Update functions must be deterministic in their
argument — every fenced transaction in the runtime is.

Failure model
-------------
Ops are at-least-once: a connection that dies with requests in flight is
redialed (bounded backoff) and the unacknowledged requests are resent in
order, so a request the server committed just before the crash may
execute twice.  Destructive reads are the exception — a replayed
``lpop_n`` would *lose* the first pop's items — so the server journals
non-empty pop results under ``net-ack/{client}/{rid}`` in the popped
key's own shard transaction and replays return the journaled items (the
client retires ack records with its next pop of the same key).
Everything else is absorbed one level up exactly as for zombie workers:
deterministic task ids, lease-time duplicate drops, ``if_absent`` result
publishes, and epoch fencing make task effects exactly-once over
at-least-once wire ops.

On reconnect the client compares the server's ``hello`` (generation +
per-shard sequences) with what it last saw and conservatively wakes
every local waiter with *unknown* keys — waiters re-probe their
predicate once, so a wake can never be lost across a server restart.

Like Redis without AUTH, the protocol is for trusted networks only: it
is pickle over a socket (arbitrary code execution by design — ``eval``
ships closures), so bind the server to localhost or a private network.
"""

from __future__ import annotations

import itertools
import pickle
import socket
import threading
import time
import uuid
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import cloudpickle

from .kv_store import BUF_FLAG, DELETE, KVStore, _FRAME_HDR, _sizeof
from .object_store import Ledger, _Backend
from .perf_model import REDIS_2017, StorageProfile

# A frame's payload may carry a whole batched put — generous cap, but an
# adversarial/corrupt header claiming more fails fast without allocating.
MAX_FRAME_LEN = 1 << 30

# Bytes-like payloads at least this large ride out-of-band buffer frames
# instead of the pickle codec.  Below it, one small pickle is cheaper than
# an extra frame header + scatter-gather bookkeeping.
ZERO_COPY_MIN = 64 * 1024


class _WireBuf:
    """Placeholder left in a pickled message where a large bytes-like
    payload was extracted into an out-of-band buffer frame; carries only
    the payload's index in the frame's buffer list."""

    __slots__ = ("idx",)

    def __init__(self, idx: int) -> None:
        self.idx = idx

    def __reduce__(self):
        return (_WireBuf, (self.idx,))


def _as_byte_view(obj) -> memoryview:
    view = obj if isinstance(obj, memoryview) else memoryview(obj)
    if view.ndim != 1 or view.format != "B":
        view = view.cast("B")
    return view


def extract_buffers(obj: Any, buffers: List[memoryview], min_bytes: int = ZERO_COPY_MIN) -> Any:
    """Walk ``obj`` (tuples/lists/dicts of anything), pulling every
    bytes-like leaf of at least ``min_bytes`` out into ``buffers`` and
    leaving a :class:`_WireBuf` index in its place.  Small ``memoryview``
    leaves are normalized to ``bytes`` (memoryviews don't pickle).  The
    input structure is never mutated — new containers are built on the
    extraction path."""
    if isinstance(obj, (bytes, bytearray, memoryview)):
        view = _as_byte_view(obj)
        if view.nbytes >= min_bytes:
            buffers.append(view)
            return _WireBuf(len(buffers) - 1)
        return bytes(obj) if isinstance(obj, memoryview) else obj
    if isinstance(obj, tuple):
        return tuple(extract_buffers(v, buffers, min_bytes) for v in obj)
    if isinstance(obj, list):
        return [extract_buffers(v, buffers, min_bytes) for v in obj]
    if isinstance(obj, dict):
        return {k: extract_buffers(v, buffers, min_bytes) for k, v in obj.items()}
    return obj


def bind_buffers(obj: Any, buffers: List[Any]) -> Any:
    """Inverse of :func:`extract_buffers`: splice received raw buffer
    payloads back over their :class:`_WireBuf` placeholders."""
    if isinstance(obj, _WireBuf):
        try:
            return buffers[obj.idx]
        except IndexError:
            raise ProtocolError(
                f"buffer placeholder #{obj.idx} without a matching buffer frame"
            )
    if isinstance(obj, tuple):
        return tuple(bind_buffers(v, buffers) for v in obj)
    if isinstance(obj, list):
        return [bind_buffers(v, buffers) for v in obj]
    if isinstance(obj, dict):
        return {k: bind_buffers(v, buffers) for k, v in obj.items()}
    return obj


def _daemon_of(key: str, n: int) -> int:
    """Which daemon of an N-entry shard map owns ``key``.  The hash is
    salted to decorrelate it from the server-side ``crc32(key) % shards``
    routing — the unsalted hash would alias with it and leave some server
    shards permanently cold."""
    if n == 1:
        return 0
    return zlib.crc32(b"d~" + key.encode()) % n


def _addr_str(addr: Tuple[str, int]) -> str:
    host, port = addr
    return host if host.startswith("unix:") else f"{host}:{port}"


class ProtocolError(Exception):
    """Malformed wire data (bad CRC, oversized length, undecodable
    payload).  The peer that sent it gets its connection closed — never a
    crash, never a partially applied transaction (ops only execute on
    whole, valid frames)."""


class RemoteError(RuntimeError):
    """A server-side op raised; carries ``etype`` (the remote exception
    class name) and the stringified message."""

    def __init__(self, etype: str, msg: str) -> None:
        super().__init__(f"{etype}: {msg}")
        self.etype = etype


def encode_wire(obj: Any, *, pickler=pickle) -> bytes:
    """One message → one frame (same header as the shard logs)."""
    payload = pickler.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return _FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload


def encode_wire_parts(
    obj: Any, buffers: List[memoryview], *, pickler=pickle
) -> List[Any]:
    """One message + its extracted buffers → a list of byte segments for a
    gathered send.  Buffer frames travel *before* the control frame, so the
    receiver has every raw payload in hand when the pickled message that
    references them decodes.  The segments are headers (bytes) interleaved
    with the raw payload ``memoryview``\\ s — nothing large is joined or
    copied here."""
    parts: List[Any] = []
    for view in buffers:
        parts.append(_FRAME_HDR.pack(BUF_FLAG | view.nbytes, zlib.crc32(view)))
        parts.append(view)
    payload = pickler.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    parts.append(_FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload)
    return parts


# sendmsg gathers at most IOV_MAX segments per call (1024 on Linux); stay
# far under it so one oversized batch can never fail outright.
_SENDMSG_SEGS = 64


def _sendall_parts(sock: socket.socket, parts: List[Any]) -> None:
    """Gathered ``sendall``: pushes every segment with ``socket.sendmsg``,
    advancing through partial sends, so large payload views go to the
    kernel without ever being joined into one contiguous frame."""
    segs = [_as_byte_view(p) for p in parts]
    i = 0
    while i < len(segs):
        batch = segs[i : i + _SENDMSG_SEGS]
        sent = sock.sendmsg(batch)
        for s in batch:
            if sent >= s.nbytes:
                sent -= s.nbytes
                i += 1
            else:
                segs[i] = s[sent:]
                break


class FrameDecoder:
    """Incremental frame decoder for a byte stream.

    ``feed(data)`` returns every whole message that became available.  A
    partial frame simply waits for more bytes (torn frames are the normal
    state of a socket mid-read); corrupt input — CRC mismatch, a length
    over ``max_frame``, an unpicklable payload — raises
    :class:`ProtocolError` and poisons the decoder (the connection is
    dead; resynchronizing inside a corrupt pickle stream is hopeless).

    Buffer frames (``BUF_FLAG`` on the length word) carry raw bytes, not
    pickles: their payloads accumulate and are spliced into the *next*
    pickled message over its :class:`_WireBuf` placeholders.  A torn
    buffer frame flips the decoder into **fill mode** — the payload's
    final ``bytearray`` is allocated once and the owner pumps the socket
    straight into it (``wanted()`` / ``fill_view()`` / ``filled(n)``), so
    an 8 MiB array crosses the receive path with zero intermediate
    copies.  ``bytes_pickled`` / ``bytes_buffer`` count payload bytes by
    path, which is what the zero-copy conformance pin measures."""

    def __init__(self, max_frame: int = MAX_FRAME_LEN) -> None:
        self._buf = bytearray()
        self._max = max_frame
        self._poisoned = False
        self._bufs: List[Any] = []  # raw payloads awaiting their message
        self._fill: Optional[bytearray] = None  # torn buffer frame target
        self._fill_got = 0
        self._fill_crc = 0
        self.bytes_pickled = 0
        self.bytes_buffer = 0

    # ---- fill mode: recv_into the payload's final buffer -----------------
    def wanted(self) -> int:
        """Bytes the active torn-buffer-frame fill still needs (0: none)."""
        return 0 if self._fill is None else len(self._fill) - self._fill_got

    def fill_view(self) -> memoryview:
        """Writable view of the unfilled payload region — hand it to
        ``sock.recv_into`` and report the count via :meth:`filled`."""
        return memoryview(self._fill)[self._fill_got :]

    def filled(self, n: int) -> None:
        self._fill_got += n
        try:
            self._finish_fill()
        except ProtocolError:
            self._poisoned = True
            raise

    def _finish_fill(self) -> None:
        if self._fill is None or self._fill_got < len(self._fill):
            return
        if zlib.crc32(self._fill) != self._fill_crc:
            raise ProtocolError("buffer frame CRC mismatch")
        self.bytes_buffer += len(self._fill)
        self._bufs.append(self._fill)
        self._fill = None
        self._fill_got = 0

    # ---- stream feed ------------------------------------------------------
    def feed(self, data) -> List[Any]:
        if self._poisoned:
            raise ProtocolError("decoder poisoned by earlier corrupt frame")
        out: List[Any] = []
        try:
            if self._fill is not None:
                # Route bytes into the active fill first; residual bytes
                # (frames behind the buffer payload) fall through below.
                view = _as_byte_view(data)
                take = min(view.nbytes, len(self._fill) - self._fill_got)
                self._fill[self._fill_got : self._fill_got + take] = view[:take]
                self._fill_got += take
                self._finish_fill()
                if self._fill is not None:
                    return out
                data = view[take:]
            self._buf += data
            off = 0
            buf = self._buf
            hdr = _FRAME_HDR.size
            while len(buf) - off >= hdr:
                word, crc = _FRAME_HDR.unpack_from(buf, off)
                is_buffer = bool(word & BUF_FLAG)
                length = word & ~BUF_FLAG
                if length > self._max:
                    raise ProtocolError(
                        f"frame length {length} exceeds cap {self._max}"
                    )
                end = off + hdr + length
                if is_buffer and len(buf) < end:
                    # Torn buffer frame: allocate the final payload buffer
                    # and move whatever already arrived into it; the owner
                    # recv_intos the rest.
                    self._fill = target = bytearray(length)
                    got = len(buf) - off - hdr
                    target[:got] = buf[off + hdr :]
                    self._fill_got = got
                    self._fill_crc = crc
                    off = len(buf)
                    break
                if len(buf) < end:
                    break  # torn frame: wait for more bytes
                if is_buffer:
                    payload = bytearray(buf[off + hdr : end])
                    if zlib.crc32(payload) != crc:
                        raise ProtocolError("buffer frame CRC mismatch")
                    self.bytes_buffer += length
                    self._bufs.append(payload)
                    off = end
                    continue
                payload = bytes(buf[off + hdr : end])
                if zlib.crc32(payload) != crc:
                    raise ProtocolError("frame CRC mismatch")
                try:
                    msg = pickle.loads(payload)
                except ProtocolError:
                    raise
                except Exception as exc:
                    raise ProtocolError(f"undecodable frame payload: {exc!r}")
                self.bytes_pickled += length
                if self._bufs:
                    msg = bind_buffers(msg, self._bufs)
                    self._bufs = []
                out.append(msg)
                off = end
        except ProtocolError:
            self._poisoned = True
            raise
        del self._buf[:off]
        return out


def parse_addr(address) -> Tuple[str, int]:
    """``"host:port"`` / ``(host, port)`` / ``"unix:/path"`` → ``(host,
    port)``.  A Unix-domain address keeps the whole ``unix:...`` string as
    the host (port 0) — same-host clusters skip the TCP stack entirely."""
    if isinstance(address, (tuple, list)):
        return str(address[0]), int(address[1])
    address = str(address)
    if address.startswith("unix:"):
        return address, 0
    host, _, port = address.rpartition(":")
    if not host:
        raise ValueError(f"address must be host:port or unix:/path, got {address!r}")
    return host, int(port)


def parse_shard_map(address) -> List[Tuple[str, int]]:
    """A single address → ``[(host, port)]``; a comma-joined string or a
    list of addresses → one endpoint per daemon.  Shard-map ORDER IS THE
    TOPOLOGY: it defines both the daemon hash ring and the global shard
    numbering, so every client of a cluster must use the same ordered
    map."""
    if isinstance(address, (tuple, list)):
        if (
            len(address) == 2
            and isinstance(address[0], str)
            and isinstance(address[1], int)
        ):
            return [parse_addr(address)]
        return [parse_addr(a) for a in address]
    address = str(address)
    if "," in address:
        return [parse_addr(a.strip()) for a in address.split(",") if a.strip()]
    return [parse_addr(address)]


class _Call:
    """One in-flight request: its encoded frame segments (kept for resend
    after a reconnect — the payload views stay valid because the caller
    blocks until the call completes), its completion state, and its
    private wake event — the pump wakes exactly the caller a response
    belongs to, never the herd."""

    __slots__ = ("parts", "done", "value", "error", "event")

    def __init__(self, parts: List[Any]) -> None:
        self.parts = parts
        self.done = False
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()


def _dial(
    host: str,
    port: int,
    client_id: str,
    topics: Tuple[str, ...],
    timeout_s: float,
    *,
    zero_copy: bool = False,
) -> Tuple[socket.socket, Dict[str, Any], FrameDecoder, List[Any]]:
    """Connect + handshake: send ``sub``, block for ``hello``.  Returns the
    socket, the hello payload, the stream decoder (already fed), and any
    messages that arrived behind the hello."""
    if host.startswith("unix:"):
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout_s)
        sock.connect(host[len("unix:"):])
    else:
        sock = socket.create_connection((host, port), timeout=timeout_s)
    try:
        if sock.family != socket.AF_UNIX:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.sendall(
            encode_wire(("sub", client_id, list(topics), {"zero_copy": bool(zero_copy)}))
        )
        dec = FrameDecoder()
        msgs: List[Any] = []
        while not msgs:
            data = sock.recv(1 << 16)
            if not data:
                raise OSError("server closed during handshake")
            msgs = dec.feed(data)
        hello = msgs[0]
        if not (isinstance(hello, tuple) and hello and hello[0] == "hello"):
            raise OSError(f"expected hello, got {hello!r}")
        sock.settimeout(None)
    except BaseException:
        sock.close()
        raise
    return sock, dict(hello[1]), dec, msgs[1:]


class _EventChannel:
    """The push plane: a second socket subscribed to watch topics, pumped
    by a background reader thread.  Kept separate from the request socket
    so the request path needs no reader-thread handoff (see
    :class:`NetClient`) while pushed wakes still arrive when the client is
    idle.  On connection loss it redials with bounded backoff and fires
    ``on_reconnect`` — waiters then re-probe, so no wake is ever lost to a
    server restart."""

    def __init__(
        self,
        host: str,
        port: int,
        client_id: str,
        topics: Tuple[str, ...],
        on_event: Callable[[tuple], None],
        on_reconnect: Optional[Callable[[dict], None]],
        closed: threading.Event,
        *,
        connect_timeout_s: float,
        retry_max_s: float,
    ) -> None:
        self._host, self._port = host, port
        self._client_id = client_id
        self._topics = topics
        self._on_event = on_event
        self._on_reconnect = on_reconnect
        self._closed = closed
        self._connect_timeout_s = connect_timeout_s
        self._retry_max_s = retry_max_s
        self.reconnects = 0
        self._sock, self.hello, self._decoder, backlog = _dial(
            host, port, client_id, topics, connect_timeout_s
        )
        for m in backlog:
            self._on_event(m)
        self._thread = threading.Thread(
            target=self._run, daemon=True, name=f"netkv-events-{port}"
        )
        self._thread.start()

    def _run(self) -> None:
        while not self._closed.is_set():
            try:
                data = self._sock.recv(1 << 16)
            except OSError:
                data = b""
            if data:
                try:
                    msgs = self._decoder.feed(data)
                except ProtocolError:
                    self._redial()
                    continue
                for m in msgs:
                    self._on_event(m)
                continue
            if self._closed.is_set():
                return
            self._redial()

    def _redial(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
        backoff = 0.005
        while not self._closed.is_set():
            try:
                self._sock, self.hello, self._decoder, backlog = _dial(
                    self._host,
                    self._port,
                    self._client_id,
                    self._topics,
                    self._connect_timeout_s,
                )
            except OSError:
                self._closed.wait(backoff)
                backoff = min(backoff * 2.0, self._retry_max_s)
                continue
            self.reconnects += 1
            # Resync: wake the owner's waiters with unknown keys — anything
            # may have happened (or a whole new server generation booted)
            # while this channel was down.
            if self._on_reconnect is not None:
                self._on_reconnect(self.hello)
            for m in backlog:
                self._on_event(m)
            return

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        if threading.current_thread() is not self._thread:
            self._thread.join(timeout=2.0)


class NetClient:
    """A pipelined connection pair to a ``repro-kvd`` server.

    Thread-safe: any number of threads may :meth:`call` concurrently;
    requests interleave on the request socket and each caller blocks only
    on its own response.  Responses are demultiplexed *by the callers
    themselves* (leader/follower): whichever waiting caller holds the pump
    baton recvs and dispatches until its own response arrives, then hands
    the baton to a waiting follower.  On the hot path — one caller, answer
    already in flight — a response costs zero thread handoffs, which is
    what keeps a wire op in the same latency class as a local disk
    transaction.  Pushed watch events ride a separate
    :class:`_EventChannel` socket with a background reader, so wakes
    arrive even when no call is in flight.

    On connection loss the pumping caller redials with bounded backoff and
    re-sends every unacknowledged request in rid order (at-least-once —
    see the module docstring)."""

    def __init__(
        self,
        host: str,
        port: int,
        *,
        topics: Tuple[str, ...] = (),
        on_event: Optional[Callable[[tuple], None]] = None,
        on_reconnect: Optional[Callable[[dict], None]] = None,
        connect_timeout_s: float = 10.0,
        retry_max_s: float = 0.2,
        zero_copy: bool = True,
    ) -> None:
        self.host, self.port = host, port
        self.client_id = uuid.uuid4().hex
        self._connect_timeout_s = connect_timeout_s
        self._retry_max_s = retry_max_s
        self._zero_copy = bool(zero_copy)
        self._rid = itertools.count(1)
        self._pending: Dict[int, _Call] = {}
        self._state_lock = threading.Lock()
        self._send_lock = threading.Lock()
        self._pumping = False
        self._closed = threading.Event()
        self._req_reconnects = 0
        # Copied-vs-raw byte accounting for the request socket, both
        # directions; the conformance suite pins the zero-copy ratio on it.
        self._sent_pickled = 0
        self._sent_buffer = 0
        self._recv_pickled_base = 0
        self._recv_buffer_base = 0
        self.hello: Dict[str, Any] = {}
        deadline = time.monotonic() + connect_timeout_s
        backoff = 0.01
        while True:  # cover the race with a server that is still binding
            try:
                self._sock, self.hello, self._decoder, _ = _dial(
                    host,
                    port,
                    self.client_id,
                    (),
                    connect_timeout_s,
                    zero_copy=self._zero_copy,
                )
                break
            except OSError as exc:
                if time.monotonic() >= deadline:
                    raise ConnectionError(
                        f"repro-kvd at {host}:{port} unreachable: {exc}"
                    ) from exc
                self._closed.wait(backoff)
                backoff = min(backoff * 2.0, retry_max_s)
        self._topics = tuple(topics)
        self._on_event = on_event
        self._on_reconnect = on_reconnect
        self._events: Optional[_EventChannel] = None
        self._events_lock = threading.Lock()

    def ensure_events(self) -> Optional[Dict[str, Any]]:
        """Dial the push channel if it is not up yet (it is lazy: a client
        that never waits never receives a single event frame).  Returns the
        channel's ``hello`` when this call created it — the caller must
        resync against its sequences, because anything that happened before
        this moment was never pushed — and ``None`` when it already ran."""
        if self._events is not None or not self._topics or self._on_event is None:
            return None
        with self._events_lock:
            if self._events is not None:
                return None
            channel = _EventChannel(
                self.host,
                self.port,
                self.client_id,
                self._topics,
                self._on_event,
                self._on_reconnect,
                self._closed,
                connect_timeout_s=self._connect_timeout_s,
                retry_max_s=self._retry_max_s,
            )
            self._events = channel
            return dict(channel.hello)

    @property
    def reconnects(self) -> int:
        return self._req_reconnects + (self._events.reconnects if self._events else 0)

    @property
    def bytes_pickled(self) -> int:
        """Payload bytes that crossed the request socket through the pickle
        codec, both directions.  With zero-copy on, a large array put/get
        moves almost everything through :attr:`bytes_buffer` instead —
        the structural pin behind the 'no copies through the codec'
        acceptance row."""
        return self._sent_pickled + self._recv_pickled_base + self._decoder.bytes_pickled

    @property
    def bytes_buffer(self) -> int:
        """Payload bytes that crossed the request socket as raw buffer
        frames (memoryview out, recv_into in), both directions."""
        return self._sent_buffer + self._recv_buffer_base + self._decoder.bytes_buffer

    # ---- request plane ---------------------------------------------------
    def call(self, op: str, *args: Any, **kwargs: Any) -> Any:
        return self.call_rid(op, *args, **kwargs)[1]

    def call_rid(self, op: str, *args: Any, **kwargs: Any) -> Tuple[int, Any]:
        """Issue one request; block for its response.  Returns ``(rid,
        value)`` — destructive reads use the rid as their server-side ack
        token.  Survives any number of reconnects in between; raises only
        a remapped server error or ``ConnectionError`` after close."""
        rid, call = self.start_call(op, *args, **kwargs)
        return rid, self.finish_call((rid, call))

    def start_call(self, op: str, *args: Any, **kwargs: Any) -> Tuple[int, _Call]:
        """Issue one request WITHOUT blocking for its response — the
        scatter half of a shard-map fan-out: a caller start_calls every
        daemon first, then :meth:`finish_call`\\ s each handle, so N
        daemons cost one round-trip of wall clock, not N."""
        if self._closed.is_set():
            raise ConnectionError("net client is closed")
        rid = next(self._rid)
        buffers: List[memoryview] = []
        if self._zero_copy and (op.startswith("kv.") or op.startswith("ob.")):
            args = extract_buffers(args, buffers)
            kwargs = extract_buffers(kwargs, buffers)
        msg = ("req", rid, op, args, kwargs)
        try:
            # Plain pickle first: it is ~3x cheaper and covers every op but
            # the closure-carrying evals, which fall back to cloudpickle.
            parts = encode_wire_parts(msg, buffers)
        except Exception:
            parts = encode_wire_parts(msg, buffers, pickler=cloudpickle)
        self._sent_pickled += len(parts[-1]) - _FRAME_HDR.size
        self._sent_buffer += sum(v.nbytes for v in buffers)
        call = _Call(parts)
        with self._state_lock:
            self._pending[rid] = call
            sock = self._sock
        if sock is not None:
            try:
                with self._send_lock:
                    _sendall_parts(sock, parts)
            except OSError:
                pass  # whoever pumps next redials and resends for us
        return rid, call

    def finish_call(self, handle: Tuple[int, _Call]) -> Any:
        """Block for a :meth:`start_call` handle's response; returns the
        value or raises the remapped server error."""
        _rid, call = handle
        self._await(call)
        if call.error is not None:
            raise call.error
        return call.value

    def cast(self, op: str, *args: Any, **kwargs: Any) -> None:
        """Fire-and-forget: one frame out, no response, no await.  For
        advisory writes (duration samples, counters) where the caller needs
        neither the result nor a delivery guarantee stronger than the
        socket's — a cast lost to a reconnect window is simply dropped
        (requests, by contrast, are resent).  Ordering relative to this
        client's own later calls is preserved (same socket, in-order
        server)."""
        if self._closed.is_set():
            raise ConnectionError("net client is closed")
        buffers: List[memoryview] = []
        if self._zero_copy and (op.startswith("kv.") or op.startswith("ob.")):
            args = extract_buffers(args, buffers)
            kwargs = extract_buffers(kwargs, buffers)
        msg = ("cast", op, args, kwargs)
        try:
            parts = encode_wire_parts(msg, buffers)
        except Exception:
            parts = encode_wire_parts(msg, buffers, pickler=cloudpickle)
        self._sent_pickled += len(parts[-1]) - _FRAME_HDR.size
        self._sent_buffer += sum(v.nbytes for v in buffers)
        with self._state_lock:
            sock = self._sock
        if sock is not None:
            try:
                with self._send_lock:
                    _sendall_parts(sock, parts)
            except OSError:
                pass  # best-effort: advisory write dropped with the conn

    def _await(self, call: _Call) -> None:
        """Leader/follower pump with targeted wakes: become the socket
        reader if nobody is, else sleep on this call's PRIVATE event.
        Completing a response wakes exactly its caller; a leader whose own
        call finished hands the baton by waking one pending caller, who
        then takes over the pump.  Under concurrent callers this costs one
        context switch per response — never a broadcast herd."""
        while not call.done:
            lead = False
            with self._state_lock:
                if call.done:
                    break
                if self._closed.is_set():
                    call.error = call.error or ConnectionError("net client closed")
                    call.done = True
                    break
                if not self._pumping:
                    self._pumping = lead = True
            if not lead:
                call.event.wait(1.0)  # bounded: baton races resolve in <1s
                call.event.clear()
                continue
            try:
                while not call.done and not self._closed.is_set():
                    self._pump_once()
            finally:
                with self._state_lock:
                    self._pumping = False
                    if self._closed.is_set() and not call.done:
                        call.error = call.error or ConnectionError(
                            "net client closed"
                        )
                        call.done = True
                    # Hand the baton over: wake ONE pending caller, who
                    # becomes the next leader (or finds itself done).
                    nxt = next(iter(self._pending.values()), None)
                if nxt is not None:
                    nxt.event.set()

    def _pump_once(self) -> None:
        sock = self._sock
        if sock is None:
            self._redial_and_resend()
            return
        dec = self._decoder
        data = None
        try:
            if dec.wanted():
                # Mid-buffer-frame: recv straight into the payload's final
                # bytearray — a large array get lands with zero copies.
                got = sock.recv_into(dec.fill_view())
            else:
                data = sock.recv(1 << 16)
                got = len(data)
        except OSError:
            got = 0
        if not got:
            if self._closed.is_set():
                return
            self._redial_and_resend()
            return
        try:
            if data is None:
                dec.filled(got)  # buffer bytes only: no message completes
                msgs: List[Any] = []
            else:
                msgs = dec.feed(data)
        except ProtocolError:
            # A server speaking garbage is indistinguishable from a
            # corrupted stream: drop the connection and resync fresh.
            self._redial_and_resend()
            return
        for m in msgs:
            self._dispatch(m)

    def _dispatch(self, m: Any) -> bool:
        kind = m[0]
        if kind not in ("res", "err"):
            return False
        with self._state_lock:
            call = self._pending.pop(m[1], None)
        if call is None:
            return False
        if kind == "res":
            call.value = m[2]
        else:
            call.error = self._map_error(m[2], m[3])
        call.done = True
        call.event.set()  # targeted: wake this caller alone
        return True

    @staticmethod
    def _map_error(etype: str, msg: str) -> Exception:
        if etype == "KeyError":
            return KeyError(msg)
        if etype == "FileNotFoundError":
            return FileNotFoundError(msg)
        return RemoteError(etype, msg)

    def _redial_and_resend(self) -> None:
        """Leader-only: redial after a lost connection, then resend the
        whole unacknowledged window in rid order."""
        with self._state_lock:
            old, self._sock = self._sock, None
        if old is not None:
            try:
                old.close()
            except OSError:
                pass
        backoff = 0.005
        while not self._closed.is_set():
            try:
                sock, self.hello, decoder, backlog = _dial(
                    self.host,
                    self.port,
                    self.client_id,
                    (),
                    self._connect_timeout_s,
                    zero_copy=self._zero_copy,
                )
            except OSError:
                self._closed.wait(backoff)
                backoff = min(backoff * 2.0, self._retry_max_s)
                continue
            # Fold the dead decoder's byte counters into the running totals
            # before dropping it — accounting survives reconnects.
            self._recv_pickled_base += self._decoder.bytes_pickled
            self._recv_buffer_base += self._decoder.bytes_buffer
            self._decoder = decoder
            with self._state_lock:
                self._sock = sock
                pending = sorted(self._pending.items())
            try:
                with self._send_lock:
                    for _rid, call in pending:
                        _sendall_parts(sock, call.parts)
            except OSError:
                continue  # lost it again mid-resend: start over
            self._req_reconnects += 1
            for m in backlog:
                self._dispatch(m)
            return

    def close(self) -> None:
        self._closed.set()
        with self._state_lock:
            sock, self._sock = self._sock, None
            pending = list(self._pending.values())
            self._pending.clear()
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for call in pending:
            if not call.done:
                call.error = ConnectionError("net client closed")
                call.done = True
            call.event.set()
        if self._events is not None:
            self._events.close()


class NetKVStore(KVStore):
    """:class:`KVStore` over a ``repro-kvd`` connection.

    Same public API, same notification contract, same charging model:
    every verb is one wire frame, charged locally with the in-memory
    store's exact formulas (one amortized round-trip per shard touched
    for batched verbs), so ledgers compare across backends.  The local
    shard structs hold no data — they carry the watch conditions, the
    keyed-wake ring (fed by pushed ``("kv", shard, seq, keys)`` events),
    and the op stats.

    Waiting is fully event-driven and *registered*: ``wait_key`` /
    ``blpop`` pin a per-key watch on the server (refcounted; one wire op
    per wait session, none per loop iteration), and the server pushes
    wake frames only for watched keys — the keyed-wake filter runs
    server-side, so the torrent of unwatched control-plane writes never
    crosses the wire at all.  Registration replies with the key's current
    server shard sequence; a mismatch with the last sequence this client
    saw means writes landed while unwatched, and the shard is woken once
    so the caller re-probes — the snapshot-check-wait contract holds with
    no lost wakes and no fallback ticks."""

    def __init__(
        self,
        address,
        profile: StorageProfile = REDIS_2017,
        ledger: Optional[Ledger] = None,
        *,
        connect_timeout_s: float = 10.0,
        zero_copy: bool = True,
    ) -> None:
        self._addrs = parse_shard_map(address)
        # Pop-ack and watch bookkeeping must exist before any event can
        # arrive.
        self._ack_guard = threading.Lock()
        self._pop_acks: Dict[str, List[int]] = {}
        self._watch_lock = threading.Lock()
        self._watch_refs: Dict[str, int] = {}
        # One connection pair per daemon, each with its own reconnect loop
        # and event closures bound to its daemon index.  The global shard
        # space concatenates the daemons' shards in shard-map order.
        self._clients: List[NetClient] = []
        self._shard_base: List[int] = []
        self._daemon_shards: List[int] = []
        self._srv_seqs: Dict[int, int] = {}
        base = 0
        for d, (host, port) in enumerate(self._addrs):
            self._shard_base.append(base)
            self._daemon_shards.append(0)  # closure-safe until hello lands
            client = NetClient(
                host,
                port,
                topics=("kv",),
                on_event=self._make_on_event(d),
                on_reconnect=self._make_on_reconnect(d),
                connect_timeout_s=connect_timeout_s,
                zero_copy=zero_copy,
            )
            self._clients.append(client)
            n = int(client.hello["num_shards"])
            self._daemon_shards[d] = n
            for i, seq in enumerate(client.hello.get("kv_seqs", [])):
                self._srv_seqs[base + i] = seq
            base += n
        super().__init__(num_shards=base, profile=profile, ledger=ledger)

    # ---- shard-map routing ----------------------------------------------
    @property
    def _client(self) -> NetClient:
        """The first daemon's client — the whole client for an N=1 map.
        Kept as the single-daemon compatibility surface (examples and
        tests reach for ``kv._client.reconnects``)."""
        return self._clients[0]

    def _daemon_of(self, key: str) -> int:
        return _daemon_of(key, len(self._clients))

    def _client_for(self, key: str) -> NetClient:
        return self._clients[self._daemon_of(key)]

    def shard_of(self, key: str) -> int:
        # Daemon first, then the daemon-local shard (the same crc32 the
        # server itself routes by), offset into the global space.  N=1
        # degenerates to exactly the base class hash.
        d = self._daemon_of(key)
        return self._shard_base[d] + zlib.crc32(key.encode()) % self._daemon_shards[d]

    def _fanout(self, op: str, per_daemon: Dict[int, tuple]) -> Dict[int, Any]:
        """One ``op`` frame per daemon, pipelined: every request leaves
        before any response is awaited, so a shard-map scatter costs one
        round-trip of wall clock."""
        handles = [
            (d, self._clients[d].start_call(op, *args))
            for d, args in per_daemon.items()
        ]
        return {d: self._clients[d].finish_call(h) for d, h in handles}

    # ---- endpoint --------------------------------------------------------
    def _endpoint_spec(self) -> Dict[str, Any]:
        return {
            "kind": "net_kv",
            "addr": ",".join(_addr_str(a) for a in self._addrs),
        }

    def close(self) -> None:
        for client in self._clients:
            client.close()

    # ---- pushed watch events --------------------------------------------
    def _make_on_event(self, d: int) -> Callable[[tuple], None]:
        """Event callback for daemon ``d``: remaps its local shard index
        into the global shard space and touches only that shard."""

        def on_event(m: tuple) -> None:
            if m[0] != "kv":
                return
            shards = getattr(self, "_shards", None)
            if shards is None:
                return  # event raced construction: no waiters exist yet
            _kind, sidx, srv_seq, keys = m
            if not (0 <= sidx < self._daemon_shards[d]):
                return
            g = self._shard_base[d] + sidx
            self._srv_seqs[g] = max(self._srv_seqs.get(g, 0), srv_seq)
            sh = shards[g]
            with sh.lock:
                sh.touch(keys)

        return on_event

    def _make_on_reconnect(self, d: int) -> Callable[[dict], None]:
        """Reconnect handler for daemon ``d`` ALONE: re-pins only the
        watches that route to it, adopts only its shard sequences, wakes
        only its shards' waiters.  The other daemons' connections are
        untouched — a one-daemon outage never disturbs the survivors."""

        def on_reconnect(hello: dict) -> None:
            shards = getattr(self, "_shards", None)
            if shards is None:
                return
            # Order matters: re-pin every live watch FIRST (a write landing
            # between hello and re-registration must not go unpushed), THEN
            # adopt the hello sequences, THEN wake every waiter with UNKNOWN
            # keys so each re-probes its predicate exactly once.  A restarted
            # server starts a new generation with fresh sequences, so this is
            # an assignment, not a max.
            with self._watch_lock:
                live = [k for k, n in self._watch_refs.items() if n > 0]
                for key in live:
                    if self._daemon_of(key) != d:
                        continue
                    try:
                        self._clients[d].call("watch.kv", key, True)
                    except (ConnectionError, OSError):
                        pass  # next reconnect re-registers again
            base = self._shard_base[d]
            for i, seq in enumerate(hello.get("kv_seqs", [])):
                self._srv_seqs[base + i] = seq
            for i in range(self._daemon_shards[d]):
                sh = shards[base + i]
                with sh.lock:
                    sh.touch(None)

        return on_reconnect

    # ---- registered waits ------------------------------------------------
    def _watch_acquire(self, key: str) -> None:
        """Pin a server-side watch on ``key`` (refcounted: one wire op per
        wait session).  The registration reply carries the key's current
        server shard sequence; if it differs from the last sequence this
        client saw, writes landed while unwatched — touch the shard so the
        caller's predicate re-check runs before it sleeps.

        The lock is held ACROSS the wire op: an "on" racing a concurrent
        "off" for the same key could otherwise land first and leave the
        server unwatched under a sleeping waiter."""
        d = self._daemon_of(key)
        client = self._clients[d]
        base = self._shard_base[d]
        with self._watch_lock:
            n = self._watch_refs.get(key, 0)
            self._watch_refs[key] = n + 1
            if n:
                return
            try:
                hello = client.ensure_events()
                if hello is not None:
                    # The event channel was just created: writes before it
                    # existed were never pushed.  Adopt its hello seqs;
                    # mismatched shards wake with unknown keys.  Only this
                    # daemon's shards are involved — the hello speaks for
                    # one daemon.
                    stale = []
                    for i, srv_seq in enumerate(hello.get("kv_seqs", [])):
                        if srv_seq != self._srv_seqs.get(base + i, 0):
                            stale.append(base + i)
                        self._srv_seqs[base + i] = srv_seq
                    for g in stale:
                        sh = self._shards[g]
                        with sh.lock:
                            sh.touch(None)
                srv_seq = int(client.call("watch.kv", key, True))
            except BaseException:
                self._watch_refs[key] = n  # registration failed: unwind
                if not n:
                    self._watch_refs.pop(key, None)
                raise
            sidx = self.shard_of(key)
            if srv_seq != self._srv_seqs.get(sidx, 0):
                self._srv_seqs[sidx] = srv_seq
                sh = self._shards[sidx]
                with sh.lock:
                    sh.touch((key,))

    def _watch_release(self, key: str) -> None:
        with self._watch_lock:
            n = self._watch_refs.get(key, 0) - 1
            if n > 0:
                self._watch_refs[key] = n
                return
            self._watch_refs.pop(key, None)
            try:
                self._client_for(key).call("watch.kv", key, False)
            except (ConnectionError, OSError, RemoteError):
                pass  # conn gone: the server reaps the watch with it

    def wait_key(self, key: str, last_seq: int, timeout_s: float) -> int:
        self._watch_acquire(key)
        try:
            return super().wait_key(key, last_seq, timeout_s)
        finally:
            self._watch_release(key)

    # ---- atomic single-key ops ------------------------------------------
    def set(self, key: str, value: Any, *, worker: str = "-") -> None:
        self._client_for(key).call("kv.set", key, value)
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "set", key, _sizeof(value), write=True)

    def get(self, key: str, default: Any = None, *, worker: str = "-") -> Any:
        value = self._client_for(key).call("kv.get", key, default)
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "get", key, _sizeof(value), write=False)
        return value

    def _group_keys(self, keys) -> Dict[int, List[int]]:
        """Input positions grouped by owning daemon (shard-map scatter)."""
        by_daemon: Dict[int, List[int]] = {}
        for i, key in enumerate(keys):
            by_daemon.setdefault(self._daemon_of(key), []).append(i)
        return by_daemon

    def mget(
        self, keys: List[str], default: Any = None, *, worker: str = "-"
    ) -> List[Any]:
        keys = list(keys)
        if len(self._clients) == 1:
            out = self._client.call("kv.mget", keys, default)
        else:
            by_daemon = self._group_keys(keys)
            parts = self._fanout(
                "kv.mget",
                {d: ([keys[i] for i in idxs], default) for d, idxs in by_daemon.items()},
            )
            out: List[Any] = [default] * len(keys)
            for d, idxs in by_daemon.items():
                for i, v in zip(idxs, parts[d]):
                    out[i] = v
        by_shard: Dict[int, List[int]] = {}
        for i, key in enumerate(keys):
            by_shard.setdefault(self.shard_of(key), []).append(i)
        for sidx, positions in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = sum(_sizeof(out[i]) for i in positions)
                self._charge(
                    sh, worker, "mget", f"[{len(positions)} keys@s{sidx}]",
                    nbytes, write=False,
                )
        return out

    def mset(self, mapping: Dict[str, Any], *, worker: str = "-") -> None:
        if len(self._clients) == 1:
            self._client.call("kv.mset", dict(mapping))
        else:
            per_daemon: Dict[int, Dict[str, Any]] = {}
            for key, value in mapping.items():
                per_daemon.setdefault(self._daemon_of(key), {})[key] = value
            self._fanout("kv.mset", {d: (m,) for d, m in per_daemon.items()})
        by_shard: Dict[int, List[str]] = {}
        for key in mapping:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = sum(_sizeof(mapping[key]) for key in group)
                self._charge(
                    sh, worker, "mset", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )

    def setnx(self, key: str, value: Any, *, worker: str = "-") -> bool:
        won = bool(self._client_for(key).call("kv.setnx", key, value))
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "setnx", key, _sizeof(value), write=True)
        return won

    def incr(self, key: str, amount: float = 1, *, worker: str = "-") -> float:
        new = self._client_for(key).call("kv.incr", key, amount)
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "incr", key, 8, write=True)
        return new

    def cas(self, key: str, expect: Any, value: Any, *, worker: str = "-") -> bool:
        won = bool(self._client_for(key).call("kv.cas", key, expect, value))
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "cas", key, _sizeof(value), write=True)
        return won

    def delete(self, key: str, *, worker: str = "-") -> None:
        self._client_for(key).call("kv.delete", key)
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "del", key, 0, write=True)

    def mdel(self, keys: List[str], *, worker: str = "-") -> int:
        keys = list(keys)
        if len(self._clients) == 1:
            removed = int(self._client.call("kv.mdel", keys))
        else:
            by_daemon = self._group_keys(keys)
            parts = self._fanout(
                "kv.mdel",
                {d: ([keys[i] for i in idxs],) for d, idxs in by_daemon.items()},
            )
            removed = sum(int(v) for v in parts.values())
        by_shard: Dict[int, List[str]] = {}
        for key in keys:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                self._charge(
                    sh, worker, "mdel", f"[{len(group)} keys@s{sidx}]", 0, write=True
                )
        return removed

    def exists(self, key: str, *, worker: str = "-") -> bool:
        ok = bool(self._client_for(key).call("kv.exists", key))
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "exists", key, 0, write=False)
        return ok

    def scan(self, prefix: str, *, worker: str = "-") -> List[str]:
        # A prefix scatters across every daemon's keyspace: fan to all,
        # union (pipelined — one round-trip of wall clock).
        parts = self._fanout(
            "kv.scan", {d: (prefix,) for d in range(len(self._clients))}
        )
        found: List[str] = []
        for vals in parts.values():
            found.extend(vals)
        per_shard: Dict[int, int] = {}
        for k in found:
            sidx = self.shard_of(k)
            per_shard[sidx] = per_shard.get(sidx, 0) + len(k.encode())
        # Same formula as the in-memory scan: every shard is charged a
        # round-trip (hashing scatters a prefix across all of them).
        for sh in self._shards:
            with sh.lock:
                self._charge(
                    sh, worker, "scan", f"[{prefix}*@s{sh.idx}]",
                    per_shard.get(sh.idx, 0), write=False,
                )
        return sorted(found)

    # ---- server-side scripting ------------------------------------------
    def eval(
        self,
        key: str,
        fn: Callable[[Any], Any],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Any:
        old = self._client_for(key).call("kv.eval", key, fn, default)
        new = fn(old)  # deterministic replay: side effects land HERE
        deleted = new is DELETE
        sh = self._shard(key)
        with sh.lock:
            self._charge(
                sh, worker, "eval", key, 0 if deleted else _sizeof(new), write=True
            )
        return None if deleted else new

    def eval_many(
        self,
        updates: Dict[str, Callable[[Any], Any]],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Dict[str, Any]:
        if len(self._clients) == 1:
            olds = self._client.call("kv.eval_many", dict(updates), default)
        else:
            per_daemon: Dict[int, Dict[str, Callable[[Any], Any]]] = {}
            for key, fn in updates.items():
                per_daemon.setdefault(self._daemon_of(key), {})[key] = fn
            olds = {}
            for part in self._fanout(
                "kv.eval_many", {d: (m, default) for d, m in per_daemon.items()}
            ).values():
                olds.update(part)
        by_shard: Dict[int, List[str]] = {}
        for key in updates:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        out: Dict[str, Any] = {}
        for sidx, group in by_shard.items():
            nbytes = 0
            for key in group:
                new = updates[key](olds[key])  # deterministic replay
                if new is DELETE:
                    out[key] = None
                    continue
                out[key] = new
                nbytes += _sizeof(new)
            sh = self._shards[sidx]
            with sh.lock:
                self._charge(
                    sh, worker, "meval", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )
        return out

    # ---- lists (queues) --------------------------------------------------
    def rpush(self, key: str, *values: Any, worker: str = "-") -> int:
        length = int(self._client_for(key).call("kv.rpush", key, *values))
        sh = self._shard(key)
        with sh.lock:
            self._charge(
                sh, worker, "rpush", key, sum(_sizeof(v) for v in values), write=True
            )
        return length

    def rpush_nowait(self, key: str, *values: Any, worker: str = "-") -> None:
        self._client_for(key).cast("kv.rpush", key, *values)
        sh = self._shard(key)
        with sh.lock:
            self._charge(
                sh, worker, "rpush", key, sum(_sizeof(v) for v in values), write=True
            )

    def rpush_many(
        self, pushes: Dict[str, List[Any]], *, worker: str = "-"
    ) -> Dict[str, int]:
        if len(self._clients) == 1:
            lengths = self._client.call("kv.rpush_many", dict(pushes))
        else:
            per_daemon: Dict[int, Dict[str, List[Any]]] = {}
            for key, values in pushes.items():
                per_daemon.setdefault(self._daemon_of(key), {})[key] = values
            lengths = {}
            for part in self._fanout(
                "kv.rpush_many", {d: (m,) for d, m in per_daemon.items()}
            ).values():
                lengths.update(part)
        by_shard: Dict[int, List[str]] = {}
        for key in pushes:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = sum(_sizeof(v) for key in group for v in pushes[key])
                self._charge(
                    sh, worker, "mrpush", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )
        return lengths

    def _pop_wire(self, key: str, max_n: int) -> List[Any]:
        """One ack-journaled destructive read (module docstring: a retried
        pop must return the FIRST pop's items, never pop again)."""
        with self._ack_guard:
            acked = self._pop_acks.pop(key, None) or []
        try:
            rid, out = self._client_for(key).call_rid("kv.lpop_n", key, max_n, acked)
        except BaseException:
            if acked:  # put the retirement list back for the next attempt
                with self._ack_guard:
                    self._pop_acks.setdefault(key, []).extend(acked)
            raise
        if out:
            with self._ack_guard:
                self._pop_acks.setdefault(key, []).append(rid)
        return out

    def lpop(self, key: str, *, worker: str = "-") -> Any:
        out = self._pop_wire(key, 1)
        value = out[0] if out else None
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "lpop", key, _sizeof(value), write=True)
        return value

    def lpop_n(self, key: str, max_n: int, *, worker: str = "-") -> List[Any]:
        out = self._pop_wire(key, max_n)
        sh = self._shard(key)
        with sh.lock:
            self._charge(
                sh, worker, "lpopn", key, sum(_sizeof(v) for v in out), write=True
            )
        return out

    def blpop(self, key: str, timeout_s: float, *, worker: str = "-") -> Any:
        """Event-driven blocking pop: wire attempt, then wait on the local
        shard condition for a pushed wake naming ``key``.  The sequence is
        snapshotted BEFORE each attempt, so a push whose event lands after
        a failed attempt wakes the wait instead of being missed."""
        deadline = time.monotonic() + timeout_s
        sh = self._shard(key)
        # One watch session spans every retry: the inner wait_key calls
        # refcount onto this pin instead of churning the wire per loop.
        self._watch_acquire(key)
        try:
            while True:
                with sh.lock:
                    seq = sh.seq
                out = self._pop_wire(key, 1)
                if out:
                    with sh.lock:
                        self._charge(
                            sh, worker, "blpop", key, _sizeof(out[0]), write=True
                        )
                    return out[0]
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self.wait_key(key, seq, remaining)
        finally:
            self._watch_release(key)

    def lrange(
        self, key: str, start: int = 0, stop: int = -1, *, worker: str = "-"
    ) -> List[Any]:
        out = self._client_for(key).call("kv.lrange", key, start, stop)
        sh = self._shard(key)
        with sh.lock:
            self._charge(
                sh, worker, "lrange", key, sum(_sizeof(v) for v in out), write=False
            )
        return out

    def llen(self, key: str, *, worker: str = "-") -> int:
        n = int(self._client_for(key).call("kv.llen", key))
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "llen", key, 8, write=False)
        return n


class NetBackend(_Backend):
    """Object-store backend over a ``repro-kvd`` connection.

    Byte-plane ops are one frame each (batched verbs stay batched); the
    watch plane is fully pushed — the server streams ``("obj", seq,
    keys)`` events for every mutation *including this client's own*
    (``echoes_puts``), feeding the inherited ``puts_since`` ring, so
    ``ObjectStore.wait_keys`` is event-driven with zero fallback ticks."""

    cross_process = True
    self_watching = True
    echoes_puts = True
    # The server consumes put blobs synchronously (logged before the res
    # frame), so callers may hand over live memoryviews without aliasing —
    # checkpoint.save skips its tobytes() copy on this signal.
    zero_copy_puts = True

    def __init__(
        self, address, *, connect_timeout_s: float = 10.0, zero_copy: bool = True
    ) -> None:
        self._addrs = parse_shard_map(address)
        self._zero_copy = bool(zero_copy)
        self._init_watch()
        self._clients: List[NetClient] = []
        self._srv_obj_seqs: Dict[int, int] = {}
        for d, (host, port) in enumerate(self._addrs):
            client = NetClient(
                host,
                port,
                topics=("obj",),
                on_event=self._make_on_event(d),
                on_reconnect=self._make_on_reconnect(d),
                connect_timeout_s=connect_timeout_s,
                zero_copy=zero_copy,
            )
            self._clients.append(client)
            self._srv_obj_seqs[d] = int(client.hello.get("obj_seq", 0))

    # ---- shard-map routing ----------------------------------------------
    @property
    def _client(self) -> NetClient:
        """First daemon's client — the whole client for an N=1 map (the
        single-daemon compatibility surface)."""
        return self._clients[0]

    def _daemon_of(self, key: str) -> int:
        return _daemon_of(key, len(self._clients))

    def _client_for(self, key: str) -> NetClient:
        return self._clients[self._daemon_of(key)]

    def _fanout(self, op: str, per_daemon: Dict[int, tuple]) -> Dict[int, Any]:
        handles = [
            (d, self._clients[d].start_call(op, *args))
            for d, args in per_daemon.items()
        ]
        return {d: self._clients[d].finish_call(h) for d, h in handles}

    def endpoint_spec(self) -> Dict[str, Any]:
        return {
            "kind": "net_obj",
            "addr": ",".join(_addr_str(a) for a in self._addrs),
        }

    def close(self) -> None:
        for client in self._clients:
            client.close()

    # ---- pushed watch events --------------------------------------------
    def _make_on_event(self, d: int) -> Callable[[tuple], None]:
        def on_event(m: tuple) -> None:
            if m[0] == "obj":
                self._srv_obj_seqs[d] = max(self._srv_obj_seqs.get(d, 0), int(m[1]))
                _Backend.notify_put(self, m[2])

        return on_event

    def _make_on_reconnect(self, d: int) -> Callable[[dict], None]:
        def on_reconnect(hello: dict) -> None:
            # Unknown-keys wake: waiters re-probe once, so no put that
            # landed while daemon ``d`` was unreachable can be missed.  New
            # generation means fresh server sequences — adopt, don't max.
            # Only this daemon's sequence resets; the survivors' event
            # streams never paused.
            self._srv_obj_seqs[d] = int(hello.get("obj_seq", 0))
            _Backend.notify_put(self, None)

        return on_reconnect

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        # The event channels are lazy (non-waiting clients pay zero event
        # CPU); first wait creates them — on every daemon, since a put may
        # land anywhere in the map.  Each hello carries that daemon's
        # current object sequence — any gap vs the last sequence we saw is
        # a put that predates the channel, so wake with unknown keys.
        for d, client in enumerate(self._clients):
            hello = client.ensure_events()
            if hello is not None:
                srv = int(hello.get("obj_seq", 0))
                if srv != self._srv_obj_seqs.get(d, 0):
                    self._srv_obj_seqs[d] = srv
                    _Backend.notify_put(self, None)
        return _Backend.wait_put(self, last_seq, timeout_s)

    # ---- byte plane ------------------------------------------------------
    def _wire_blob(self, blob) -> Any:
        """Large bytes-likes ride buffer frames untouched; everything else
        (and everything when zero-copy is off) normalizes to ``bytes`` so
        the pickled fallback path always round-trips."""
        if self._zero_copy and isinstance(blob, (bytes, bytearray, memoryview)):
            return blob
        return bytes(blob)

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        return bool(
            self._client_for(key).call("ob.put", key, self._wire_blob(blob), if_absent)
        )

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        if len(self._clients) == 1:
            return int(
                self._client.call(
                    "ob.put_many",
                    {k: self._wire_blob(b) for k, b in items.items()},
                    if_absent,
                )
            )
        per_daemon: Dict[int, Dict[str, Any]] = {}
        for key, blob in items.items():
            per_daemon.setdefault(self._daemon_of(key), {})[key] = self._wire_blob(blob)
        parts = self._fanout(
            "ob.put_many", {d: (m, if_absent) for d, m in per_daemon.items()}
        )
        return sum(int(v) for v in parts.values())

    def get(self, key: str) -> bytes:
        return self._client_for(key).call("ob.get", key)

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        if len(self._clients) == 1:
            return self._client.call("ob.get_many", list(keys))
        per_daemon: Dict[int, List[str]] = {}
        for key in keys:
            per_daemon.setdefault(self._daemon_of(key), []).append(key)
        out: Dict[str, bytes] = {}
        for part in self._fanout(
            "ob.get_many", {d: (ks,) for d, ks in per_daemon.items()}
        ).values():
            out.update(part)
        return out

    def exists(self, key: str) -> bool:
        return bool(self._client_for(key).call("ob.exists", key))

    def exists_many(self, keys: List[str]) -> set:
        if len(self._clients) == 1:
            return set(self._client.call("ob.exists_many", list(keys)))
        per_daemon: Dict[int, List[str]] = {}
        for key in keys:
            per_daemon.setdefault(self._daemon_of(key), []).append(key)
        out: set = set()
        for part in self._fanout(
            "ob.exists_many", {d: (ks,) for d, ks in per_daemon.items()}
        ).values():
            out.update(part)
        return out

    def delete(self, key: str) -> None:
        self._client_for(key).call("ob.delete", key)

    def list(self, prefix: str) -> List[str]:
        out: List[str] = []
        for part in self._fanout(
            "ob.list", {d: (prefix,) for d in range(len(self._clients))}
        ).values():
            out.extend(part)
        return out
