"""S3-semantics object store: the bulk state plane of the stateless runtime.

Semantics reproduced from the paper's use of S3:
  * whole-object atomic ``put`` / ``get`` (no partial writes ever visible);
  * ``put_if_absent`` — the atomic-write primitive the paper relies on for
    exactly-once result visibility ("We only need atomic writes to remote
    storage for tracking which functions have succeeded");
  * ``list(prefix)`` for completion polling;
  * **no append** (the paper calls this limitation out in §4) — appends must
    be emulated by writing new keys, exactly as PyWren's shuffle does;
  * integrity: every object carries a sha256 etag.

Backends: in-memory (tests, benchmarks) and file-backed (crash-safe via
``os.replace``; used by checkpointing so restarts survive process death).

Data plane (batching + notification):
  * **batched reads** — ``get_many``/``get_many_bytes`` (alias
    ``multi_get``) coalesce N key fetches into one backend call and charge
    *one* amortized round-trip: a single request latency plus the summed
    transfer time, instead of N× latency.  This is the numpywren lesson —
    object-store cost is dominated by per-request latency, so every
    driver-side fan-in (future resolution, shuffle column reads, parameter
    pulls) should ride a multi-get.  Missing keys are omitted from the
    result dict (callers that need all keys pass ``missing="error"``).
  * **batched writes** — ``put_many``/``put_many_bytes`` are the write-side
    mirror: N objects land in one backend call charged as a single request
    latency plus the summed transfer time (``write_latency + Σbytes/bw``),
    and the whole batch fires **one** ``notify_put`` — waiters wake once
    per batch, not once per object.  ``delete_many`` rides the same
    accounting for teardown (shuffle-intermediate GC, per-job GC).  This is
    the other half of the Fig 5/6 request-count bottleneck: map-side
    fan-outs (``shuffle.write_partitions``, input staging) are request-
    bound, not byte-bound, so pipelining the batch amortizes exactly the
    term that saturates first.  ``if_absent`` batches keep per-key
    first-writer-wins semantics; the return value counts keys won.
  * **key watch** (event-driven completion signalling) — every successful
    ``put_bytes`` through this store handle calls ``notify_put``: a
    broadcast on the store's watch condition plus a monotonically
    increasing put sequence number.  Waiters (``wait_keys``, futures)
    snapshot ``put_seq()``, check key existence, then block in
    ``wait_put`` until the sequence advances — the snapshot-then-wait
    ordering means an in-process publish can never be missed between the
    existence check and the wait.
  * wakeup guarantee is **per backend**: the watch condition and sequence
    live on the backend, so a publish through *any* store handle sharing
    that backend wakes every waiter in this process.  A *different process*
    sharing a ``FileBackend`` directory publishes without reaching this
    process's condition directly; ``FileBackend`` closes that gap with a
    **cross-process watch**: every write appends one byte to a per-root
    sequence file (size is the cross-process write sequence — monotone and
    atomic under ``O_APPEND``), and a per-backend watch thread stats that
    file plus the directory's dirent mtime with exponential poll backoff
    (``_PollWatcher``; fast after a change, backing off to a small cap when
    idle, fully parked while nobody waits), converting external writes into
    in-process ``notify_put`` broadcasts.  ``wait_keys`` therefore no
    longer needs its fallback re-check tick on any built-in backend; the
    tick (``WATCH_FALLBACK_TICK_S``) survives only for out-of-tree
    cross-process backends without a watcher, and every tick-bounded wait
    is counted in ``ObjectStore.fallback_tick_waits`` so tests can assert
    the event-driven path really is tick-free.

Every operation is charged virtual wire time from a
:class:`~repro.storage.perf_model.StorageProfile` and recorded in a
:class:`Ledger` keyed by the calling worker, which the paper-figure
benchmarks aggregate.
"""

from __future__ import annotations

import os
import threading
import time
import uuid
import weakref
from collections import defaultdict
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

from . import serialization
from .perf_model import S3_2017, StorageProfile

# Store handles pickle BY REFERENCE (like an S3 client: the serialized form
# is an endpoint, not the data).  Functions shipped through the runtime close
# over store handles; on the worker they must resolve to the *same* store.
_HANDLE_REGISTRY: "weakref.WeakValueDictionary[str, Any]" = weakref.WeakValueDictionary()


def _resolve_handle(uid: str) -> Any:
    try:
        return _HANDLE_REGISTRY[uid]
    except KeyError:
        raise RuntimeError(
            f"storage handle {uid} not live in this process; in a real "
            "deployment this would reconnect to the remote endpoint"
        ) from None


class _Endpoint:
    """Mixin giving a class by-reference pickling semantics."""

    def _register_endpoint(self) -> None:
        self._endpoint_uid = f"{type(self).__name__}-{uuid.uuid4().hex}"
        _HANDLE_REGISTRY[self._endpoint_uid] = self

    def __reduce__(self):
        return (_resolve_handle, (self._endpoint_uid,))


@dataclass
class OpRecord:
    worker: str
    op: str  # "get" | "put" | "list" | "delete" | "head"
    key: str
    nbytes: int
    vtime_s: float  # modeled wire duration
    wall_t: float  # real monotonic time of issue (ordering/debug only)


class Ledger:
    """Thread-safe per-worker record of storage ops in virtual time."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: List[OpRecord] = []

    def record(self, rec: OpRecord) -> None:
        with self._lock:
            self._records.append(rec)

    def records(self) -> List[OpRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- aggregation helpers used by benchmarks -------------------------
    def totals(self) -> Dict[str, Tuple[int, float]]:
        """op -> (total bytes, total virtual seconds)."""
        out: Dict[str, Tuple[int, float]] = defaultdict(lambda: (0, 0.0))
        for r in self.records():
            b, t = out[r.op]
            out[r.op] = (b + r.nbytes, t + r.vtime_s)
        return dict(out)

    def per_worker(self) -> Dict[str, Dict[str, Tuple[int, float]]]:
        out: Dict[str, Dict[str, Tuple[int, float]]] = defaultdict(
            lambda: defaultdict(lambda: (0, 0.0))
        )
        for r in self.records():
            b, t = out[r.worker][r.op]
            out[r.worker][r.op] = (b + r.nbytes, t + r.vtime_s)
        return {w: dict(ops) for w, ops in out.items()}


class KeyExistsError(KeyError):
    pass


# Fallback re-check interval for key watchers: covers publishes that bypass
# this store handle's notifications on a cross-process backend *without* a
# watch thread (no built-in backend is one anymore; see _PollWatcher).
WATCH_FALLBACK_TICK_S = 0.25

# _PollWatcher backoff bounds: fast enough after a change that a
# cross-process wake is near-immediate, capped so an idle watcher costs a
# couple of stat() calls per _WATCH_MAX_BACKOFF_S at worst.
_WATCH_MIN_BACKOFF_S = 0.002
_WATCH_MAX_BACKOFF_S = 0.05


class _PollWatcher:
    """Watch filesystem signals for cross-process writes.

    Watches a fixed set of paths by ``stat`` signature ``(size, mtime_ns)``
    — sequence files grow monotonically under ``O_APPEND`` and a POSIX
    ``rename``/``unlink`` bumps the parent dirent's mtime, so together they
    cover every mutation a foreign process can make.  Polling is
    exponential-backoff (reset to ``min_s`` on every observed change) and
    **waiter-gated**: with zero registered waiters the thread parks on an
    event and costs nothing.  The comparison baseline persists across idle
    periods, so a write landing while parked is detected on the first pass
    after a waiter registers — the snapshot-then-check-then-wait contract
    of ``wait_put`` does the rest.  When a real inotify binding is
    importable it could replace the poll loop; none is assumed (the
    container has no inotify package), so the backoff poll is the portable
    default."""

    def __init__(
        self,
        paths: List[str],
        on_change,
        min_s: float = _WATCH_MIN_BACKOFF_S,
        max_s: float = _WATCH_MAX_BACKOFF_S,
    ) -> None:
        self._paths = list(paths)
        self._on_change = on_change
        self._min_s = min_s
        self._max_s = max_s
        self._lock = threading.Lock()
        self._waiters = 0
        self._wake = threading.Event()
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    @staticmethod
    def _sig(path: str) -> Tuple[int, int]:
        try:
            st = os.stat(path)
        except OSError:
            return (0, 0)
        return (st.st_size, st.st_mtime_ns)

    def add_waiter(self) -> None:
        with self._lock:
            self._waiters += 1
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, daemon=True, name="fs-watch"
                )
                self._thread.start()
            self._wake.set()

    def remove_waiter(self) -> None:
        with self._lock:
            self._waiters = max(0, self._waiters - 1)

    def close(self) -> None:
        self._closed = True
        self._wake.set()

    def _run(self) -> None:
        last = [self._sig(p) for p in self._paths]
        backoff = self._min_s
        while not self._closed:
            with self._lock:
                idle = self._waiters == 0
                if idle:
                    self._wake.clear()
            if idle:
                # Park until a waiter registers; `last` persists, so writes
                # landing while parked are seen on the first pass after wake.
                self._wake.wait()
                continue
            changed = []
            for i, p in enumerate(self._paths):
                sig = self._sig(p)
                if sig != last[i]:
                    last[i] = sig
                    changed.append(i)
            if changed:
                backoff = self._min_s
                self._on_change(changed)
            else:
                backoff = min(backoff * 2.0, self._max_s)
            time.sleep(backoff)


class _Backend:
    # True when writers in *other processes* can mutate the backing state
    # without going through an in-process store handle.  Backends that also
    # run a cross-process watcher (``self_watching``) convert those foreign
    # writes into in-process notifications, so their waiters stay purely
    # event-driven; only a cross-process backend *without* a watcher needs
    # the fallback re-check tick.
    cross_process = False
    self_watching = False

    def _init_watch(self) -> None:
        """Watch state lives on the *backend*, not the store handle: two
        ``ObjectStore`` handles sharing one backend must wake each other's
        waiters (subclass ``__init__`` calls this)."""
        self._watch_cv = threading.Condition()
        self._watch_seq = 0

    def notify_put(self) -> None:
        with self._watch_cv:
            self._watch_seq += 1
            self._watch_cv.notify_all()

    def put_seq(self) -> int:
        with self._watch_cv:
            return self._watch_seq

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        with self._watch_cv:
            if self._watch_seq == last_seq:
                self._watch_cv.wait(timeout_s)
            return self._watch_seq

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        """Batched fetch: returns present keys only (missing keys omitted).
        Backends override to serve the whole batch in one locked pass."""
        out: Dict[str, bytes] = {}
        for key in keys:
            try:
                out[key] = self.get(key)
            except (KeyError, FileNotFoundError):
                continue
        return out

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        """Batched write: land every item, returning how many were written
        (``if_absent`` keeps per-key first-writer-wins; losers don't count).
        Backends override to serve the whole batch in one locked pass."""
        won = 0
        for key, blob in items.items():
            if self.put(key, blob, if_absent=if_absent):
                won += 1
        return won

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def list(self, prefix: str) -> List[str]:
        raise NotImplementedError


class InMemoryBackend(_Backend):
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._data: Dict[str, bytes] = {}
        self._init_watch()

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        with self._lock:
            if if_absent and key in self._data:
                return False
            self._data[key] = blob
            return True

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        with self._lock:
            return {k: self._data[k] for k in keys if k in self._data}

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        with self._lock:
            won = 0
            for key, blob in items.items():
                if if_absent and key in self._data:
                    continue
                self._data[key] = blob
                won += 1
            return won

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def list(self, prefix: str) -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))


class FileBackend(_Backend):
    """Directory-backed store.  Writes are crash-atomic: write temp file,
    fsync, then commit — ``os.replace`` for plain puts, ``os.link`` for
    ``if_absent`` puts.  The link either creates the final dirent atomically
    or fails ``EEXIST``, so two *processes* racing a ``put_if_absent``
    cannot both win (the first-writer-wins contract the fenced result
    publishes ride on), and either way only a complete object ever becomes
    visible.

    Cross-process watch: every mutation appends one byte to the root's
    ``.watch-seq`` file after it lands, so the file's *size* is a monotone
    cross-process write sequence (``O_APPEND`` appends are atomic).  The
    first ``wait_put`` starts a ``_PollWatcher`` over that file plus the
    root dirent's mtime (rename/unlink bump it even for writers that skip
    the seq append); any observed change fires this process's
    ``notify_put``, so waiters sharing the directory across processes are
    woken without a fallback re-check tick — the last ROADMAP polling hole.
    The watcher is waiter-gated and backs off exponentially, so a backend
    nobody waits on never polls at all."""

    cross_process = True
    self_watching = True

    _SEQ_NAME = ".watch-seq"

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self._seq_path = os.path.join(self.root, self._SEQ_NAME)
        self._watcher: Optional[_PollWatcher] = None
        self._init_watch()

    def _path(self, key: str) -> str:
        safe = key.replace("/", "%2F")
        return os.path.join(self.root, safe)

    def _unpath(self, name: str) -> str:
        return name.replace("%2F", "/")

    def _bump_cross_seq(self) -> None:
        """Advance the cross-process write sequence: one atomic O_APPEND
        byte.  Other processes' watchers detect the size growth."""
        fd = os.open(self._seq_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, b"x")
        finally:
            os.close(fd)

    def _ensure_watcher(self) -> _PollWatcher:
        with self._lock:
            if self._watcher is None:
                self._watcher = _PollWatcher(
                    [self._seq_path, self.root],
                    lambda _changed: self.notify_put(),
                )
            return self._watcher

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        # Register with the cross-process watcher for the duration of the
        # wait: foreign writes become in-process notify_put broadcasts, so
        # the base condition wait needs no fallback tick.
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            return super().wait_put(last_seq, timeout_s)
        finally:
            watcher.remove_waiter()

    def close(self) -> None:
        """Stop the watch thread (tests; daemon thread otherwise)."""
        with self._lock:
            if self._watcher is not None:
                self._watcher.close()
                self._watcher = None

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        path = self._path(key)
        with self._lock:
            if if_absent and os.path.exists(path):
                return False
            tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            if if_absent:
                # Atomic cross-process first-writer-wins: link either
                # creates the dirent or fails EEXIST — the exists() above is
                # only a fast path, another process can land between it and
                # here.
                try:
                    os.link(tmp, path)
                except FileExistsError:
                    os.remove(tmp)
                    return False
                os.remove(tmp)
            else:
                os.replace(tmp, path)
            self._bump_cross_seq()
            return True

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
            self._bump_cross_seq()
        except FileNotFoundError:
            pass

    def list(self, prefix: str) -> List[str]:
        out = []
        for name in os.listdir(self.root):
            # skip temp files and watch-plane files (".watch-seq" etc.)
            if name.startswith(".") or name.endswith((".tmp",)) or ".tmp." in name:
                continue
            key = self._unpath(name)
            if key.startswith(prefix):
                out.append(key)
        return sorted(out)


class ObjectStore(_Endpoint):
    """The remote bulk store.  All durable runtime state lives here."""

    def __init__(
        self,
        backend: Optional[_Backend] = None,
        profile: StorageProfile = S3_2017,
        ledger: Optional[Ledger] = None,
    ) -> None:
        self.backend = backend or InMemoryBackend()
        self.profile = profile
        self.ledger = ledger or Ledger()
        # How many tick-bounded (non-event-driven) waits wait_keys has done
        # on this handle.  Built-in backends are all event-driven now, so
        # tests assert this stays 0; a nonzero count means some waiter fell
        # back to polling (an out-of-tree cross-process backend, or an
        # explicit poll_s).
        self.fallback_tick_waits = 0
        self._register_endpoint()

    # ---- key watch (notification plane) --------------------------------
    # Watch state lives on the backend so that two store handles sharing
    # one backend (e.g. two ObjectStores over the same InMemoryBackend)
    # wake each other's waiters; these methods delegate.
    def notify_put(self, key: str) -> None:
        """Wake every watcher of this store's backend: ``key`` just became
        visible.  Called by ``put_bytes`` on each successful write; external
        feeders writing to the backend out of band may call it too."""
        self.backend.notify_put()

    def put_seq(self) -> int:
        """Snapshot of the backend's put counter; pass to :meth:`wait_put`."""
        return self.backend.put_seq()

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        """Block until any put lands on the backend after the ``last_seq``
        snapshot (or the timeout elapses); returns the current sequence."""
        return self.backend.wait_put(last_seq, timeout_s)

    # ---- raw byte plane ------------------------------------------------
    def put_bytes(
        self, key: str, blob: bytes, *, worker: str = "-", if_absent: bool = False
    ) -> bool:
        won = self.backend.put(key, blob, if_absent=if_absent)
        self.ledger.record(
            OpRecord(worker, "put", key, len(blob), self.profile.write_time(len(blob)), time.monotonic())
        )
        if won:
            self.notify_put(key)
        return won

    def put_many_bytes(
        self, items: Dict[str, bytes], *, worker: str = "-", if_absent: bool = False
    ) -> int:
        """Batched write: one backend call, one amortized round-trip.

        Mirrors :meth:`get_many_bytes` on the write side — N objects cost
        ``write_latency + Σbytes/bw`` instead of ``N·latency + …``, the
        pipelined-PUT amortization.  The whole batch fires exactly one
        ``notify_put`` (waiters re-check their predicate once per batch).
        Returns the number of keys written; with ``if_absent=True`` each key
        keeps first-writer-wins semantics and losers are not counted."""
        if not items:
            return 0
        won = self.backend.put_many(dict(items), if_absent=if_absent)
        total = sum(len(b) for b in items.values())
        vt = self.profile.write_latency_s + total / self.profile.write_bw_per_conn
        self.ledger.record(
            OpRecord(worker, "mput", f"[{len(items)} keys]", total, vt, time.monotonic())
        )
        if won:
            self.backend.notify_put()
        return won

    def get_bytes(self, key: str, *, worker: str = "-") -> bytes:
        blob = self.backend.get(key)
        self.ledger.record(
            OpRecord(worker, "get", key, len(blob), self.profile.read_time(len(blob)), time.monotonic())
        )
        return blob

    def get_many_bytes(self, keys: List[str], *, worker: str = "-") -> Dict[str, bytes]:
        """Batched fetch: one backend call, one amortized round-trip.

        Charged as a single request latency plus the summed transfer time —
        N keys cost ``latency + Σbytes/bw`` instead of ``N·latency + …``.
        Missing keys are omitted from the returned dict."""
        blobs = self.backend.get_many(list(keys))
        total = sum(len(b) for b in blobs.values())
        vt = self.profile.read_latency_s + total / self.profile.read_bw_per_conn
        self.ledger.record(
            OpRecord(worker, "mget", f"[{len(keys)} keys]", total, vt, time.monotonic())
        )
        return blobs

    def exists(self, key: str, *, worker: str = "-") -> bool:
        ok = self.backend.exists(key)
        self.ledger.record(
            OpRecord(worker, "head", key, 0, self.profile.read_latency_s, time.monotonic())
        )
        return ok

    def delete(self, key: str, *, worker: str = "-") -> None:
        self.backend.delete(key)
        self.ledger.record(
            OpRecord(worker, "delete", key, 0, self.profile.write_latency_s, time.monotonic())
        )

    def delete_many(self, keys: List[str], *, worker: str = "-") -> None:
        """Batched delete: one amortized round-trip for the whole batch
        (cf. :meth:`get_many_bytes` — per-request latency, not bytes,
        dominates deletes)."""
        for k in keys:
            self.backend.delete(k)
        self.ledger.record(
            OpRecord(
                worker, "mdel", f"[{len(keys)} keys]", 0,
                self.profile.write_latency_s, time.monotonic(),
            )
        )

    def delete_prefix(self, prefix: str, *, worker: str = "-") -> int:
        """Delete every key under ``prefix`` (job GC); one list + one
        batched delete round-trip.  Returns the count."""
        keys = self.list(prefix, worker=worker)
        if keys:
            self.delete_many(keys, worker=worker)
        return len(keys)

    def list(self, prefix: str, *, worker: str = "-") -> List[str]:
        keys = self.backend.list(prefix)
        self.ledger.record(
            OpRecord(worker, "list", prefix, 0, self.profile.read_latency_s, time.monotonic())
        )
        return keys

    # ---- object plane (serialized values) ------------------------------
    def put(self, key: str, value: Any, *, worker: str = "-", if_absent: bool = False) -> bool:
        return self.put_bytes(key, serialization.dumps(value), worker=worker, if_absent=if_absent)

    def get(self, key: str, *, worker: str = "-") -> Any:
        return serialization.loads(self.get_bytes(key, worker=worker))

    def get_many(
        self, keys: List[str], *, worker: str = "-", missing: str = "omit"
    ) -> Dict[str, Any]:
        """Batched object fetch (see :meth:`get_many_bytes` for the cost
        model).  ``missing="omit"`` drops absent keys from the result;
        ``missing="error"`` raises ``KeyError`` naming them."""
        blobs = self.get_many_bytes(keys, worker=worker)
        if missing == "error" and len(blobs) < len(set(keys)):
            absent = [k for k in keys if k not in blobs]
            raise KeyError(f"{len(absent)} keys absent, e.g. {absent[:3]}")
        return {k: serialization.loads(b) for k, b in blobs.items()}

    # Redis-style alias; some call sites read better as multi_get.
    multi_get = get_many

    def put_many(
        self, items: Dict[str, Any], *, worker: str = "-", if_absent: bool = False
    ) -> int:
        """Batched object write (see :meth:`put_many_bytes` for the cost
        model): serialize every value, land the batch in one amortized
        round-trip, wake watchers once.  Returns the number of keys
        written."""
        return self.put_many_bytes(
            {k: serialization.dumps(v) for k, v in items.items()},
            worker=worker,
            if_absent=if_absent,
        )

    def put_content_addressed(self, prefix: str, value: Any, *, worker: str = "-") -> str:
        """PyWren's 'globally unique keys': content-hash the blob.  Duplicate
        puts of identical content are idempotent by construction."""
        key, blob = serialization.dumps_with_key(prefix, value)
        self.put_bytes(key, blob, worker=worker, if_absent=True)
        return key

    # ---- completion signalling (the paper's atomic-result contract) ----
    def publish_result(self, key: str, value: Any, *, worker: str = "-") -> bool:
        """Atomic publish: first writer wins; late/speculative duplicates are
        silently discarded.  Existence of ``key`` == task completion."""
        return self.put(key, value, worker=worker, if_absent=True)

    def watch_tick_s(self, poll_s: Optional[float] = None) -> Optional[float]:
        """Fallback re-check interval for key watchers on this store.

        ``None`` means purely event-driven: every write either goes through
        an in-process handle (which fires ``notify_put``) or is detected by
        the backend's own cross-process watcher (``FileBackend``'s seq-file
        + dirent-mtime ``_PollWatcher``), so waiters never need to poll.
        Only a cross-process backend *without* a watcher returns the
        fallback tick.  An explicit ``poll_s`` always wins
        (backward-compatible knob)."""
        if poll_s is not None:
            return poll_s
        if self.backend.cross_process and not self.backend.self_watching:
            return WATCH_FALLBACK_TICK_S
        return None

    def wait_keys(
        self, keys: List[str], *, poll_s: Optional[float] = None, timeout_s: float = 60.0
    ) -> None:
        """Block until all keys exist (PyWren signals completion 'by the
        existence of this key').  Event-driven: woken by ``notify_put`` the
        moment a publisher on this handle lands a key; on a ``FileBackend``
        a publisher in *another process* is converted into the same wake by
        the backend's watch thread, so there is no polling on any built-in
        backend.  ``poll_s`` is kept for backward compatibility and forces
        a re-check tick; tick-bounded waits are counted in
        ``fallback_tick_waits``."""
        deadline = time.monotonic() + timeout_s
        tick = self.watch_tick_s(poll_s)
        pending = list(keys)
        while True:
            seq = self.put_seq()
            pending = [k for k in pending if not self.backend.exists(k)]
            if not pending:
                return
            now = time.monotonic()
            if now > deadline:
                raise TimeoutError(f"{len(pending)} keys still absent, e.g. {pending[:3]}")
            remaining = deadline - now
            if tick is None:
                self.wait_put(seq, remaining)
            else:
                self.fallback_tick_waits += 1
                self.wait_put(seq, min(tick, remaining))

    def iter_prefix(self, prefix: str, *, worker: str = "-") -> Iterator[Tuple[str, Any]]:
        for key in self.list(prefix, worker=worker):
            yield key, self.get(key, worker=worker)
