"""S3-semantics object store: the bulk state plane of the stateless runtime.

Semantics reproduced from the paper's use of S3:
  * whole-object atomic ``put`` / ``get`` (no partial writes ever visible);
  * ``put_if_absent`` — the atomic-write primitive the paper relies on for
    exactly-once result visibility ("We only need atomic writes to remote
    storage for tracking which functions have succeeded");
  * ``list(prefix)`` for completion polling;
  * **no append** (the paper calls this limitation out in §4) — appends must
    be emulated by writing new keys, exactly as PyWren's shuffle does;
  * integrity: every object carries a sha256 etag.

Backends: in-memory (tests, benchmarks) and file-backed (crash-safe via
``os.replace``; used by checkpointing so restarts survive process death).

Data plane (batching + notification):
  * **batched reads** — ``get_many``/``get_many_bytes`` (alias
    ``multi_get``) coalesce N key fetches into one backend call and charge
    *one* amortized round-trip: a single request latency plus the summed
    transfer time, instead of N× latency.  This is the numpywren lesson —
    object-store cost is dominated by per-request latency, so every
    driver-side fan-in (future resolution, shuffle column reads, parameter
    pulls) should ride a multi-get.  Missing keys are omitted from the
    result dict (callers that need all keys pass ``missing="error"``).
  * **batched writes** — ``put_many``/``put_many_bytes`` are the write-side
    mirror: N objects land in one backend call charged as a single request
    latency plus the summed transfer time (``write_latency + Σbytes/bw``),
    and the whole batch fires **one** ``notify_put`` — waiters wake once
    per batch, not once per object.  ``delete_many`` rides the same
    accounting for teardown (shuffle-intermediate GC, per-job GC).  This is
    the other half of the Fig 5/6 request-count bottleneck: map-side
    fan-outs (``shuffle.write_partitions``, input staging) are request-
    bound, not byte-bound, so pipelining the batch amortizes exactly the
    term that saturates first.  ``if_absent`` batches keep per-key
    first-writer-wins semantics; the return value counts keys won.
  * **key watch** (event-driven completion signalling) — every successful
    ``put_bytes`` through this store handle calls ``notify_put``: a
    broadcast on the store's watch condition plus a monotonically
    increasing put sequence number.  Waiters (``wait_keys``, futures)
    snapshot ``put_seq()``, check key existence, then block in
    ``wait_put`` until the sequence advances — the snapshot-then-wait
    ordering means an in-process publish can never be missed between the
    existence check and the wait.
  * wakeup guarantee is **per backend**: the watch condition and sequence
    live on the backend, so a publish through *any* store handle sharing
    that backend wakes every waiter in this process.  Put events carry the
    *keys* that landed (``puts_since``): completion waits retire exactly
    those keys with O(1) bookkeeping per event instead of re-probing the
    backend per wake (and when they must probe — first pass, unknown-key
    events — they use the batched ``exists_many``, one readdir per key
    directory, never one stat per key).  A *different process* sharing a
    ``FileBackend`` directory publishes without reaching this process's
    condition directly; ``FileBackend`` closes that gap with a
    **cross-process watch**: every mutation appends one framed ``op, key``
    record to a per-root ledger (size is the cross-process write sequence
    — monotone and atomic under ``O_APPEND``; rotated atomically past a
    cap), and a per-backend watch thread (``_PollWatcher``) blocks on
    inotify where available — zero wakeups between events — falling back
    to an exponential-backoff stat poll (fast after a change, backing off
    to a small cap when idle, fully parked while nobody waits), converting
    external writes into in-process ``notify_put`` broadcasts.
    ``wait_keys`` therefore no longer needs its fallback re-check tick on
    any built-in backend; the tick (``WATCH_FALLBACK_TICK_S``) survives
    only for out-of-tree cross-process backends without a watcher, and
    every tick-bounded wait is counted in
    ``ObjectStore.fallback_tick_waits`` so tests can assert the
    event-driven path really is tick-free.

Every operation is charged virtual wire time from a
:class:`~repro.storage.perf_model.StorageProfile` and recorded in a
:class:`Ledger` keyed by the calling worker, which the paper-figure
benchmarks aggregate.
"""

from __future__ import annotations

import os
import threading
import time
import uuid
import weakref
from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

from . import serialization
from .perf_model import S3_2017, StorageProfile

# Store handles pickle BY REFERENCE (like an S3 client: the serialized form
# is an endpoint, not the data).  Functions shipped through the runtime close
# over store handles; on the worker they must resolve to the *same* store.
_HANDLE_REGISTRY: "weakref.WeakValueDictionary[str, Any]" = weakref.WeakValueDictionary()

# Reconnected handles, one per (kind, root) per process: a foreign process
# unpickling N task closures over one directory store shares one handle —
# N private handles would each run their own watcher thread and group-commit
# counter over the same files.
_RECONNECT_CACHE: Dict[Tuple[str, str], Any] = {}
_RECONNECT_LOCK = threading.Lock()


def _reconnect(spec: Dict[str, Any]) -> Any:
    """Rebuild a handle over the same directory substrate in THIS process —
    the moral equivalent of an S3 client re-opening a connection from its
    endpoint URL.  Only file-backed handles carry a spec (their root path
    *is* the endpoint); in-memory handles are process-local by nature."""
    cache_key = (spec["kind"], spec.get("root") or spec.get("addr"))
    with _RECONNECT_LOCK:
        handle = _RECONNECT_CACHE.get(cache_key)
    if handle is not None:
        return handle
    if spec["kind"] == "object":
        handle = ObjectStore(
            backend=FileBackend(spec["root"], fsync=spec.get("fsync", "auto"))
        )
    elif spec["kind"] == "file_kv":
        from .file_kv import FileKVStore  # local import: file_kv imports us

        handle = FileKVStore(
            spec["root"],
            num_shards=int(spec.get("num_shards", 1)),
            engine=spec.get("engine", "log"),
            fsync=spec.get("fsync", "auto"),
        )
    elif spec["kind"] == "net_kv":
        from .net_kv import NetKVStore  # local import: net_kv imports us

        handle = NetKVStore(spec["addr"])
    elif spec["kind"] == "net_obj":
        from .net_kv import NetBackend  # local import: net_kv imports us

        handle = ObjectStore(backend=NetBackend(spec["addr"]))
    else:
        raise RuntimeError(f"unknown storage endpoint spec {spec!r}")
    with _RECONNECT_LOCK:
        return _RECONNECT_CACHE.setdefault(cache_key, handle)


def _resolve_handle(uid: str, spec: Optional[Dict[str, Any]] = None) -> Any:
    try:
        return _HANDLE_REGISTRY[uid]
    except KeyError:
        pass
    if spec is not None:
        return _reconnect(spec)
    raise RuntimeError(
        f"storage handle {uid} not live in this process and it carries no "
        "reconnect spec (in-memory handles cannot cross processes); use a "
        "FileBackend/FileKVStore-backed handle for cross-process jobs"
    )


class _Endpoint:
    """Mixin giving a class by-reference pickling semantics.

    Same process: the unpickled handle IS the original object (registry
    hit).  Foreign process: handles whose state lives on a shared directory
    (``FileBackend``-backed stores, ``FileKVStore``) additionally carry an
    ``_endpoint_spec()`` reconnect recipe, so a task closure registered by
    one driver still resolves its stores after that driver is dead — the
    prerequisite for job adoption (``core/bsp.py``).  In-memory handles
    return no spec and keep raising in a foreign process."""

    def _register_endpoint(self) -> None:
        self._endpoint_uid = f"{type(self).__name__}-{uuid.uuid4().hex}"
        _HANDLE_REGISTRY[self._endpoint_uid] = self

    def _endpoint_spec(self) -> Optional[Dict[str, Any]]:
        return None

    def __reduce__(self):
        return (_resolve_handle, (self._endpoint_uid, self._endpoint_spec()))


@dataclass
class OpRecord:
    worker: str
    op: str  # "get" | "put" | "list" | "delete" | "head"
    key: str
    nbytes: int
    vtime_s: float  # modeled wire duration
    wall_t: float  # real monotonic time of issue (ordering/debug only)


class Ledger:
    """Thread-safe per-worker record of storage ops in virtual time."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: List[OpRecord] = []

    def record(self, rec: OpRecord) -> None:
        with self._lock:
            self._records.append(rec)

    def records(self) -> List[OpRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- aggregation helpers used by benchmarks -------------------------
    def totals(self) -> Dict[str, Tuple[int, float]]:
        """op -> (total bytes, total virtual seconds)."""
        out: Dict[str, Tuple[int, float]] = defaultdict(lambda: (0, 0.0))
        for r in self.records():
            b, t = out[r.op]
            out[r.op] = (b + r.nbytes, t + r.vtime_s)
        return dict(out)

    def per_worker(self) -> Dict[str, Dict[str, Tuple[int, float]]]:
        out: Dict[str, Dict[str, Tuple[int, float]]] = defaultdict(
            lambda: defaultdict(lambda: (0, 0.0))
        )
        for r in self.records():
            b, t = out[r.worker][r.op]
            out[r.worker][r.op] = (b + r.nbytes, t + r.vtime_s)
        return {w: dict(ops) for w, ops in out.items()}


class KeyExistsError(KeyError):
    pass


# Fallback re-check interval for key watchers: covers publishes that bypass
# this store handle's notifications on a cross-process backend *without* a
# watch thread (no built-in backend is one anymore; see _PollWatcher).
WATCH_FALLBACK_TICK_S = 0.25

# _PollWatcher backoff bounds: fast enough after a change that a
# cross-process wake is near-immediate, capped so an idle watcher costs a
# couple of stat() calls per _WATCH_MAX_BACKOFF_S at worst.
_WATCH_MIN_BACKOFF_S = 0.002
_WATCH_MAX_BACKOFF_S = 0.05


class _PollWatcher:
    """Watch filesystem signals for cross-process writes.

    Watches a fixed set of paths by ``stat`` signature ``(size, mtime_ns)``
    — log/sequence files grow monotonically and a POSIX ``rename``/
    ``unlink`` bumps the parent dirent's mtime, so together they cover
    every mutation a foreign process can make.

    Two modes, picked at thread start:

    * **inotify** (Linux, the default where it works) — a ctypes binding
      (:mod:`repro.storage.inotify`) watches the paths' parent directories
      and the thread blocks in ``poll()`` on the inotify fd: *zero* timed
      wakeups between events (``poll_wakeups`` stays 0), wake latency is
      the kernel's, not a backoff bound.  Every event is resolved back to
      changed paths by the same stat-signature comparison, so the contract
      is identical to poll mode.
    * **backoff poll** (portable fallback, ``mode == "poll"``) —
      exponential backoff (reset to ``min_s`` on every observed change)
      and **waiter-gated**: with zero registered waiters the thread parks
      on an event and costs nothing.  Each timed scan increments
      ``poll_wakeups`` (tests assert inotify mode keeps it 0).

    In both modes the comparison baseline persists across idle periods, so
    a write landing while parked is detected on the first pass after a
    waiter registers — the snapshot-then-check-then-wait contract of
    ``wait_put`` does the rest."""

    def __init__(
        self,
        paths: List[str],
        on_change,
        min_s: float = _WATCH_MIN_BACKOFF_S,
        max_s: float = _WATCH_MAX_BACKOFF_S,
        use_inotify: Optional[bool] = None,
    ) -> None:
        self._paths = list(paths)
        self._on_change = on_change
        self._min_s = min_s
        self._max_s = max_s
        self._use_inotify = use_inotify  # None = auto-detect
        self._lock = threading.Lock()
        self._waiters = 0
        self._wake = threading.Event()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self._pipe_r, self._pipe_w = os.pipe()  # close() → wake the poll()
        self.mode = "poll"  # "inotify" once the event loop takes over
        self.poll_wakeups = 0  # timed scans in poll mode (0 under inotify)

    @staticmethod
    def _sig(path: str) -> Tuple[int, int, int]:
        """Change signature: (inode, size, mtime).  The inode matters since
        PR 5 made watched files non-monotone across replacement — KV
        compaction and ledger rotation shrink the file via atomic rename —
        so a shrink-then-regrow to the same size inside one mtime granule
        would collide on (size, mtime) alone; the rename always installs a
        new inode, which cannot collide.  Within one inode the files are
        append-only, so size growth covers the rest."""
        try:
            st = os.stat(path)
        except OSError:
            return (0, 0, 0)
        return (st.st_ino, st.st_size, st.st_mtime_ns)

    def add_waiter(self) -> None:
        with self._lock:
            self._waiters += 1
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, daemon=True, name="fs-watch"
                )
                self._thread.start()
            self._wake.set()

    def remove_waiter(self) -> None:
        with self._lock:
            self._waiters = max(0, self._waiters - 1)

    def close(self) -> None:
        self._closed = True
        self._wake.set()
        with self._lock:
            started = self._thread is not None
            if self._pipe_w is not None:
                try:
                    os.write(self._pipe_w, b"x")
                except OSError:
                    pass
        if not started:
            self._close_pipe()

    def _close_pipe(self) -> None:
        with self._lock:
            for attr in ("_pipe_r", "_pipe_w"):
                fd = getattr(self, attr)
                if fd is not None:
                    try:
                        os.close(fd)
                    except OSError:
                        pass
                    setattr(self, attr, None)

    def _scan(self, last: List[Tuple[int, int]]) -> List[int]:
        """Compare every path's stat signature against ``last`` (updated in
        place); returns the indexes that changed."""
        changed = []
        for i, p in enumerate(self._paths):
            sig = self._sig(p)
            if sig != last[i]:
                last[i] = sig
                changed.append(i)
        return changed

    def _try_inotify(self):
        if self._use_inotify is False:
            return None
        try:
            from .inotify import Inotify

            if not Inotify.available():
                return None
            ino = Inotify()
            seen = set()
            for p in self._paths:
                d = p if os.path.isdir(p) else (os.path.dirname(p) or ".")
                if d not in seen:
                    seen.add(d)
                    ino.add_watch(d)
            return ino
        except Exception:
            return None

    def _run(self) -> None:
        if self._closed:
            self._close_pipe()  # close() deferred cleanup to us
            return
        ino = self._try_inotify()
        try:
            if ino is not None:
                self._run_inotify(ino)
            else:
                self._run_poll()
        finally:
            if ino is not None:
                ino.close()
            self._close_pipe()

    def _run_inotify(self, ino) -> None:
        import select

        self.mode = "inotify"
        last = [self._sig(p) for p in self._paths]
        poller = select.poll()
        poller.register(ino.fileno(), select.POLLIN)
        poller.register(self._pipe_r, select.POLLIN)
        # The baseline above races the mode flip: a write that landed just
        # before is already folded in; one landing after raises an event.
        while not self._closed:
            poller.poll()  # block: no timeout, no timed wakeups
            if self._closed:
                return
            ino.read_events()  # drain the kernel queue (names unused)
            changed = self._scan(last)
            if changed:
                self._on_change(changed)

    def _run_poll(self) -> None:
        last = [self._sig(p) for p in self._paths]
        backoff = self._min_s
        while not self._closed:
            with self._lock:
                idle = self._waiters == 0
                if idle:
                    self._wake.clear()
            if idle:
                # Park until a waiter registers; `last` persists, so writes
                # landing while parked are seen on the first pass after wake.
                self._wake.wait()
                continue
            self.poll_wakeups += 1
            changed = self._scan(last)
            if changed:
                backoff = self._min_s
                self._on_change(changed)
            else:
                backoff = min(backoff * 2.0, self._max_s)
            time.sleep(backoff)


class _Backend:
    # True when writers in *other processes* can mutate the backing state
    # without going through an in-process store handle.  Backends that also
    # run a cross-process watcher (``self_watching``) convert those foreign
    # writes into in-process notifications, so their waiters stay purely
    # event-driven; only a cross-process backend *without* a watcher needs
    # the fallback re-check tick.
    cross_process = False
    self_watching = False

    # True when the backend's own event plane already reports this handle's
    # writes back to it (the net backend: the server pushes a watch frame
    # for every mutation, including ours).  ``ObjectStore`` then skips its
    # local ``notify_put`` after puts — otherwise every batch would wake
    # waiters twice, once locally and once on the echoed event.
    echoes_puts = False

    # True when a put CONSUMES its blob before returning (written to disk,
    # sent on a socket), so callers may hand over a ``memoryview`` of live
    # array memory instead of copying to bytes first.  False for backends
    # that store the reference (the in-memory backend): an aliased view
    # would let later array mutation corrupt the stored object.
    zero_copy_puts = False

    # How many recent put events carry their key lists before waiters must
    # fall back to an existence probe (bounds memory, not correctness).
    _RECENT_PUTS = 512

    def _init_watch(self) -> None:
        """Watch state lives on the *backend*, not the store handle: two
        ``ObjectStore`` handles sharing one backend must wake each other's
        waiters (subclass ``__init__`` calls this)."""
        self._watch_cv = threading.Condition()
        self._watch_seq = 0
        # Ring of (seq, keys-or-None): which keys each recent put event
        # covered.  None = unknown (a cross-process write relayed by a
        # watcher) — consumers must re-probe.
        self._recent_puts: "deque" = deque(maxlen=self._RECENT_PUTS)

    def notify_put(self, keys: Optional[List[str]] = None) -> None:
        """Advance the put sequence and wake waiters.  ``keys`` names what
        just became visible; waiters then retire exactly those keys instead
        of re-probing the backend (``puts_since``).  Pass None when the set
        is unknown (out-of-band/cross-process writes)."""
        with self._watch_cv:
            self._watch_seq += 1
            self._recent_puts.append(
                (self._watch_seq, tuple(keys) if keys is not None else None)
            )
            self._watch_cv.notify_all()

    def put_seq(self) -> int:
        with self._watch_cv:
            return self._watch_seq

    def puts_since(self, last_seq: int) -> Tuple[int, Optional[set]]:
        """(current seq, keys that landed after ``last_seq``) — or
        ``(seq, None)`` when the set is unknown (ring overflow, or any
        event without keys), in which case the caller re-probes.  This is
        what makes an N-task completion wait O(1) bookkeeping per event
        instead of a backend probe per wake."""
        with self._watch_cv:
            cur = self._watch_seq
            if cur == last_seq:
                return cur, set()
            # Ring seqs are contiguous (one entry per bump): complete
            # coverage of (last_seq, cur] iff the ring reaches back far
            # enough and every covered event knows its keys.
            if not self._recent_puts or self._recent_puts[0][0] > last_seq + 1:
                return cur, None
            keys: set = set()
            for seq, ks in self._recent_puts:
                if seq <= last_seq:
                    continue
                if ks is None:
                    return cur, None
                keys.update(ks)
            return cur, keys

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        with self._watch_cv:
            if self._watch_seq == last_seq:
                self._watch_cv.wait(timeout_s)
            return self._watch_seq

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        """Batched fetch: returns present keys only (missing keys omitted).
        Backends override to serve the whole batch in one locked pass."""
        out: Dict[str, bytes] = {}
        for key in keys:
            try:
                out[key] = self.get(key)
            except (KeyError, FileNotFoundError):
                continue
        return out

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        """Batched write: land every item, returning how many were written
        (``if_absent`` keeps per-key first-writer-wins; losers don't count).
        Backends override to serve the whole batch in one locked pass."""
        won = 0
        for key, blob in items.items():
            if self.put(key, blob, if_absent=if_absent):
                won += 1
        return won

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def exists_many(self, keys: List[str]) -> set:
        """Batched existence: the subset of ``keys`` present.  Backends
        override to answer the whole batch in one pass — completion waits
        (futures, ``wait_keys``) re-check every pending key on every wake,
        so per-key probes turn an N-task fan-in into O(N²) stats."""
        return {k for k in keys if self.exists(k)}

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def list(self, prefix: str) -> List[str]:
        raise NotImplementedError


class InMemoryBackend(_Backend):
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._data: Dict[str, bytes] = {}
        self._init_watch()

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        with self._lock:
            if if_absent and key in self._data:
                return False
            self._data[key] = blob
            return True

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        with self._lock:
            return {k: self._data[k] for k in keys if k in self._data}

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        with self._lock:
            won = 0
            for key, blob in items.items():
                if if_absent and key in self._data:
                    continue
                self._data[key] = blob
                won += 1
            return won

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def exists_many(self, keys: List[str]) -> set:
        with self._lock:
            return {k for k in keys if k in self._data}

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def list(self, prefix: str) -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))


class FileBackend(_Backend):
    """Directory-backed store.  Writes are crash-atomic: write temp file,
    then commit — ``os.replace`` for plain puts, ``os.link`` for
    ``if_absent`` puts.  The link either creates the final dirent atomically
    or fails ``EEXIST``, so two *processes* racing a ``put_if_absent``
    cannot both win (the first-writer-wins contract the fenced result
    publishes ride on), and either way only a complete object ever becomes
    visible.

    Durability is a policy (``fsync=``), mirroring ``FileKVStore``'s:
    ``auto`` (default) fsyncs per put for keys under ``durable_prefixes``
    (``ckpt/`` — checkpoints must survive a machine crash) and
    group-commits the rest — one ``os.sync()`` every ``fsync_batch_n``
    puts (objects are distinct files, so a per-file fsync could not flush
    its predecessors; the single syscall flushes them all) and one more on
    ``close()``; ``always`` restores the PR-4 every-put fsync; ``batch``
    group-commits everything; ``never`` is OS-buffered.  *Visibility* is unaffected — the rename/link commit makes
    an object readable by every process immediately; the policy only
    decides what survives a machine (not process) crash.  Data-plane puts
    (``input/``, ``result/``, shuffle intermediates) are re-drivable from
    the job, exactly the paper's recovery story, so they default batched.

    Cross-process watch: every mutation appends one framed record
    (``op, key`` — :func:`repro.storage.kv_store.encode_frame`, the same
    framing as the KV's shard logs) to the root's ``.watch-seq`` ledger
    after it lands, so the ledger's *size* is a monotone cross-process
    write sequence (``O_APPEND`` appends are atomic) and its tail says
    *which* keys moved (debuggability).  The ledger is an event channel,
    not state: when it outgrows a cap it is swapped for a fresh one via
    atomic rename (itself a watchable dirent change).  The first
    ``wait_put`` starts a ``_PollWatcher`` over the ledger plus the root
    dirent's mtime (rename/unlink bump it even for writers that skip the
    ledger append); any observed change fires this process's
    ``notify_put``, so waiters sharing the directory across processes are
    woken without a fallback re-check tick.  The watcher blocks on inotify
    where available and otherwise backoff-polls, waiter-gated."""

    cross_process = True
    self_watching = True
    zero_copy_puts = True  # every put writes the blob out before returning

    _SEQ_NAME = ".watch-seq"
    _SEQ_ROTATE_BYTES = 1 << 20  # swap the event ledger past 1 MiB

    def __init__(
        self,
        root: str,
        *,
        fsync: str = "auto",
        durable_prefixes: Tuple[str, ...] = ("ckpt/",),
        fsync_batch_n: int = 32,
        watch_ledger: bool = True,
    ) -> None:
        if fsync == "commit":
            fsync = "always"  # FileKVStore's name for the same policy
        if fsync not in ("auto", "always", "batch", "never"):
            raise ValueError(f"unknown fsync policy {fsync!r}")
        # watch_ledger=False: skip the .watch-seq append per mutation.  Only
        # for a sole-owner backend whose host pushes its own change events
        # (the repro-kvd server) — with no foreign watchers, the ledger is
        # pure overhead.
        self.watch_ledger = watch_ledger
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.fsync = fsync
        self.durable_prefixes = tuple(durable_prefixes)
        self.fsync_batch_n = fsync_batch_n
        self._puts_since_sync = 0
        self._lock = threading.Lock()
        self._seq_path = os.path.join(self.root, self._SEQ_NAME)
        self._seq_fd: Optional[int] = None  # cached O_APPEND ledger fd
        self._made_dirs: set = set()  # subdirs known created (saves a mkdir RPC)
        self._io_pool = None  # lazy thread pool for batched get/put fan-out
        self._watcher: Optional[_PollWatcher] = None
        self._init_watch()

    # Batches below this size aren't worth the thread-pool handoff.
    _PARALLEL_BATCH_MIN = 8

    def _pool(self):
        """Small worker pool for batched I/O: on a network filesystem each
        open/write/rename is a round trip that releases the GIL, so a
        64-object batch completes in ~8 round-trip times instead of 64."""
        if self._io_pool is None:
            from concurrent.futures import ThreadPoolExecutor

            with self._lock:
                if self._io_pool is None:
                    self._io_pool = ThreadPoolExecutor(
                        max_workers=8, thread_name_prefix="fb-io"
                    )
        return self._io_pool

    # Keys are sharded into one subdirectory per key *directory* (everything
    # up to the last "/", %2F-encoded): ``result/job/t3`` lives at
    # ``root/result%2Fjob/t3``.  A flat directory makes every batched
    # existence probe / prefix list pay a readdir of the WHOLE store — on a
    # network filesystem that turns an N-task completion wait into
    # O(total objects) per wake.  Sharded, a job's probes list only the
    # job's own directory.
    def _split(self, key: str) -> Tuple[str, str]:
        if "/" in key:
            head, base = key.rsplit("/", 1)
            return head.replace("/", "%2F"), base
        return "", key

    def _path(self, key: str) -> str:
        sub, base = self._split(key)
        if not sub:
            return os.path.join(self.root, base)
        return os.path.join(self.root, sub, base)

    def _ensure_dir(self, key: str) -> None:
        sub, _ = self._split(key)
        if sub and sub not in self._made_dirs:
            os.makedirs(os.path.join(self.root, sub), exist_ok=True)
            self._made_dirs.add(sub)

    def _durable(self, key: str) -> bool:
        """Does this put fsync before commit?  (Policy; module docstring.)
        Non-durable puts are group-committed by :meth:`_note_lazy_puts` —
        an ``os.sync()`` every ``fsync_batch_n`` puts — because objects are
        DISTINCT files: fsyncing the Nth file would not flush the N-1
        before it, so per-file fsync cannot implement a group commit."""
        if self.fsync == "always":
            return True
        if self.fsync == "never":
            return False
        return self.fsync == "auto" and key.startswith(self.durable_prefixes)

    def _note_lazy_puts(self, n: int) -> None:
        """Group commit for non-fsynced puts (caller holds the lock): one
        ``os.sync()`` flushes every file the batch dirtied in a single
        syscall, bounding machine-crash data loss to ``fsync_batch_n``
        puts.  ``never`` opts out entirely (OS-buffered)."""
        if self.fsync == "never" or n <= 0:
            return
        self._puts_since_sync += n
        if self._puts_since_sync >= self.fsync_batch_n:
            self._puts_since_sync = 0
            os.sync()

    def _bump_cross_seq(self, op: str, keys) -> None:
        """Advance the cross-process write sequence: one atomic O_APPEND
        frame naming the mutated keys (one frame per batch; caller holds
        ``self._lock``).  Other processes' watchers detect the size growth;
        the ledger is rotated (atomic rename — itself a watchable event)
        once it outgrows the cap, so it never accretes unboundedly.  The fd
        is cached — one write + one fstat per mutation, not open/close round
        trips; the fstat's ``st_nlink`` doubles as the detector for a peer's
        rotation (our append went to the unlinked ledger: re-append to the
        fresh one)."""
        if not self.watch_ledger:
            return
        from .kv_store import encode_frame  # late: kv_store imports us

        frame = encode_frame([(op, k, None) for k in keys])
        st = None
        for _attempt in range(2):
            if self._seq_fd is None:
                self._seq_fd = os.open(
                    self._seq_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644
                )
            os.write(self._seq_fd, frame)
            # The fstat doubles as the rotation-due check AND the detector
            # for a peer having rotated underneath us: st_nlink == 0 means
            # our frame just went to the unlinked ledger where no watcher
            # would ever see it — a lost cross-process wake — so re-append
            # to the live one.  One write + one fstat per mutation (the
            # cached fd already saved the open/close round trips); skipping
            # the fstat would trade a real liveness hole for ~0.4 ms.
            st = os.fstat(self._seq_fd)
            if st.st_nlink > 0:
                break
            os.close(self._seq_fd)
            self._seq_fd = None
        if st is not None and st.st_nlink > 0 and st.st_size > self._SEQ_ROTATE_BYTES:
            os.close(self._seq_fd)
            self._seq_fd = None
            tmp = f"{self._seq_path}.tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "wb"):
                pass
            os.replace(tmp, self._seq_path)

    def _ensure_watcher(self) -> _PollWatcher:
        with self._lock:
            if self._watcher is None:
                self._watcher = _PollWatcher(
                    [self._seq_path, self.root],
                    lambda _changed: self.notify_put(),
                )
            return self._watcher

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        # Register with the cross-process watcher for the duration of the
        # wait: foreign writes become in-process notify_put broadcasts, so
        # the base condition wait needs no fallback tick.
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            return super().wait_put(last_seq, timeout_s)
        finally:
            watcher.remove_waiter()

    def close(self) -> None:
        """Stop the watch thread, flush pending group commits, and release
        cached fds/pools (tests; daemon threads otherwise)."""
        with self._lock:
            if self._watcher is not None:
                self._watcher.close()
                self._watcher = None
            if self._seq_fd is not None:
                os.close(self._seq_fd)
                self._seq_fd = None
            if self._io_pool is not None:
                self._io_pool.shutdown(wait=False)
                self._io_pool = None
            if self._puts_since_sync and self.fsync in ("auto", "batch"):
                self._puts_since_sync = 0
                # reprolint: disable=LOCK001(shutdown-only flush; no concurrent critical section contends for this lock by then)
                os.sync()

    def _put_one(self, key: str, blob: bytes, *, if_absent: bool, durable: bool) -> bool:
        """Land one object (caller holds the lock, decided durability, and
        bumps the seq; thread-safe given distinct keys — batched puts fan
        out over the I/O pool)."""
        self._ensure_dir(key)
        path = self._path(key)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(blob)
            if durable:
                f.flush()
                os.fsync(f.fileno())
        if if_absent:
            # Atomic cross-process first-writer-wins: link either creates
            # the dirent or fails EEXIST — no pre-check needed (a racing
            # process could land between a check and the link anyway, and
            # on the common first-publish path the check is a wasted round
            # trip; a duplicate just pays its tmp write and loses here).
            try:
                os.link(tmp, path)
            except FileExistsError:
                os.remove(tmp)
                return False
            os.remove(tmp)
        else:
            os.replace(tmp, path)
        return True

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        # The object commit itself is lock-free: the tmp name is unique per
        # thread and the final link/replace is atomic, so concurrent puts —
        # even of the same key — race safely (first link wins).  The lock
        # guards only the policy counter and the ledger fd, so N workers
        # publish results concurrently instead of queueing on each other's
        # network-fs round trips.
        durable = self._durable(key)
        if not self._put_one(key, blob, if_absent=if_absent, durable=durable):
            return False
        with self._lock:
            self._note_lazy_puts(0 if durable else 1)
            self._bump_cross_seq("put", [key])
        return True

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        """Batched write: every object lands (fanned out over the I/O pool —
        each commit is an independent round trip on its own key), then ONE
        framed ledger append covers the whole batch — the disk-append
        mirror of the one coalesced ``notify_put`` the store layer fires."""
        durable = {k: self._durable(k) for k in items}
        if len(items) < self._PARALLEL_BATCH_MIN:
            won_keys = [
                k
                for k, blob in items.items()
                if self._put_one(k, blob, if_absent=if_absent, durable=durable[k])
            ]
        else:
            results = list(
                self._pool().map(
                    lambda kv: (
                        kv[0],
                        self._put_one(
                            kv[0], kv[1], if_absent=if_absent, durable=durable[kv[0]]
                        ),
                    ),
                    items.items(),
                )
            )
            won_keys = [k for k, won in results if won]
        if won_keys:
            with self._lock:
                self._note_lazy_puts(sum(1 for k in won_keys if not durable[k]))
                self._bump_cross_seq("put", won_keys)
        return len(won_keys)

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        """Batched fetch, fanned out over the I/O pool: N network-fs opens
        overlap instead of serializing (each is a GIL-releasing round
        trip).  Missing keys are omitted, as in the base contract."""
        if len(keys) < self._PARALLEL_BATCH_MIN:
            return super().get_many(keys)

        def _read(key: str):
            try:
                return key, self.get(key)
            except (KeyError, FileNotFoundError):
                return key, None

        out: Dict[str, bytes] = {}
        for key, blob in self._pool().map(_read, keys):
            if blob is not None:
                out[key] = blob
        return out

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def exists_many(self, keys: List[str]) -> set:
        """One directory listing per key-directory answers the whole batch:
        N stats collapse into a few readdirs — on a network filesystem each
        stat is a round trip, so this is what keeps an N-task completion
        wait O(N) total instead of O(N²).  Thanks to subdirectory sharding
        each readdir covers only the probed keys' own directory (a job's
        results), not the whole store."""
        by_dir: Dict[str, List[Tuple[str, str]]] = {}
        for k in keys:
            sub, base = self._split(k)
            by_dir.setdefault(sub, []).append((k, base))
        present = set()
        for sub, group in by_dir.items():
            if len(group) < 8:
                present.update(k for k, _ in group if self.exists(k))
                continue
            try:
                names = set(os.listdir(os.path.join(self.root, sub)))
            except OSError:
                continue  # directory never created: none of these exist
            present.update(k for k, base in group if base in names)
        return present

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            return
        with self._lock:
            self._bump_cross_seq("del", [key])

    @staticmethod
    def _is_plane_file(name: str) -> bool:
        # temp files and watch-plane files (".watch-seq" etc.)
        return name.startswith(".") or name.endswith(".tmp") or ".tmp." in name

    def list(self, prefix: str) -> List[str]:
        out = []
        try:
            entries = list(os.scandir(self.root))
        except OSError:
            return out
        for entry in entries:
            name = entry.name
            if self._is_plane_file(name):
                continue
            if entry.is_dir():
                decoded = name.replace("%2F", "/")
                # Prune subdirectories that can't hold matching keys.
                head = decoded + "/"
                if not (head.startswith(prefix) or prefix.startswith(head)):
                    continue
                for fname in os.listdir(entry.path):
                    if self._is_plane_file(fname):
                        continue
                    key = head + fname
                    if key.startswith(prefix):
                        out.append(key)
            elif name.startswith(prefix):
                out.append(name)
        return sorted(out)


class ObjectStore(_Endpoint):
    """The remote bulk store.  All durable runtime state lives here."""

    def __init__(
        self,
        backend: Optional[_Backend] = None,
        profile: StorageProfile = S3_2017,
        ledger: Optional[Ledger] = None,
    ) -> None:
        self.backend = backend or InMemoryBackend()
        self.profile = profile
        self.ledger = ledger or Ledger()
        # How many tick-bounded (non-event-driven) waits wait_keys has done
        # on this handle.  Built-in backends are all event-driven now, so
        # tests assert this stays 0; a nonzero count means some waiter fell
        # back to polling (an out-of-tree cross-process backend, or an
        # explicit poll_s).
        self.fallback_tick_waits = 0
        self._register_endpoint()

    def _endpoint_spec(self) -> Optional[Dict[str, Any]]:
        # A FileBackend-backed store reconnects by directory in a foreign
        # process (see _Endpoint); the profile/ledger are per-handle
        # accounting, not shared state, so the reconnected handle gets
        # fresh defaults.
        if isinstance(self.backend, FileBackend):
            return {
                "kind": "object",
                "root": self.backend.root,
                "fsync": self.backend.fsync,
            }
        # Other cross-process backends (the net backend) carry their own
        # endpoint spec — the address is the endpoint.
        spec_fn = getattr(self.backend, "endpoint_spec", None)
        if spec_fn is not None:
            return spec_fn()
        return None

    # ---- key watch (notification plane) --------------------------------
    # Watch state lives on the backend so that two store handles sharing
    # one backend (e.g. two ObjectStores over the same InMemoryBackend)
    # wake each other's waiters; these methods delegate.
    def notify_put(self, key: Optional[str] = None) -> None:
        """Wake every watcher of this store's backend: ``key`` just became
        visible.  Called by ``put_bytes`` on each successful write; external
        feeders writing to the backend out of band may call it too (with no
        key if they don't know what changed — waiters then re-probe)."""
        self.backend.notify_put([key] if key is not None else None)

    def put_seq(self) -> int:
        """Snapshot of the backend's put counter; pass to :meth:`wait_put`."""
        return self.backend.put_seq()

    def puts_since(self, last_seq: int):
        """Delegates to the backend: see ``_Backend.puts_since``."""
        return self.backend.puts_since(last_seq)

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        """Block until any put lands on the backend after the ``last_seq``
        snapshot (or the timeout elapses); returns the current sequence."""
        return self.backend.wait_put(last_seq, timeout_s)

    # ---- raw byte plane ------------------------------------------------
    def put_bytes(
        self, key: str, blob: bytes, *, worker: str = "-", if_absent: bool = False
    ) -> bool:
        won = self.backend.put(key, blob, if_absent=if_absent)
        self.ledger.record(
            OpRecord(worker, "put", key, len(blob), self.profile.write_time(len(blob)), time.monotonic())
        )
        if won and not self.backend.echoes_puts:
            self.notify_put(key)
        return won

    def put_many_bytes(
        self, items: Dict[str, bytes], *, worker: str = "-", if_absent: bool = False
    ) -> int:
        """Batched write: one backend call, one amortized round-trip.

        Mirrors :meth:`get_many_bytes` on the write side — N objects cost
        ``write_latency + Σbytes/bw`` instead of ``N·latency + …``, the
        pipelined-PUT amortization.  The whole batch fires exactly one
        ``notify_put`` (waiters re-check their predicate once per batch).
        Returns the number of keys written; with ``if_absent=True`` each key
        keeps first-writer-wins semantics and losers are not counted."""
        if not items:
            return 0
        won = self.backend.put_many(dict(items), if_absent=if_absent)
        total = sum(len(b) for b in items.values())
        vt = self.profile.write_latency_s + total / self.profile.write_bw_per_conn
        self.ledger.record(
            OpRecord(worker, "mput", f"[{len(items)} keys]", total, vt, time.monotonic())
        )
        if won and not self.backend.echoes_puts:
            # All batch keys are visible now (if_absent losers existed
            # already), so the single coalesced wakeup can name them all.
            self.backend.notify_put(list(items.keys()))
        return won

    def get_bytes(self, key: str, *, worker: str = "-") -> bytes:
        blob = self.backend.get(key)
        self.ledger.record(
            OpRecord(worker, "get", key, len(blob), self.profile.read_time(len(blob)), time.monotonic())
        )
        return blob

    def get_many_bytes(self, keys: List[str], *, worker: str = "-") -> Dict[str, bytes]:
        """Batched fetch: one backend call, one amortized round-trip.

        Charged as a single request latency plus the summed transfer time —
        N keys cost ``latency + Σbytes/bw`` instead of ``N·latency + …``.
        Missing keys are omitted from the returned dict."""
        blobs = self.backend.get_many(list(keys))
        total = sum(len(b) for b in blobs.values())
        vt = self.profile.read_latency_s + total / self.profile.read_bw_per_conn
        self.ledger.record(
            OpRecord(worker, "mget", f"[{len(keys)} keys]", total, vt, time.monotonic())
        )
        return blobs

    def exists(self, key: str, *, worker: str = "-") -> bool:
        ok = self.backend.exists(key)
        self.ledger.record(
            OpRecord(worker, "head", key, 0, self.profile.read_latency_s, time.monotonic())
        )
        return ok

    def exists_many(self, keys: List[str], *, worker: str = "-") -> set:
        """Batched existence probe: the subset of ``keys`` present, charged
        as one amortized round-trip (HEADs are request-bound, exactly like
        ``mdel``).  Completion waits ride this — see ``wait_keys``."""
        present = self.backend.exists_many(list(keys))
        self.ledger.record(
            OpRecord(
                worker, "mhead", f"[{len(keys)} keys]", 0,
                self.profile.read_latency_s, time.monotonic(),
            )
        )
        return present

    def delete(self, key: str, *, worker: str = "-") -> None:
        self.backend.delete(key)
        self.ledger.record(
            OpRecord(worker, "delete", key, 0, self.profile.write_latency_s, time.monotonic())
        )

    def delete_many(self, keys: List[str], *, worker: str = "-") -> None:
        """Batched delete: one amortized round-trip for the whole batch
        (cf. :meth:`get_many_bytes` — per-request latency, not bytes,
        dominates deletes)."""
        for k in keys:
            # reprolint: disable=BATCH001(this IS the batched verb: backend deletes are local unlinks, charged one amortized round-trip below)
            self.backend.delete(k)
        self.ledger.record(
            OpRecord(
                worker, "mdel", f"[{len(keys)} keys]", 0,
                self.profile.write_latency_s, time.monotonic(),
            )
        )

    def delete_prefix(self, prefix: str, *, worker: str = "-") -> int:
        """Delete every key under ``prefix`` (job GC); one list + one
        batched delete round-trip.  Returns the count."""
        keys = self.list(prefix, worker=worker)
        if keys:
            self.delete_many(keys, worker=worker)
        return len(keys)

    def list(self, prefix: str, *, worker: str = "-") -> List[str]:
        keys = self.backend.list(prefix)
        self.ledger.record(
            OpRecord(worker, "list", prefix, 0, self.profile.read_latency_s, time.monotonic())
        )
        return keys

    # ---- object plane (serialized values) ------------------------------
    def put(self, key: str, value: Any, *, worker: str = "-", if_absent: bool = False) -> bool:
        return self.put_bytes(key, serialization.dumps(value), worker=worker, if_absent=if_absent)

    def get(self, key: str, *, worker: str = "-") -> Any:
        return serialization.loads(self.get_bytes(key, worker=worker))

    def get_many(
        self, keys: List[str], *, worker: str = "-", missing: str = "omit"
    ) -> Dict[str, Any]:
        """Batched object fetch (see :meth:`get_many_bytes` for the cost
        model).  ``missing="omit"`` drops absent keys from the result;
        ``missing="error"`` raises ``KeyError`` naming them."""
        blobs = self.get_many_bytes(keys, worker=worker)
        if missing == "error" and len(blobs) < len(set(keys)):
            absent = [k for k in keys if k not in blobs]
            raise KeyError(f"{len(absent)} keys absent, e.g. {absent[:3]}")
        return {k: serialization.loads(b) for k, b in blobs.items()}

    # Redis-style alias; some call sites read better as multi_get.
    multi_get = get_many

    def put_many(
        self, items: Dict[str, Any], *, worker: str = "-", if_absent: bool = False
    ) -> int:
        """Batched object write (see :meth:`put_many_bytes` for the cost
        model): serialize every value, land the batch in one amortized
        round-trip, wake watchers once.  Returns the number of keys
        written."""
        return self.put_many_bytes(
            {k: serialization.dumps(v) for k, v in items.items()},
            worker=worker,
            if_absent=if_absent,
        )

    def put_content_addressed(self, prefix: str, value: Any, *, worker: str = "-") -> str:
        """PyWren's 'globally unique keys': content-hash the blob.  Duplicate
        puts of identical content are idempotent by construction."""
        key, blob = serialization.dumps_with_key(prefix, value)
        self.put_bytes(key, blob, worker=worker, if_absent=True)
        return key

    # ---- completion signalling (the paper's atomic-result contract) ----
    def publish_result(self, key: str, value: Any, *, worker: str = "-") -> bool:
        """Atomic publish: first writer wins; late/speculative duplicates are
        silently discarded.  Existence of ``key`` == task completion."""
        return self.put(key, value, worker=worker, if_absent=True)

    def watch_tick_s(self, poll_s: Optional[float] = None) -> Optional[float]:
        """Fallback re-check interval for key watchers on this store.

        ``None`` means purely event-driven: every write either goes through
        an in-process handle (which fires ``notify_put``) or is detected by
        the backend's own cross-process watcher (``FileBackend``'s seq-file
        + dirent-mtime ``_PollWatcher``), so waiters never need to poll.
        Only a cross-process backend *without* a watcher returns the
        fallback tick.  An explicit ``poll_s`` always wins
        (backward-compatible knob)."""
        if poll_s is not None:
            return poll_s
        if self.backend.cross_process and not self.backend.self_watching:
            return WATCH_FALLBACK_TICK_S
        return None

    def wait_keys(
        self, keys: List[str], *, poll_s: Optional[float] = None, timeout_s: float = 60.0
    ) -> None:
        """Block until all keys exist (PyWren signals completion 'by the
        existence of this key').  Event-driven: woken by ``notify_put`` the
        moment a publisher on this handle lands a key; on a ``FileBackend``
        a publisher in *another process* is converted into the same wake by
        the backend's watch thread, so there is no polling on any built-in
        backend.  ``poll_s`` is kept for backward compatibility and forces
        a re-check tick; tick-bounded waits are counted in
        ``fallback_tick_waits``."""
        deadline = time.monotonic() + timeout_s
        tick = self.watch_tick_s(poll_s)
        pending = list(keys)
        seq: Optional[int] = None
        while True:
            if seq is None or tick is not None:
                # Full probe: first pass, tick mode (out-of-band writers),
                # or an event whose key set was unknown.  One batched
                # existence check per wake — a completion burst costs one
                # readdir, not one stat per still-pending key.
                seq = self.put_seq()
                present = self.backend.exists_many(pending)
            else:
                # Incremental: consume exactly the keys recent put events
                # named — O(1) bookkeeping per event, no backend probe.
                seq, landed = self.puts_since(seq)
                if landed is None:
                    present = self.backend.exists_many(pending)
                else:
                    present = landed
            pending = [k for k in pending if k not in present]
            if not pending:
                return
            now = time.monotonic()
            if now > deadline:
                raise TimeoutError(f"{len(pending)} keys still absent, e.g. {pending[:3]}")
            remaining = deadline - now
            if tick is None:
                self.wait_put(seq, remaining)
            else:
                self.fallback_tick_waits += 1
                self.wait_put(seq, min(tick, remaining))

    def iter_prefix(self, prefix: str, *, worker: str = "-") -> Iterator[Tuple[str, Any]]:
        for key in self.list(prefix, worker=worker):
            yield key, self.get(key, worker=worker)
