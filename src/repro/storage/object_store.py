"""S3-semantics object store: the bulk state plane of the stateless runtime.

Semantics reproduced from the paper's use of S3:
  * whole-object atomic ``put`` / ``get`` (no partial writes ever visible);
  * ``put_if_absent`` — the atomic-write primitive the paper relies on for
    exactly-once result visibility ("We only need atomic writes to remote
    storage for tracking which functions have succeeded");
  * ``list(prefix)`` for completion polling;
  * **no append** (the paper calls this limitation out in §4) — appends must
    be emulated by writing new keys, exactly as PyWren's shuffle does;
  * integrity: every object carries a sha256 etag.

Backends: in-memory (tests, benchmarks) and file-backed (crash-safe via
``os.replace``; used by checkpointing so restarts survive process death).

Key-watch facility (event-driven completion signalling):
  * every successful ``put_bytes`` through this store handle calls
    ``notify_put`` — a broadcast on the store's watch condition plus a
    monotonically increasing put sequence number;
  * waiters (``wait_keys``, futures) snapshot ``put_seq()``, check key
    existence, then block in ``wait_put`` until the sequence advances —
    the snapshot-then-wait ordering means an in-process publish can never
    be missed between the existence check and the wait;
  * wakeup guarantee is **per store handle**: a publish through a
    different handle or process (e.g. another process sharing a
    ``FileBackend`` directory) does not notify, so waiters also re-check
    existence on a short fallback tick (``WATCH_FALLBACK_TICK_S``).

Every operation is charged virtual wire time from a
:class:`~repro.storage.perf_model.StorageProfile` and recorded in a
:class:`Ledger` keyed by the calling worker, which the paper-figure
benchmarks aggregate.
"""

from __future__ import annotations

import os
import threading
import time
import uuid
import weakref
from collections import defaultdict
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

from . import serialization
from .perf_model import S3_2017, StorageProfile

# Store handles pickle BY REFERENCE (like an S3 client: the serialized form
# is an endpoint, not the data).  Functions shipped through the runtime close
# over store handles; on the worker they must resolve to the *same* store.
_HANDLE_REGISTRY: "weakref.WeakValueDictionary[str, Any]" = weakref.WeakValueDictionary()


def _resolve_handle(uid: str) -> Any:
    try:
        return _HANDLE_REGISTRY[uid]
    except KeyError:
        raise RuntimeError(
            f"storage handle {uid} not live in this process; in a real "
            "deployment this would reconnect to the remote endpoint"
        ) from None


class _Endpoint:
    """Mixin giving a class by-reference pickling semantics."""

    def _register_endpoint(self) -> None:
        self._endpoint_uid = f"{type(self).__name__}-{uuid.uuid4().hex}"
        _HANDLE_REGISTRY[self._endpoint_uid] = self

    def __reduce__(self):
        return (_resolve_handle, (self._endpoint_uid,))


@dataclass
class OpRecord:
    worker: str
    op: str  # "get" | "put" | "list" | "delete" | "head"
    key: str
    nbytes: int
    vtime_s: float  # modeled wire duration
    wall_t: float  # real monotonic time of issue (ordering/debug only)


class Ledger:
    """Thread-safe per-worker record of storage ops in virtual time."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: List[OpRecord] = []

    def record(self, rec: OpRecord) -> None:
        with self._lock:
            self._records.append(rec)

    def records(self) -> List[OpRecord]:
        with self._lock:
            return list(self._records)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- aggregation helpers used by benchmarks -------------------------
    def totals(self) -> Dict[str, Tuple[int, float]]:
        """op -> (total bytes, total virtual seconds)."""
        out: Dict[str, Tuple[int, float]] = defaultdict(lambda: (0, 0.0))
        for r in self.records():
            b, t = out[r.op]
            out[r.op] = (b + r.nbytes, t + r.vtime_s)
        return dict(out)

    def per_worker(self) -> Dict[str, Dict[str, Tuple[int, float]]]:
        out: Dict[str, Dict[str, Tuple[int, float]]] = defaultdict(
            lambda: defaultdict(lambda: (0, 0.0))
        )
        for r in self.records():
            b, t = out[r.worker][r.op]
            out[r.worker][r.op] = (b + r.nbytes, t + r.vtime_s)
        return {w: dict(ops) for w, ops in out.items()}


class KeyExistsError(KeyError):
    pass


# Fallback re-check interval for key watchers: covers publishes that bypass
# this store handle's notifications (other processes on a FileBackend).
WATCH_FALLBACK_TICK_S = 0.25


class _Backend:
    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def list(self, prefix: str) -> List[str]:
        raise NotImplementedError


class InMemoryBackend(_Backend):
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._data: Dict[str, bytes] = {}

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        with self._lock:
            if if_absent and key in self._data:
                return False
            self._data[key] = blob
            return True

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def list(self, prefix: str) -> List[str]:
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))


class FileBackend(_Backend):
    """Directory-backed store.  Writes are crash-atomic: write temp file,
    fsync, ``os.replace``.  ``put_if_absent`` uses O_EXCL on the final name's
    lock sibling so two processes cannot both win."""

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        safe = key.replace("/", "%2F")
        return os.path.join(self.root, safe)

    def _unpath(self, name: str) -> str:
        return name.replace("%2F", "/")

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        path = self._path(key)
        with self._lock:
            if if_absent and os.path.exists(path):
                return False
            tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            return True

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def list(self, prefix: str) -> List[str]:
        out = []
        for name in os.listdir(self.root):
            if name.endswith((".tmp",)) or ".tmp." in name:
                continue
            key = self._unpath(name)
            if key.startswith(prefix):
                out.append(key)
        return sorted(out)


class ObjectStore(_Endpoint):
    """The remote bulk store.  All durable runtime state lives here."""

    def __init__(
        self,
        backend: Optional[_Backend] = None,
        profile: StorageProfile = S3_2017,
        ledger: Optional[Ledger] = None,
    ) -> None:
        self.backend = backend or InMemoryBackend()
        self.profile = profile
        self.ledger = ledger or Ledger()
        self._watch_cv = threading.Condition()
        self._put_seq = 0
        self._register_endpoint()

    # ---- key watch (notification plane) --------------------------------
    def notify_put(self, key: str) -> None:
        """Wake every watcher: ``key`` just became visible.  Called by
        ``put_bytes`` on each successful write; external backends fed out of
        band may call it too."""
        with self._watch_cv:
            self._put_seq += 1
            self._watch_cv.notify_all()

    def put_seq(self) -> int:
        """Snapshot of the put counter; pass to :meth:`wait_put`."""
        with self._watch_cv:
            return self._put_seq

    def wait_put(self, last_seq: int, timeout_s: float) -> int:
        """Block until any put lands after the ``last_seq`` snapshot (or the
        timeout elapses); returns the current sequence."""
        with self._watch_cv:
            if self._put_seq == last_seq:
                self._watch_cv.wait(timeout_s)
            return self._put_seq

    # ---- raw byte plane ------------------------------------------------
    def put_bytes(
        self, key: str, blob: bytes, *, worker: str = "-", if_absent: bool = False
    ) -> bool:
        won = self.backend.put(key, blob, if_absent=if_absent)
        self.ledger.record(
            OpRecord(worker, "put", key, len(blob), self.profile.write_time(len(blob)), time.monotonic())
        )
        if won:
            self.notify_put(key)
        return won

    def get_bytes(self, key: str, *, worker: str = "-") -> bytes:
        blob = self.backend.get(key)
        self.ledger.record(
            OpRecord(worker, "get", key, len(blob), self.profile.read_time(len(blob)), time.monotonic())
        )
        return blob

    def exists(self, key: str, *, worker: str = "-") -> bool:
        ok = self.backend.exists(key)
        self.ledger.record(
            OpRecord(worker, "head", key, 0, self.profile.read_latency_s, time.monotonic())
        )
        return ok

    def delete(self, key: str, *, worker: str = "-") -> None:
        self.backend.delete(key)
        self.ledger.record(
            OpRecord(worker, "delete", key, 0, self.profile.write_latency_s, time.monotonic())
        )

    def list(self, prefix: str, *, worker: str = "-") -> List[str]:
        keys = self.backend.list(prefix)
        self.ledger.record(
            OpRecord(worker, "list", prefix, 0, self.profile.read_latency_s, time.monotonic())
        )
        return keys

    # ---- object plane (serialized values) ------------------------------
    def put(self, key: str, value: Any, *, worker: str = "-", if_absent: bool = False) -> bool:
        return self.put_bytes(key, serialization.dumps(value), worker=worker, if_absent=if_absent)

    def get(self, key: str, *, worker: str = "-") -> Any:
        return serialization.loads(self.get_bytes(key, worker=worker))

    def put_content_addressed(self, prefix: str, value: Any, *, worker: str = "-") -> str:
        """PyWren's 'globally unique keys': content-hash the blob.  Duplicate
        puts of identical content are idempotent by construction."""
        key, blob = serialization.dumps_with_key(prefix, value)
        self.put_bytes(key, blob, worker=worker, if_absent=True)
        return key

    # ---- completion signalling (the paper's atomic-result contract) ----
    def publish_result(self, key: str, value: Any, *, worker: str = "-") -> bool:
        """Atomic publish: first writer wins; late/speculative duplicates are
        silently discarded.  Existence of ``key`` == task completion."""
        return self.put(key, value, worker=worker, if_absent=True)

    def wait_keys(
        self, keys: List[str], *, poll_s: Optional[float] = None, timeout_s: float = 60.0
    ) -> None:
        """Block until all keys exist (PyWren signals completion 'by the
        existence of this key').  Event-driven: woken by ``notify_put`` the
        moment a publisher on this handle lands a key; re-checks on a short
        fallback tick only to cover out-of-band writers.  ``poll_s`` is kept
        for backward compatibility and overrides the fallback tick."""
        deadline = time.monotonic() + timeout_s
        tick = WATCH_FALLBACK_TICK_S if poll_s is None else poll_s
        pending = list(keys)
        while True:
            seq = self.put_seq()
            pending = [k for k in pending if not self.backend.exists(k)]
            if not pending:
                return
            now = time.monotonic()
            if now > deadline:
                raise TimeoutError(f"{len(pending)} keys still absent, e.g. {pending[:3]}")
            self.wait_put(seq, min(tick, deadline - now))

    def iter_prefix(self, prefix: str, *, worker: str = "-") -> Iterator[Tuple[str, Any]]:
        for key in self.list(prefix, worker=worker):
            yield key, self.get(key, worker=worker)
