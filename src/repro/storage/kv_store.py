"""Redis-semantics low-latency KV store: the coordination plane.

The paper uses ElastiCache/Redis for (a) small synchronous put/gets (Fig 4),
(b) shuffle intermediates when S3 request throughput is the bottleneck
(Fig 5/6), and (c) parameter servers with server-side scripting for range
updates / flexible consistency (§3.3).

Reproduced semantics:
  * sharded keyspace (consistent hashing over N shards, each shard has its
    own request-throughput budget — the Fig 5/6 bottleneck);
  * atomic single-key ops: get/set/setnx/incr/cas/delete;
  * ``eval`` — server-side scripting analogue: apply a Python callable to a
    key's value *atomically under the shard lock* (Redis EVAL), used by the
    parameter server for in-place range updates (HOGWILD!);
  * lists (rpush/lrange) for queues, plus blocking ``blpop`` (Redis BLPOP).

Data plane (batching + per-shard notification):
  * **batched reads** — ``mget`` groups its keys by shard and serves each
    shard's group in one locked pass, charged as one amortized round-trip
    per *shard touched* (one request latency + summed transfer time) rather
    than one per key.  The Cloudburst/numpywren lesson applied to the
    coordination plane: parameter-server pulls and shuffle column reads
    cost O(shards) requests, not O(keys).
  * **batched writes** — ``mset`` (Redis MSET), pipelined ``rpush_many``,
    and ``eval_many`` (pipelined EVAL) mirror ``mget`` on the write side:
    keys are grouped by shard, each shard's group lands in one locked pass
    charged as one amortized round-trip (request latency + summed
    transfer), and each touched shard's sequence is bumped **exactly
    once** — a batch of N writes wakes each shard's watchers once, not N
    times.  Shuffle map-side fan-out, parameter-server pushes, and
    scheduler batch-submits ride these; ``mdel`` closes the lifecycle with
    the same per-shard accounting.
  * **per-shard watch conditions** — every mutating op (``set``/``setnx``/
    ``incr``/``cas``/``eval``/``rpush``/``delete``) bumps its shard's write
    sequence and broadcasts on the shard's condition.  Consumers snapshot
    ``shard_seq(key)``, check state, then block in ``wait_key`` until the
    shard's sequence advances (snapshot-then-wait: an in-process write can
    never be missed between the check and the wait).  ``blpop`` builds the
    Redis blocking-pop on top.  Scheduler queue waits and parameter-server
    pullers block here — per shard, woken only by writes that could matter
    to them — instead of riding a global poll tick.
  * wakeups from *this* class are in-process (it is an in-memory model);
    :class:`~repro.storage.file_kv.FileKVStore` extends the identical
    contract across processes via per-shard seq files and a watch thread,
    so multi-process drivers get event-driven ``blpop``/``wait_key`` too.

Each op is charged virtual wire time and recorded per shard so benchmarks
can detect shard saturation exactly like the paper's sort experiment.
"""

from __future__ import annotations

import pickle
import struct
import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple

from .object_store import Ledger, OpRecord, _Endpoint
from .perf_model import REDIS_2017, StorageProfile

_TOMBSTONE = object()

# Sentinel an ``eval``/``eval_many`` update function may return to delete
# the key atomically instead of storing a value — the Redis-script idiom
# ``if ok then redis.call('DEL', key) end`` used by fenced lease releases:
# compare-epoch-then-delete must be one atomic step or a zombie's heartbeat
# could slip between the compare and the delete.  It must survive a pickle
# round-trip as the SAME object (update closures ship to repro-kvd, whose
# ``is DELETE`` check runs in another process), so it reduces to the
# module singleton rather than to a fresh anonymous ``object()``.
class _DeleteSentinel:
    __slots__ = ()

    def __repr__(self) -> str:
        return "DELETE"

    def __reduce__(self):
        return (_delete_sentinel, ())


def _delete_sentinel() -> "_DeleteSentinel":
    return DELETE


DELETE = _DeleteSentinel()


def kv_pure(fn):
    """Mark an eval function as PURE for the KV engines: it neither mutates
    its argument in place nor is its key's stored value mutated in place by
    any other writer.  A wire server may then hand the stored object to the
    function directly and return it as the pre-image without the defensive
    ``pickle`` deep-copy it otherwise pays per key (material on eval-heavy
    hot paths — lease records carry whole task specs).  Purity survives the
    wire: partials of a marked module function pickle by reference, so the
    marker is on the server-side unpickled function too."""
    fn.__kv_pure__ = True
    return fn


@dataclass
class ShardStats:
    ops: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    vtime_s: float = 0.0


# How many (seq, keys) touch records each shard remembers for keyed wakes —
# the KV mirror of ``_Backend._RECENT_PUTS`` in object_store.py.
_SHARD_RECENT = 512


class _Shard:
    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.lock = threading.RLock()
        # Watch condition shares the shard lock: writers notify while
        # already holding it, so notification adds no extra locking.
        self.cond = threading.Condition(self.lock)
        self.seq = 0  # monotonically increasing write sequence
        self.data: Dict[str, Any] = {}
        self.stats = ShardStats()
        # Ring of (seq, frozenset(keys) | None) per touch: lets keyed
        # waiters prove a wake named only other keys.  None = unknown
        # (virtual touch, cross-process file watch, ring overflow).
        self.recent: deque = deque(maxlen=_SHARD_RECENT)
        self.skipped_wakes = 0  # foreign-key wakes absorbed by wait_key

    def touch(self, keys: Optional[Iterable[str]] = None) -> None:
        """Record a write: bump the sequence, wake every shard watcher.
        ``keys`` names what the write touched so keyed waiters
        (:meth:`KVStore.wait_key`) can absorb wakes that provably do not
        concern them; ``None`` means unknown — treat as touching anything.
        Must be called with the shard lock held."""
        self.seq += 1
        self.recent.append((self.seq, None if keys is None else frozenset(keys)))
        self.cond.notify_all()


def _sizeof(value: Any) -> int:
    if isinstance(value, bytes):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (int, float)):
        return 8
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, (list, tuple)):
        return sum(_sizeof(v) for v in value) + 8
    if isinstance(value, dict):
        return sum(_sizeof(k) + _sizeof(v) for k, v in value.items()) + 8
    return 64  # opaque


# ---------------------------------------------------------------------------
# Record framing for append-only logs (shared by FileKVStore's per-shard
# logs and FileBackend's watch ledger).
#
# One *frame* is one commit: a length/CRC header followed by a pickled list
# of state-delta records.  The header makes torn tails self-detecting — a
# writer killed mid-append leaves either a short header, a short payload, or
# a CRC mismatch, and replay stops at the last whole frame (the committed
# prefix).  Records are state *deltas*, not operations, so replaying a log
# over the snapshot it was appended after reconstructs the exact state:
#
#   ("s", key, value)   set key to value          (set/incr/cas/eval/mset …)
#   ("d", key, None)    delete key                (delete/mdel/eval→DELETE)
#   ("a", key, [v, …])  extend key's list         (rpush/rpush_many)
#   ("p", key, n)       drop n items from the left of key's list (lpop/blpop)
#
# List ops get their own compact deltas because queues are the hottest keys:
# an rpush frame carries only the pushed values, never the whole list.
# ---------------------------------------------------------------------------

_FRAME_HDR = struct.Struct("<II")  # (payload length, crc32(payload))

# Wire-protocol buffer frames (PR 9): bit 31 of the length field marks a
# frame whose payload is RAW BYTES, not a pickle — ndarray/blob payloads
# travel out-of-band from the pickled verb header so neither side copies
# them through the codec.  The bit is free: payload lengths are capped at
# MAX_FRAME_LEN (1 << 30) everywhere a frame is decoded, so a legitimate
# length never sets it.  Shard logs never use buffer frames; the flag
# lives here only because the wire protocol shares this header struct.
BUF_FLAG = 1 << 31

# Log files open with a fixed header naming the *generation* — bumped by
# every compaction, so a snapshot and the log it supersedes can never be
# replayed together (see file_kv.py's compaction protocol).
LOG_MAGIC = b"WKV1"
_LOG_HDR = struct.Struct("<4sQ")  # (magic, generation)
LOG_HEADER_SIZE = _LOG_HDR.size


def encode_log_header(generation: int) -> bytes:
    return _LOG_HDR.pack(LOG_MAGIC, generation)


def decode_log_header(buf: bytes) -> Optional[int]:
    """Generation from a log header, or None if short/corrupt."""
    if len(buf) < _LOG_HDR.size:
        return None
    magic, gen = _LOG_HDR.unpack_from(buf)
    if magic != LOG_MAGIC:
        return None
    return gen


def encode_frame(records: List[Tuple[str, str, Any]]) -> bytes:
    """Frame one commit's delta records: ``[len][crc32][pickle(records)]``."""
    payload = pickle.dumps(records, protocol=pickle.HIGHEST_PROTOCOL)
    return _FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload


def iter_frames(
    buf: bytes, start: int = 0
) -> Iterator[Tuple[List[Tuple[str, str, Any]], int]]:
    """Yield ``(records, end_offset)`` for every whole frame in ``buf``.

    Stops silently at the first torn frame (short header, short payload, or
    CRC mismatch): everything before it is the committed prefix, everything
    from it on is a crashed writer's garbage."""
    off = start
    n = len(buf)
    while off + _FRAME_HDR.size <= n:
        length, crc = _FRAME_HDR.unpack_from(buf, off)
        end = off + _FRAME_HDR.size + length
        if end > n:
            return  # torn payload
        payload = buf[off + _FRAME_HDR.size : end]
        if zlib.crc32(payload) != crc:
            return  # torn/corrupt frame
        yield pickle.loads(payload), end
        off = end


def apply_record(state: Dict[str, Any], rec: Tuple[str, str, Any]) -> None:
    """Apply one framed state-delta record to ``state`` (replay)."""
    op, key, val = rec
    if op == "s":
        state[key] = val
    elif op == "d":
        state.pop(key, None)
    elif op == "a":
        state.setdefault(key, []).extend(val)
    elif op == "p":
        lst = state.get(key)
        if lst:
            del lst[:val]
    else:  # pragma: no cover - forward-compat guard
        raise ValueError(f"unknown log record op {op!r}")


class KVStore(_Endpoint):
    """Sharded in-memory KV store with Redis-like atomic ops."""

    def __init__(
        self,
        num_shards: int = 1,
        profile: StorageProfile = REDIS_2017,
        ledger: Optional[Ledger] = None,
        *,
        charged: bool = True,
    ) -> None:
        if num_shards < 1:
            raise ValueError("num_shards >= 1")
        self.num_shards = num_shards
        self.profile = profile
        self.ledger = ledger or Ledger()
        # charged=False skips per-op accounting entirely — for engine-role
        # handles whose ledger nobody reads (the repro-kvd server charges
        # nothing; its CLIENTS charge, so the modeled ledger is theirs).
        self.charged = charged
        self._shards = [_Shard(i) for i in range(num_shards)]
        self._register_endpoint()

    # ---- sharding ------------------------------------------------------
    def shard_of(self, key: str) -> int:
        return zlib.crc32(key.encode()) % self.num_shards

    def _shard(self, key: str) -> _Shard:
        return self._shards[self.shard_of(key)]

    def _charge(
        self, shard: _Shard, worker: str, op: str, key: str, nbytes: int, write: bool
    ) -> None:
        if not self.charged:
            return
        vt = self.profile.write_time(nbytes) if write else self.profile.read_time(nbytes)
        shard.stats.ops += 1
        shard.stats.vtime_s += vt
        if write:
            shard.stats.bytes_in += nbytes
        else:
            shard.stats.bytes_out += nbytes
        self.ledger.record(OpRecord(worker, op, key, nbytes, vt, time.monotonic()))

    # ---- per-shard watch (notification plane) ---------------------------
    def shard_seq(self, key: str) -> int:
        """Snapshot the write sequence of ``key``'s shard; pass to
        :meth:`wait_key`.  Snapshot-then-check-then-wait makes an in-process
        write impossible to miss."""
        sh = self._shard(key)
        with sh.lock:
            return sh.seq

    def wait_key(self, key: str, last_seq: int, timeout_s: float) -> int:
        """Block until a write lands on ``key`` — not merely its shard —
        after the ``last_seq`` snapshot (or the timeout elapses); returns
        the current sequence.  Wakes are *keyed*: every touch records which
        keys it wrote (a ``puts_since``-style ring, mirroring the object
        store), and a wake whose key set provably excludes ``key`` is
        absorbed here instead of bouncing the caller into a futile
        predicate re-check.  A wake with unknown keys (virtual touch,
        cross-process file watch, ring overflow) conservatively returns.
        Callers still loop and re-check their own predicate, exactly like
        ``ObjectStore.wait_put``."""
        sh = self._shard(key)
        deadline = time.monotonic() + timeout_s
        with sh.lock:
            while True:
                if sh.seq != last_seq:
                    if self._touched(sh, key, last_seq):
                        return sh.seq
                    sh.skipped_wakes += 1
                    last_seq = sh.seq  # foreign-key wake: absorb and re-arm
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return sh.seq
                sh.cond.wait(remaining)

    @staticmethod
    def _touched(sh: _Shard, key: str, last_seq: int) -> bool:
        """True if any touch after ``last_seq`` may have written ``key``
        (named it, had unknown keys, or scrolled off the ring)."""
        recent = sh.recent
        if not recent or recent[0][0] > last_seq + 1:
            return True  # ring can't prove the wakes were foreign
        for seq, keys in recent:
            if seq <= last_seq:
                continue
            if keys is None or key in keys:
                return True
        return False

    def foreign_wake_skips(self) -> int:
        """How many shard wakes :meth:`wait_key` absorbed because the touch
        named only other keys — the keyed-wake win the dataplane tests pin."""
        return sum(sh.skipped_wakes for sh in self._shards)

    def notify_key(self, key: str) -> None:
        """Virtual touch: wake every watcher of ``key`` without writing
        (used by e.g. scheduler shutdown to unblock queue waiters)."""
        sh = self._shard(key)
        with sh.lock:
            sh.touch((key,))

    # ---- atomic single-key ops ------------------------------------------
    def set(self, key: str, value: Any, *, worker: str = "-") -> None:
        sh = self._shard(key)
        with sh.lock:
            sh.data[key] = value
            self._charge(sh, worker, "set", key, _sizeof(value), write=True)
            sh.touch((key,))

    def get(self, key: str, default: Any = None, *, worker: str = "-") -> Any:
        sh = self._shard(key)
        with sh.lock:
            value = sh.data.get(key, default)
            self._charge(sh, worker, "get", key, _sizeof(value), write=False)
            return value

    def mget(
        self, keys: List[str], default: Any = None, *, worker: str = "-"
    ) -> List[Any]:
        """Batched get (Redis MGET): values in ``keys`` order, ``default``
        for missing entries.  Keys are grouped by shard and each shard's
        group is served in one locked pass, charged as one amortized
        round-trip per shard touched (request latency + summed transfer) —
        not one per key."""
        by_shard: Dict[int, List[int]] = {}
        for i, key in enumerate(keys):
            by_shard.setdefault(self.shard_of(key), []).append(i)
        out: List[Any] = [default] * len(keys)
        for sidx, positions in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = 0
                for i in positions:
                    value = sh.data.get(keys[i], default)
                    out[i] = value
                    nbytes += _sizeof(value)
                # one amortized round-trip for the whole shard group
                self._charge(
                    sh, worker, "mget", f"[{len(positions)} keys@s{sidx}]",
                    nbytes, write=False,
                )
        return out

    def mset(self, mapping: Dict[str, Any], *, worker: str = "-") -> None:
        """Batched set (Redis MSET): the write-side mirror of :meth:`mget`.
        Keys are grouped by shard; each shard's group lands in one locked
        pass charged as one amortized round-trip (request latency + summed
        transfer), and the shard sequence is bumped exactly once — watchers
        wake once per touched shard, not once per key."""
        by_shard: Dict[int, List[str]] = {}
        for key in mapping:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = 0
                for key in group:
                    value = mapping[key]
                    sh.data[key] = value
                    nbytes += _sizeof(value)
                self._charge(
                    sh, worker, "mset", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )
                sh.touch(group)  # one wakeup per touched shard for the whole batch

    def setnx(self, key: str, value: Any, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "setnx", key, _sizeof(value), write=True)
            if key in sh.data:
                return False
            sh.data[key] = value
            sh.touch((key,))
            return True

    def incr(self, key: str, amount: float = 1, *, worker: str = "-") -> float:
        sh = self._shard(key)
        with sh.lock:
            new = sh.data.get(key, 0) + amount
            sh.data[key] = new
            self._charge(sh, worker, "incr", key, 8, write=True)
            sh.touch((key,))
            return new

    def cas(self, key: str, expect: Any, value: Any, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "cas", key, _sizeof(value), write=True)
            cur = sh.data.get(key, _TOMBSTONE)
            matched = (cur is not _TOMBSTONE and cur == expect) or (
                cur is _TOMBSTONE and expect is None
            )
            if matched:
                sh.data[key] = value
                sh.touch((key,))
                return True
            return False

    def delete(self, key: str, *, worker: str = "-") -> None:
        sh = self._shard(key)
        with sh.lock:
            sh.data.pop(key, None)
            self._charge(sh, worker, "del", key, 0, write=True)
            sh.touch((key,))

    def mdel(self, keys: List[str], *, worker: str = "-") -> int:
        """Batched delete: one amortized round-trip per shard touched (cf.
        :meth:`mget`).  Returns how many of the keys actually existed —
        job GC uses the count to settle advisory lease accounting."""
        by_shard: Dict[int, List[str]] = {}
        for key in keys:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        removed = 0
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                for key in group:
                    if sh.data.pop(key, _TOMBSTONE) is not _TOMBSTONE:
                        removed += 1
                self._charge(
                    sh, worker, "mdel", f"[{len(group)} keys@s{sidx}]", 0, write=True
                )
                sh.touch(group)
        return removed

    def exists(self, key: str, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "exists", key, 0, write=False)
            return key in sh.data

    def scan(self, prefix: str, *, worker: str = "-") -> List[str]:
        """All keys starting with ``prefix`` (Redis SCAN MATCH): one charged
        round-trip per shard — every shard must be visited, since hashing
        scatters a prefix across all of them.  Used by stateless scheduler
        handles to rebuild their lease-index caches from the KV (the KV is
        the source of truth; local heaps are hints)."""
        out: List[str] = []
        for sh in self._shards:
            with sh.lock:
                found = [k for k in sh.data if k.startswith(prefix)]
                self._charge(
                    sh, worker, "scan", f"[{prefix}*@s{sh.idx}]",
                    sum(len(k.encode()) for k in found), write=False,
                )
                out.extend(found)
        return sorted(out)

    # ---- server-side scripting (Redis EVAL analogue) ---------------------
    def eval(
        self,
        key: str,
        fn: Callable[[Any], Any],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Any:
        """Atomically ``data[key] = fn(data.get(key, default))`` under the
        shard lock; returns the new value.  This is the paper's 'existing
        support for server-side scripting … to implement features like range
        updates' — the parameter server's in-place gradient apply, and (with
        the :data:`DELETE` sentinel return) the scheduler's fenced
        compare-epoch-then-delete lease release."""
        sh = self._shard(key)
        with sh.lock:
            cur = sh.data.get(key, default)
            new = fn(cur)
            if new is DELETE:
                sh.data.pop(key, None)
                self._charge(sh, worker, "eval", key, 0, write=True)
                sh.touch((key,))
                return None
            sh.data[key] = new
            self._charge(sh, worker, "eval", key, _sizeof(new), write=True)
            sh.touch((key,))
            return new

    def eval_many(
        self,
        updates: Dict[str, Callable[[Any], Any]],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Dict[str, Any]:
        """Pipelined EVAL: apply ``updates[key]`` to each key atomically
        under its shard lock, grouped by shard — one amortized round-trip
        and **one** watcher wakeup per touched shard for the whole batch.
        Each update still runs atomically per key (HOGWILD! range-update
        semantics are unchanged); what's batched is the wire, not the
        locking.  Returns the new value per key."""
        by_shard: Dict[int, List[str]] = {}
        for key in updates:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        out: Dict[str, Any] = {}
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = 0
                for key in group:
                    new = updates[key](sh.data.get(key, default))
                    if new is DELETE:
                        sh.data.pop(key, None)
                        out[key] = None
                        continue
                    sh.data[key] = new
                    out[key] = new
                    nbytes += _sizeof(new)
                self._charge(
                    sh, worker, "meval", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )
                sh.touch(group)
        return out

    # ---- lists (queues) ---------------------------------------------------
    def rpush(self, key: str, *values: Any, worker: str = "-") -> int:
        sh = self._shard(key)
        with sh.lock:
            lst = sh.data.setdefault(key, [])
            lst.extend(values)
            self._charge(sh, worker, "rpush", key, sum(_sizeof(v) for v in values), write=True)
            sh.touch((key,))
            return len(lst)

    def rpush_nowait(self, key: str, *values: Any, worker: str = "-") -> None:
        """Advisory RPUSH: no return value and — on wire-backed stores — no
        round trip (the append rides a fire-and-forget frame and may be
        dropped by a reconnect window).  For telemetry-grade appends like
        duration samples, where losing one entry is benign but paying a
        blocking round trip per task is not.  In-process stores append
        synchronously; only the *guarantee* is weakened, never the
        ordering a single client observes."""
        self.rpush(key, *values, worker=worker)

    def rpush_many(
        self, pushes: Dict[str, List[Any]], *, worker: str = "-"
    ) -> Dict[str, int]:
        """Pipelined RPUSH across keys: group by shard, extend every list in
        one locked pass per shard, charge one amortized round-trip per shard
        and bump each touched shard's sequence exactly once — N queue
        appends wake each shard's blocked ``blpop``/``wait_key`` consumers
        once.  Returns the new length per key."""
        by_shard: Dict[int, List[str]] = {}
        for key in pushes:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        lengths: Dict[str, int] = {}
        for sidx, group in by_shard.items():
            sh = self._shards[sidx]
            with sh.lock:
                nbytes = 0
                for key in group:
                    values = pushes[key]
                    lst = sh.data.setdefault(key, [])
                    lst.extend(values)
                    lengths[key] = len(lst)
                    nbytes += sum(_sizeof(v) for v in values)
                self._charge(
                    sh, worker, "mrpush", f"[{len(group)} keys@s{sidx}]",
                    nbytes, write=True,
                )
                sh.touch(group)
        return lengths

    def lpop(self, key: str, *, worker: str = "-") -> Any:
        sh = self._shard(key)
        with sh.lock:
            lst = sh.data.get(key)
            value = lst.pop(0) if lst else None
            self._charge(sh, worker, "lpop", key, _sizeof(value), write=True)
            return value

    def lpop_n(self, key: str, max_n: int, *, worker: str = "-") -> List[Any]:
        """Pop up to ``max_n`` items off the left of ``key``'s list in ONE
        locked pass / one charged round-trip (Redis ``LPOP key count``).
        The queue-consumer mirror of ``rpush_many``: a worker leasing a
        batch pays one request, not one per task."""
        sh = self._shard(key)
        with sh.lock:
            lst = sh.data.get(key)
            out = list(lst[:max_n]) if lst else []
            if out:
                del lst[: len(out)]
            self._charge(
                sh, worker, "lpopn", key,
                sum(_sizeof(v) for v in out), write=True,
            )
            return out

    def blpop(self, key: str, timeout_s: float, *, worker: str = "-") -> Any:
        """Blocking left pop (Redis BLPOP): pop the head of ``key``'s list,
        waiting on the shard's watch condition until an element arrives or
        the timeout elapses (then ``None``).  No polling: a producer's
        ``rpush`` on the same shard wakes this directly."""
        deadline = time.monotonic() + timeout_s
        sh = self._shard(key)
        with sh.lock:
            while True:
                lst = sh.data.get(key)
                if lst:
                    value = lst.pop(0)
                    self._charge(sh, worker, "blpop", key, _sizeof(value), write=True)
                    return value
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                sh.cond.wait(remaining)

    def lrange(self, key: str, start: int = 0, stop: int = -1, *, worker: str = "-") -> List[Any]:
        sh = self._shard(key)
        with sh.lock:
            lst = list(sh.data.get(key, []))
            out = lst[start:] if stop == -1 else lst[start : stop + 1]
            self._charge(sh, worker, "lrange", key, sum(_sizeof(v) for v in out), write=False)
            return out

    def llen(self, key: str, *, worker: str = "-") -> int:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "llen", key, 8, write=False)
            return len(sh.data.get(key, []))

    # ---- stats ------------------------------------------------------------
    def shard_stats(self) -> List[ShardStats]:
        return [sh.stats for sh in self._shards]

    def total_ops(self) -> int:
        return sum(sh.stats.ops for sh in self._shards)

    def hottest_shard_vtime(self) -> float:
        """Virtual busy-time of the most loaded shard — the sort benchmark's
        bottleneck signal (paper Fig 6: 'Redis I/O time increases by 42%')."""
        return max((sh.stats.vtime_s for sh in self._shards), default=0.0)
