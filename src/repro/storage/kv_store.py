"""Redis-semantics low-latency KV store: the coordination plane.

The paper uses ElastiCache/Redis for (a) small synchronous put/gets (Fig 4),
(b) shuffle intermediates when S3 request throughput is the bottleneck
(Fig 5/6), and (c) parameter servers with server-side scripting for range
updates / flexible consistency (§3.3).

Reproduced semantics:
  * sharded keyspace (consistent hashing over N shards, each shard has its
    own request-throughput budget — the Fig 5/6 bottleneck);
  * atomic single-key ops: get/set/setnx/incr/cas/delete;
  * ``eval`` — server-side scripting analogue: apply a Python callable to a
    key's value *atomically under the shard lock* (Redis EVAL), used by the
    parameter server for in-place range updates (HOGWILD!);
  * lists (rpush/lrange) for queues.

Each op is charged virtual wire time and recorded per shard so benchmarks
can detect shard saturation exactly like the paper's sort experiment.
"""

from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from .object_store import Ledger, OpRecord, _Endpoint
from .perf_model import REDIS_2017, StorageProfile

_TOMBSTONE = object()


@dataclass
class ShardStats:
    ops: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    vtime_s: float = 0.0


class _Shard:
    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.lock = threading.RLock()
        self.data: Dict[str, Any] = {}
        self.stats = ShardStats()


def _sizeof(value: Any) -> int:
    if isinstance(value, bytes):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (int, float)):
        return 8
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, (list, tuple)):
        return sum(_sizeof(v) for v in value) + 8
    if isinstance(value, dict):
        return sum(_sizeof(k) + _sizeof(v) for k, v in value.items()) + 8
    return 64  # opaque


class KVStore(_Endpoint):
    """Sharded in-memory KV store with Redis-like atomic ops."""

    def __init__(
        self,
        num_shards: int = 1,
        profile: StorageProfile = REDIS_2017,
        ledger: Optional[Ledger] = None,
    ) -> None:
        if num_shards < 1:
            raise ValueError("num_shards >= 1")
        self.num_shards = num_shards
        self.profile = profile
        self.ledger = ledger or Ledger()
        self._shards = [_Shard(i) for i in range(num_shards)]
        self._register_endpoint()

    # ---- sharding ------------------------------------------------------
    def shard_of(self, key: str) -> int:
        return zlib.crc32(key.encode()) % self.num_shards

    def _shard(self, key: str) -> _Shard:
        return self._shards[self.shard_of(key)]

    def _charge(
        self, shard: _Shard, worker: str, op: str, key: str, nbytes: int, write: bool
    ) -> None:
        vt = self.profile.write_time(nbytes) if write else self.profile.read_time(nbytes)
        shard.stats.ops += 1
        shard.stats.vtime_s += vt
        if write:
            shard.stats.bytes_in += nbytes
        else:
            shard.stats.bytes_out += nbytes
        self.ledger.record(OpRecord(worker, op, key, nbytes, vt, time.monotonic()))

    # ---- atomic single-key ops ------------------------------------------
    def set(self, key: str, value: Any, *, worker: str = "-") -> None:
        sh = self._shard(key)
        with sh.lock:
            sh.data[key] = value
            self._charge(sh, worker, "set", key, _sizeof(value), write=True)

    def get(self, key: str, default: Any = None, *, worker: str = "-") -> Any:
        sh = self._shard(key)
        with sh.lock:
            value = sh.data.get(key, default)
            self._charge(sh, worker, "get", key, _sizeof(value), write=False)
            return value

    def setnx(self, key: str, value: Any, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "setnx", key, _sizeof(value), write=True)
            if key in sh.data:
                return False
            sh.data[key] = value
            return True

    def incr(self, key: str, amount: float = 1, *, worker: str = "-") -> float:
        sh = self._shard(key)
        with sh.lock:
            new = sh.data.get(key, 0) + amount
            sh.data[key] = new
            self._charge(sh, worker, "incr", key, 8, write=True)
            return new

    def cas(self, key: str, expect: Any, value: Any, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "cas", key, _sizeof(value), write=True)
            cur = sh.data.get(key, _TOMBSTONE)
            matched = (cur is not _TOMBSTONE and cur == expect) or (
                cur is _TOMBSTONE and expect is None
            )
            if matched:
                sh.data[key] = value
                return True
            return False

    def delete(self, key: str, *, worker: str = "-") -> None:
        sh = self._shard(key)
        with sh.lock:
            sh.data.pop(key, None)
            self._charge(sh, worker, "del", key, 0, write=True)

    def exists(self, key: str, *, worker: str = "-") -> bool:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "exists", key, 0, write=False)
            return key in sh.data

    # ---- server-side scripting (Redis EVAL analogue) ---------------------
    def eval(
        self,
        key: str,
        fn: Callable[[Any], Any],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Any:
        """Atomically ``data[key] = fn(data.get(key, default))`` under the
        shard lock; returns the new value.  This is the paper's 'existing
        support for server-side scripting … to implement features like range
        updates' — the parameter server's in-place gradient apply."""
        sh = self._shard(key)
        with sh.lock:
            cur = sh.data.get(key, default)
            new = fn(cur)
            sh.data[key] = new
            self._charge(sh, worker, "eval", key, _sizeof(new), write=True)
            return new

    # ---- lists (queues) ---------------------------------------------------
    def rpush(self, key: str, *values: Any, worker: str = "-") -> int:
        sh = self._shard(key)
        with sh.lock:
            lst = sh.data.setdefault(key, [])
            lst.extend(values)
            self._charge(sh, worker, "rpush", key, sum(_sizeof(v) for v in values), write=True)
            return len(lst)

    def lpop(self, key: str, *, worker: str = "-") -> Any:
        sh = self._shard(key)
        with sh.lock:
            lst = sh.data.get(key)
            value = lst.pop(0) if lst else None
            self._charge(sh, worker, "lpop", key, _sizeof(value), write=True)
            return value

    def lrange(self, key: str, start: int = 0, stop: int = -1, *, worker: str = "-") -> List[Any]:
        sh = self._shard(key)
        with sh.lock:
            lst = list(sh.data.get(key, []))
            out = lst[start:] if stop == -1 else lst[start : stop + 1]
            self._charge(sh, worker, "lrange", key, sum(_sizeof(v) for v in out), write=False)
            return out

    def llen(self, key: str, *, worker: str = "-") -> int:
        sh = self._shard(key)
        with sh.lock:
            self._charge(sh, worker, "llen", key, 8, write=False)
            return len(sh.data.get(key, []))

    # ---- stats ------------------------------------------------------------
    def shard_stats(self) -> List[ShardStats]:
        return [sh.stats for sh in self._shards]

    def total_ops(self) -> int:
        return sum(sh.stats.ops for sh in self._shards)

    def hottest_shard_vtime(self) -> float:
        """Virtual busy-time of the most loaded shard — the sort benchmark's
        bottleneck signal (paper Fig 6: 'Redis I/O time increases by 42%')."""
        return max((sh.stats.vtime_s for sh in self._shards), default=0.0)
