"""Pytree <-> bytes codecs used by the storage layer and the function runtime.

PyWren serializes functions and data with cloudpickle and places them at
globally-unique S3 keys.  We reproduce that contract: every value the runtime
persists goes through :func:`dumps` / :func:`loads`, is integrity-hashed, and
is addressable by a deterministic key derived from its content
(:func:`content_key`).

JAX arrays are handled natively (zero-copy to numpy on CPU); arbitrary Python
objects fall back to pickle — the cloudpickle analogue.  A small header tags
the codec so readers never guess.
"""

from __future__ import annotations

import hashlib
import io
import pickle
import struct
from typing import Any, Tuple

import jax
import numpy as np

_MAGIC = b"RWRN"
_CODEC_PICKLE = 1
_CODEC_NPZ = 2  # pytree of arrays: treedef pickled + arrays in .npz
_HEADER = struct.Struct("<4sBQ")  # magic, codec, payload length


def _is_array_pytree(value: Any) -> bool:
    leaves = jax.tree_util.tree_leaves(value)
    if not leaves:
        return False
    return all(isinstance(l, (np.ndarray, np.generic, jax.Array)) for l in leaves)


def dumps(value: Any) -> bytes:
    """Serialize an arbitrary value.  Array pytrees use the npz fast path."""
    if _is_array_pytree(value):
        leaves, treedef = jax.tree_util.tree_flatten(value)
        buf = io.BytesIO()
        np.savez(
            buf,
            **{f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)},
        )
        payload = pickle.dumps(treedef) + b"\x00TREE\x00" + buf.getvalue()
        codec = _CODEC_NPZ
    else:
        payload = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        codec = _CODEC_PICKLE
    return _HEADER.pack(_MAGIC, codec, len(payload)) + payload


def loads(blob: bytes) -> Any:
    magic, codec, length = _HEADER.unpack_from(blob, 0)
    if magic != _MAGIC:
        raise ValueError("bad magic: not a repro-serialized blob")
    payload = blob[_HEADER.size : _HEADER.size + length]
    if codec == _CODEC_PICKLE:
        return pickle.loads(payload)
    if codec == _CODEC_NPZ:
        sep = payload.index(b"\x00TREE\x00")
        treedef = pickle.loads(payload[:sep])
        with np.load(io.BytesIO(payload[sep + 6 :])) as npz:
            leaves = [npz[f"a{i}"] for i in range(len(npz.files))]
        return jax.tree_util.tree_unflatten(treedef, leaves)
    raise ValueError(f"unknown codec {codec}")


def digest(blob: bytes) -> str:
    return hashlib.sha256(blob).hexdigest()


def content_key(prefix: str, blob: bytes) -> str:
    """Deterministic, globally-unique key for a serialized value (PyWren's
    'globally unique keys in S3')."""
    return f"{prefix}/{digest(blob)[:32]}"


def dumps_with_key(prefix: str, value: Any) -> Tuple[str, bytes]:
    blob = dumps(value)
    return content_key(prefix, blob), blob
