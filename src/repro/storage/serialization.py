"""Pytree <-> bytes codecs used by the storage layer and the function runtime.

PyWren serializes functions and data with cloudpickle and places them at
globally-unique S3 keys.  We reproduce that contract: every value the runtime
persists goes through :func:`dumps` / :func:`loads`, is integrity-hashed, and
is addressable by a deterministic key derived from its content
(:func:`content_key`).

JAX arrays are handled natively; arbitrary Python objects fall back to
pickle — the cloudpickle analogue.  A small header tags the codec so readers
never guess.

Array pytrees use the **raw codec** (PR 9): a length-prefixed pickled
descriptor (treedef + per-leaf dtype/shape) followed by each leaf's raw
contiguous bytes.  :func:`dumps_parts` exposes that layout as a list of
segments whose leaf entries are zero-copy ``memoryview``\\ s over the array
memory — the wire tier (:mod:`.net_kv`) hands them to ``socket.sendmsg``
without ever pickling the payload — and :func:`loads` reconstructs every
leaf with ``np.frombuffer`` over the blob, so a KV-cache block or a
checkpoint shard is never copied through the codec on either end.

The legacy NPZ codec remains readable.  Its treedef separator is now
length-prefixed; the original format split on a sentinel byte string
(``b"\\x00TREE\\x00"``), which corrupted the payload whenever the pickled
treedef happened to contain those bytes (e.g. a dict key naming them).
"""

from __future__ import annotations

import hashlib
import io
import pickle
import struct
from typing import Any, List, Tuple

import jax
import numpy as np

_MAGIC = b"RWRN"
_CODEC_PICKLE = 1
_CODEC_NPZ = 2  # legacy: pytree of arrays, treedef pickled + arrays in .npz
_CODEC_RAW = 3  # pytree of arrays: pickled descriptor + raw leaf bytes
_HEADER = struct.Struct("<4sBQ")  # magic, codec, payload length
_LEN = struct.Struct("<Q")  # length prefix for embedded pickled sections


def _is_array_pytree(value: Any) -> bool:
    leaves = jax.tree_util.tree_leaves(value)
    if not leaves:
        return False
    return all(isinstance(l, (np.ndarray, np.generic, jax.Array)) for l in leaves)


def dumps_parts(value: Any) -> List[Any]:
    """Serialize ``value`` as a list of byte segments whose concatenation is
    exactly ``dumps(value)``.  For an array pytree the first segment is the
    header + descriptor and every following segment is one leaf's raw bytes
    as a zero-copy ``memoryview`` — a transport that can scatter-gather
    (``socket.sendmsg``, ``writev``) never copies the array payload at all.
    Non-array values collapse to a single pickled segment."""
    if _is_array_pytree(value):
        leaves, treedef = jax.tree_util.tree_flatten(value)
        arrays = [np.ascontiguousarray(np.asarray(leaf)) for leaf in leaves]
        views = [memoryview(a).cast("B") for a in arrays]
        meta = pickle.dumps(
            (treedef, [(a.dtype.str, a.shape) for a in arrays]),
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        payload_len = _LEN.size + len(meta) + sum(v.nbytes for v in views)
        head = _HEADER.pack(_MAGIC, _CODEC_RAW, payload_len) + _LEN.pack(len(meta)) + meta
        return [head] + views
    payload = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
    return [_HEADER.pack(_MAGIC, _CODEC_PICKLE, len(payload)) + payload]


def dumps(value: Any) -> bytes:
    """Serialize an arbitrary value.  Array pytrees use the raw fast path."""
    return b"".join(dumps_parts(value))


def _dumps_npz(value: Any) -> bytes:
    """Legacy NPZ encoding (compressed-container layout), kept so the codec
    branch stays exercised.  The treedef is length-prefixed — the old
    sentinel-scan split corrupted any treedef whose pickle contained the
    sentinel bytes."""
    leaves, treedef = jax.tree_util.tree_flatten(value)
    buf = io.BytesIO()
    np.savez(buf, **{f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)})
    tree_blob = pickle.dumps(treedef)
    payload = _LEN.pack(len(tree_blob)) + tree_blob + buf.getvalue()
    return _HEADER.pack(_MAGIC, _CODEC_NPZ, len(payload)) + payload


def loads(blob: Any) -> Any:
    """Inverse of :func:`dumps`.  Accepts any bytes-like object (``bytes``,
    ``bytearray``, ``memoryview``) — raw-codec leaves are reconstructed with
    ``np.frombuffer`` over the blob itself, so large arrays are zero-copy
    views of the storage/wire buffer."""
    view = memoryview(blob)
    magic, codec, length = _HEADER.unpack_from(view, 0)
    if magic != _MAGIC:
        raise ValueError("bad magic: not a repro-serialized blob")
    payload = view[_HEADER.size : _HEADER.size + length]
    if codec == _CODEC_PICKLE:
        return pickle.loads(payload)
    if codec == _CODEC_RAW:
        (meta_len,) = _LEN.unpack_from(payload, 0)
        treedef, descs = pickle.loads(payload[_LEN.size : _LEN.size + meta_len])
        off = _LEN.size + meta_len
        leaves = []
        for dtype_str, shape in descs:
            dtype = np.dtype(dtype_str)
            nbytes = dtype.itemsize * int(np.prod(shape, dtype=np.int64))
            arr = np.frombuffer(payload[off : off + nbytes], dtype=dtype)
            leaves.append(arr.reshape(shape))
            off += nbytes
        return jax.tree_util.tree_unflatten(treedef, leaves)
    if codec == _CODEC_NPZ:
        (tree_len,) = _LEN.unpack_from(payload, 0)
        treedef = pickle.loads(payload[_LEN.size : _LEN.size + tree_len])
        with np.load(io.BytesIO(bytes(payload[_LEN.size + tree_len :]))) as npz:
            leaves = [npz[f"a{i}"] for i in range(len(npz.files))]
        return jax.tree_util.tree_unflatten(treedef, leaves)
    raise ValueError(f"unknown codec {codec}")


def digest(blob: Any) -> str:
    return hashlib.sha256(blob).hexdigest()


def content_key(prefix: str, blob: Any) -> str:
    """Deterministic, globally-unique key for a serialized value (PyWren's
    'globally unique keys in S3')."""
    return f"{prefix}/{digest(blob)[:32]}"


def dumps_with_key(prefix: str, value: Any) -> Tuple[str, bytes]:
    blob = dumps(value)
    return content_key(prefix, blob), blob
