"""Disaggregated storage plane: object store (S3 semantics), KV store
(Redis semantics), shuffle, serialization, and paper-calibrated perf models."""

from .kv_store import KVStore
from .object_store import FileBackend, InMemoryBackend, Ledger, ObjectStore, OpRecord
from .perf_model import (
    DISAGG_2026,
    LOCAL_SSD_C3,
    LOCAL_SSD_I2,
    LOCAL_SSD_I2_RAID,
    PROFILES,
    REDIS_2017,
    S3_2017,
    StorageProfile,
)
from .serialization import content_key, digest, dumps, dumps_with_key, loads

__all__ = [
    "KVStore",
    "ObjectStore",
    "InMemoryBackend",
    "FileBackend",
    "Ledger",
    "OpRecord",
    "StorageProfile",
    "PROFILES",
    "S3_2017",
    "REDIS_2017",
    "DISAGG_2026",
    "LOCAL_SSD_C3",
    "LOCAL_SSD_I2",
    "LOCAL_SSD_I2_RAID",
    "dumps",
    "loads",
    "digest",
    "content_key",
    "dumps_with_key",
]
