"""Disaggregated storage plane: object store (S3 semantics), KV store
(Redis semantics), shuffle, serialization, and paper-calibrated perf models.

Batched data-plane contract (both directions, the Fig 5/6 request-count
fix — see each module's docstring for details):

  * reads  — ``ObjectStore.get_many`` / ``KVStore.mget``: N keys cost one
    amortized round-trip (request latency + summed transfer; the KV charges
    one per *shard touched*), never one per key;
  * writes — ``ObjectStore.put_many`` / ``KVStore.mset`` / ``rpush_many`` /
    ``eval_many``: the symmetric mirror, with notification coalesced — a
    batch fires one ``notify_put`` (object store) or exactly one sequence
    bump per touched shard (KV), so waiters wake once per batch;
  * deletes — ``delete_many`` / ``mdel`` ride the same accounting for
    lifecycle teardown (shuffle-intermediate GC, per-job GC).

Every operation is recorded in a :class:`~repro.storage.object_store.Ledger`
(one record == one modeled request), which is what benchmarks count."""

from .file_kv import FileKVStore
from .kv_store import DELETE, KVStore, kv_pure
from .net_kv import NetBackend, NetKVStore
from .object_store import FileBackend, InMemoryBackend, Ledger, ObjectStore, OpRecord
from .perf_model import (
    DISAGG_2026,
    LOCAL_SSD_C3,
    LOCAL_SSD_I2,
    LOCAL_SSD_I2_RAID,
    PROFILES,
    REDIS_2017,
    S3_2017,
    StorageProfile,
)
from .serialization import content_key, digest, dumps, dumps_with_key, loads

__all__ = [
    "KVStore",
    "FileKVStore",
    "NetKVStore",
    "NetBackend",
    "DELETE",
    "kv_pure",
    "ObjectStore",
    "InMemoryBackend",
    "FileBackend",
    "Ledger",
    "OpRecord",
    "StorageProfile",
    "PROFILES",
    "S3_2017",
    "REDIS_2017",
    "DISAGG_2026",
    "LOCAL_SSD_C3",
    "LOCAL_SSD_I2",
    "LOCAL_SSD_I2_RAID",
    "dumps",
    "loads",
    "digest",
    "content_key",
    "dumps_with_key",
]
