"""Storage-backed shuffle: the paper's BSP/MapReduce data plane.

Terasort-style two-stage shuffle (§3.3):
  stage 1 (partition): each map task range/hash-partitions its input and
    writes one object per (map_task, reduce_partition) — the paper's
    2500² intermediate-file blowup, which is why request throughput (not
    bandwidth) becomes the bottleneck;
  stage 2 (merge): each reduce task reads its column of intermediates,
    merges, and writes final output.

Two intermediate backends, as in the paper: the ObjectStore (S3; abundant
bandwidth, low request throughput) and the KVStore (Redis; provisioned
shards).  Range partitioning uses sampled splitters (TeraSort's sampler).
"""

from __future__ import annotations

import bisect
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .kv_store import KVStore
from .object_store import ObjectStore

Store = Union[ObjectStore, KVStore]


def sample_splitters(
    sample: Sequence[Any], num_partitions: int, key: Optional[Callable[[Any], Any]] = None
) -> List[Any]:
    """TeraSort sampler: pick num_partitions-1 splitters from a sample so the
    output partitions are balanced."""
    if num_partitions < 1:
        raise ValueError("num_partitions >= 1")
    keys = sorted(key(x) if key else x for x in sample)
    if not keys or num_partitions == 1:
        return []
    idx = [int(len(keys) * (i + 1) / num_partitions) for i in range(num_partitions - 1)]
    return [keys[min(i, len(keys) - 1)] for i in idx]


def range_partition(
    records: Sequence[Any],
    splitters: List[Any],
    key: Optional[Callable[[Any], Any]] = None,
) -> List[List[Any]]:
    parts: List[List[Any]] = [[] for _ in range(len(splitters) + 1)]
    for rec in records:
        k = key(rec) if key else rec
        parts[bisect.bisect_right(splitters, k)].append(rec)
    return parts


def hash_partition(
    records: Sequence[Tuple[Any, Any]], num_partitions: int
) -> List[List[Tuple[Any, Any]]]:
    parts: List[List[Tuple[Any, Any]]] = [[] for _ in range(num_partitions)]
    for k, v in records:
        parts[hash(k) % num_partitions].append((k, v))
    return parts


# ---------------------------------------------------------------------------
# intermediate-file plane
# ---------------------------------------------------------------------------

def intermediate_key(job: str, map_id: int, part_id: int) -> str:
    return f"shuffle/{job}/m{map_id:06d}/p{part_id:06d}"


def write_partitions(
    store: Store,
    job: str,
    map_id: int,
    parts: Sequence[Sequence[Any]],
    *,
    worker: str = "-",
) -> int:
    """Write one intermediate object per partition; returns #objects.
    This is where the paper's quadratic request count comes from."""
    n = 0
    for part_id, part in enumerate(parts):
        key = intermediate_key(job, map_id, part_id)
        if isinstance(store, KVStore):
            store.set(key, list(part), worker=worker)
        else:
            store.put(key, list(part), worker=worker)
        n += 1
    return n


def read_partition_column(
    store: Store,
    job: str,
    num_map_tasks: int,
    part_id: int,
    *,
    worker: str = "-",
) -> List[Any]:
    """Reduce-side: read intermediates from every map task for one partition.

    Batched — one ``mget`` (KV: one round-trip per shard touched) or one
    ``get_many`` (object store: one amortized round-trip) for the whole
    column, instead of ``num_map_tasks`` synchronous gets.  This is the
    fan-in the paper's Fig 5/6 sort saturates on; batching attacks the
    request count, not just the byte count."""
    keys = [intermediate_key(job, map_id, part_id) for map_id in range(num_map_tasks)]
    if isinstance(store, KVStore):
        chunks = store.mget(keys, default=[], worker=worker)
    else:
        got = store.get_many(keys, worker=worker)
        chunks = [got.get(k, []) for k in keys]
    out: List[Any] = []
    for chunk in chunks:
        out.extend(chunk)
    return out


def merge_sorted(chunks: List[List[Any]], key: Optional[Callable[[Any], Any]] = None) -> List[Any]:
    import heapq

    return list(heapq.merge(*[sorted(c, key=key) for c in chunks], key=key))


def make_sort_records(n: int, seed: int, payload_bytes: int = 90) -> np.ndarray:
    """Daytona-sort-style records: 10-byte key + payload, as uint8 rows."""
    rng = np.random.default_rng(seed)
    recs = rng.integers(0, 256, size=(n, 10 + payload_bytes), dtype=np.uint8)
    return recs


def record_sort_key(rec: np.ndarray) -> bytes:
    return rec[:10].tobytes()
