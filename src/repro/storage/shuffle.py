"""Storage-backed shuffle: the paper's BSP/MapReduce data plane.

Terasort-style two-stage shuffle (§3.3):
  stage 1 (partition): each map task range/hash-partitions its input and
    writes one object per (map_task, reduce_partition) — the paper's
    2500² intermediate-file blowup, which is why request throughput (not
    bandwidth) becomes the bottleneck;
  stage 2 (merge): each reduce task reads its column of intermediates,
    merges, and writes final output.

Two intermediate backends, as in the paper: the ObjectStore (S3; abundant
bandwidth, low request throughput) and the KVStore (Redis; provisioned
shards).  Range partitioning uses sampled splitters (TeraSort's sampler).

Request-count accounting (the Fig 5/6 bottleneck), both directions batched:
  * ``write_partitions`` lands a map task's entire fan-out in one batched
    write — ``ObjectStore.put_many`` (one amortized round-trip) or
    ``KVStore.mset`` (one per shard touched) — instead of one modeled
    request per (map, partition) object;
  * ``read_partition_column`` reads a reduce task's entire fan-in in one
    ``get_many``/``mget`` the same way;
  * ``delete_intermediates`` retires the whole ``shuffle/{job}`` column
    space after merge in one batched delete (``delete_many``/``mdel``), so
    intermediates don't outlive the job (ROADMAP shuffle-GC item).
"""

from __future__ import annotations

import bisect
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .kv_store import KVStore
from .object_store import ObjectStore

Store = Union[ObjectStore, KVStore]


def sample_splitters(
    sample: Sequence[Any], num_partitions: int, key: Optional[Callable[[Any], Any]] = None
) -> List[Any]:
    """TeraSort sampler: pick num_partitions-1 splitters from a sample so the
    output partitions are balanced."""
    if num_partitions < 1:
        raise ValueError("num_partitions >= 1")
    keys = sorted(key(x) if key else x for x in sample)
    if not keys or num_partitions == 1:
        return []
    idx = [int(len(keys) * (i + 1) / num_partitions) for i in range(num_partitions - 1)]
    return [keys[min(i, len(keys) - 1)] for i in idx]


def range_partition(
    records: Sequence[Any],
    splitters: List[Any],
    key: Optional[Callable[[Any], Any]] = None,
) -> List[List[Any]]:
    parts: List[List[Any]] = [[] for _ in range(len(splitters) + 1)]
    for rec in records:
        k = key(rec) if key else rec
        parts[bisect.bisect_right(splitters, k)].append(rec)
    return parts


def hash_partition(
    records: Sequence[Tuple[Any, Any]], num_partitions: int
) -> List[List[Tuple[Any, Any]]]:
    parts: List[List[Tuple[Any, Any]]] = [[] for _ in range(num_partitions)]
    for k, v in records:
        parts[hash(k) % num_partitions].append((k, v))
    return parts


# ---------------------------------------------------------------------------
# intermediate-file plane
# ---------------------------------------------------------------------------

def intermediate_key(job: str, map_id: int, part_id: int) -> str:
    return f"shuffle/{job}/m{map_id:06d}/p{part_id:06d}"


def gc_tombstone_key(job: str) -> str:
    """Marker that ``job``'s shuffle intermediates were GC'd.  Lives outside
    the ``shuffle/{job}/`` column space so deleting the columns can't race
    with reading the marker.  A straggler map attempt finishing after the
    merge barrier (its speculative duplicate satisfied the stage) would
    otherwise re-create just-deleted intermediates that nothing ever
    deletes again; ``write_partitions`` re-checks this marker after its
    batch lands and un-writes it.  One O(1) key per shuffle job outlives
    the GC — vs. the O(maps × partitions) leak it prevents.

    Consequence: **job ids are single-use per store** — a GC'd job name
    stays dead, and writes under it are dropped (mirroring the
    scheduler's ``finish_job`` tombstones, which drop queued duplicates
    of finished jobs the same way).  ``mapreduce``/``terasort`` mint
    uuid-suffixed ids, so this only concerns callers naming jobs by
    hand; :func:`clear_gc_tombstone` is the explicit escape hatch."""
    return f"shuffle-gc/{job}"


def clear_gc_tombstone(store: Store, job: str, *, worker: str = "-") -> None:
    """Explicitly revive a GC'd shuffle job name (job ids are single-use
    per store otherwise — see :func:`gc_tombstone_key`).  Only safe once
    no zombie attempt of the *old* job instance can still be running."""
    store.delete(gc_tombstone_key(job), worker=worker)


def write_partitions(
    store: Store,
    job: str,
    map_id: int,
    parts: Sequence[Sequence[Any]],
    *,
    worker: str = "-",
) -> int:
    """Write one intermediate object per partition; returns #objects.

    This is where the paper's quadratic request count comes from — and
    where batching attacks it: the whole map-side fan-out lands in one
    ``mset`` (KV: one round-trip per shard touched) or one ``put_many``
    (object store: one amortized round-trip), instead of one modeled
    request per partition.  The object *count* is unchanged (reducers
    still address per-(map, partition) keys); only the request count
    collapses.

    A zombie attempt (straggler whose speculative duplicate already
    satisfied the stage barrier) may run after ``delete_intermediates``
    GC'd the job; the tombstone check below un-writes its batch (returns
    0) instead of resurrecting deleted keys.  The check runs *after* the
    write on purpose — check-then-write would race (a tombstone landing
    between check and write leaves the resurrected keys forever), while
    write-then-check cannot: the tombstone is written before the GC's
    batched delete, so any write that lands after that delete must
    observe the tombstone and self-clean.  Cost: one modeled existence
    check per map task, amortized over the whole fan-out.

    Corollary: writes under a job name whose intermediates were already
    GC'd are dropped — job ids are single-use per store unless revived
    via :func:`clear_gc_tombstone`."""
    items = {
        intermediate_key(job, map_id, part_id): list(part)
        for part_id, part in enumerate(parts)
    }
    tomb = gc_tombstone_key(job)
    if isinstance(store, KVStore):
        store.mset(items, worker=worker)
        if store.exists(tomb, worker=worker):
            store.mdel(list(items), worker=worker)
            return 0
    else:
        store.put_many(items, worker=worker)
        if store.exists(tomb, worker=worker):
            store.delete_many(list(items), worker=worker)
            return 0
    return len(items)


def read_partition_column(
    store: Store,
    job: str,
    num_map_tasks: int,
    part_id: int,
    *,
    worker: str = "-",
) -> List[Any]:
    """Reduce-side: read intermediates from every map task for one partition.

    Batched — one ``mget`` (KV: one round-trip per shard touched) or one
    ``get_many`` (object store: one amortized round-trip) for the whole
    column, instead of ``num_map_tasks`` synchronous gets.  This is the
    fan-in the paper's Fig 5/6 sort saturates on; batching attacks the
    request count, not just the byte count."""
    keys = [intermediate_key(job, map_id, part_id) for map_id in range(num_map_tasks)]
    if isinstance(store, KVStore):
        chunks = store.mget(keys, default=[], worker=worker)
    else:
        got = store.get_many(keys, worker=worker)
        chunks = [got.get(k, []) for k in keys]
    out: List[Any] = []
    for chunk in chunks:
        out.extend(chunk)
    return out


def delete_intermediates(
    store: Store,
    job: str,
    num_map_tasks: int,
    num_partitions: int,
    *,
    worker: str = "-",
) -> int:
    """Shuffle-intermediate GC: retire every ``shuffle/{job}`` object after
    the merge stage has consumed them.  The key space is deterministic
    (``intermediate_key`` over the map × partition grid), so no listing is
    needed — the whole column space goes in one batched delete
    (``KVStore.mdel``: one round-trip per shard touched;
    ``ObjectStore.delete_many``: one amortized round-trip).  A GC
    tombstone (:func:`gc_tombstone_key`) is written *before* the deletes
    so a zombie map attempt landing afterwards sees it and drops its
    re-write.  Returns the number of keys submitted for deletion."""
    keys = [
        intermediate_key(job, map_id, part_id)
        for map_id in range(num_map_tasks)
        for part_id in range(num_partitions)
    ]
    if not keys:
        return 0
    if isinstance(store, KVStore):
        store.set(gc_tombstone_key(job), 1, worker=worker)
        store.mdel(keys, worker=worker)
    else:
        store.put(gc_tombstone_key(job), 1, worker=worker)
        store.delete_many(keys, worker=worker)
    return len(keys)


def merge_sorted(chunks: List[List[Any]], key: Optional[Callable[[Any], Any]] = None) -> List[Any]:
    import heapq

    return list(heapq.merge(*[sorted(c, key=key) for c in chunks], key=key))


def make_sort_records(n: int, seed: int, payload_bytes: int = 90) -> np.ndarray:
    """Daytona-sort-style records: 10-byte key + payload, as uint8 rows."""
    rng = np.random.default_rng(seed)
    recs = rng.integers(0, 256, size=(n, 10 + payload_bytes), dtype=np.uint8)
    return recs


def record_sort_key(rec: np.ndarray) -> bytes:
    return rec[:10].tobytes()
