"""Storage performance models.

The container this framework is developed in has one CPU core and no real
S3/Redis, but the paper's claims are quantitative (30–40 MB/s per worker,
60–80 GB/s aggregate, <1 ms KV ops, Redis request-throughput saturation).
To reproduce those *relationships* honestly we run every byte of the runtime
for real (data is actually stored, hashed, listed, shuffled) and model only
the wire: each storage operation is assigned a *virtual duration* from a
profile calibrated to the paper's measurements.  Virtual durations are
recorded in per-worker ledgers; benchmarks aggregate them.

Profiles:
  * ``S3_2017``        — the paper's measured S3 (Table 1, Fig 3).
  * ``LOCAL_SSD_C3`` / ``LOCAL_SSD_I2`` — Table 1 instance-local SSDs.
  * ``REDIS_2017``     — ElastiCache per-shard (Fig 4, Fig 5/6).
  * ``DISAGG_2026``    — the §4 extrapolation: disaggregated flash with
                         100 Gb/s NICs and much higher request throughput.

The model is a standard M/D/1-free approximation: per-op virtual time is
``latency + bytes / per_connection_bw``, and *aggregate* capacity caps are
applied analytically at the benchmark layer (effective per-worker bandwidth
= min(per_conn, aggregate / workers)); KV shards additionally cap request
throughput at ``ops_per_s_per_shard``.

Batched operations (``get_many``/``put_many``/``mget``/``mset``/…) charge
the *same formula once for the whole batch*: one request latency plus the
summed transfer time (the KV applies it per shard touched).  That makes
request count — the paper's Fig 5/6 bottleneck — a first-class modeled
quantity: one ledger record is one request, so batching N ops into one
record is exactly an N× request-count reduction at equal bytes.
"""

from __future__ import annotations

from dataclasses import dataclass

MB = 1e6
GB = 1e9


@dataclass(frozen=True)
class StorageProfile:
    name: str
    read_latency_s: float
    write_latency_s: float
    read_bw_per_conn: float  # bytes/s one connection can sustain
    write_bw_per_conn: float
    aggregate_read_bw: float  # bytes/s across all connections
    aggregate_write_bw: float
    ops_per_s_per_shard: float  # request-throughput cap (per shard)

    # ---- per-op virtual durations -------------------------------------
    def read_time(self, nbytes: int) -> float:
        return self.read_latency_s + nbytes / self.read_bw_per_conn

    def write_time(self, nbytes: int) -> float:
        return self.write_latency_s + nbytes / self.write_bw_per_conn

    # ---- aggregate analytics (used by scaling benchmarks) -------------
    def effective_read_bw(self, workers: int) -> float:
        """Per-worker read bandwidth under aggregate contention."""
        return min(self.read_bw_per_conn, self.aggregate_read_bw / max(workers, 1))

    def effective_write_bw(self, workers: int) -> float:
        return min(self.write_bw_per_conn, self.aggregate_write_bw / max(workers, 1))

    def effective_ops_per_s(self, workers: int, shards: int = 1) -> float:
        """Per-worker synchronous op rate: bounded by 1/latency per
        connection and by the shard request-throughput cap."""
        per_conn = 1.0 / max(self.read_latency_s, 1e-9)
        cap = self.ops_per_s_per_shard * max(shards, 1) / max(workers, 1)
        return min(per_conn, cap)


# Paper-calibrated constants -------------------------------------------------
# Fig 3: ~30 MB/s write, ~40 MB/s read per Lambda; aggregate >60 GB/s write,
# >80 GB/s read at 2800 workers.  Latency: S3 GET/PUT time-to-first-byte.
S3_2017 = StorageProfile(
    name="s3-2017",
    read_latency_s=0.030,
    write_latency_s=0.045,
    read_bw_per_conn=40 * MB,
    write_bw_per_conn=30 * MB,
    aggregate_read_bw=112 * GB,
    aggregate_write_bw=84 * GB,
    ops_per_s_per_shard=6_000.0,  # S3 request throughput: the sort bottleneck
)

# Table 1: single-machine write bandwidth.
LOCAL_SSD_C3 = StorageProfile(
    name="ssd-c3.8xlarge",
    read_latency_s=0.0001,
    write_latency_s=0.0001,
    read_bw_per_conn=400 * MB,
    write_bw_per_conn=208.73 * MB,
    aggregate_read_bw=400 * MB,
    aggregate_write_bw=208.73 * MB,
    ops_per_s_per_shard=100_000.0,
)
LOCAL_SSD_I2 = StorageProfile(
    name="ssd-i2.8xlarge",
    read_latency_s=0.0001,
    write_latency_s=0.0001,
    read_bw_per_conn=900 * MB,
    write_bw_per_conn=460.36 * MB,
    aggregate_read_bw=900 * MB,
    aggregate_write_bw=460.36 * MB,
    ops_per_s_per_shard=100_000.0,
)
LOCAL_SSD_I2_RAID = StorageProfile(
    name="4xssd-i2.8xlarge",
    read_latency_s=0.0001,
    write_latency_s=0.0001,
    read_bw_per_conn=3400 * MB,
    write_bw_per_conn=1768.04 * MB,
    aggregate_read_bw=3400 * MB,
    aggregate_write_bw=1768.04 * MB,
    ops_per_s_per_shard=400_000.0,
)
# Table 1 row "S3" is single-machine aggregate: 501.13 MB/s from one instance
# (many parallel connections on a c3.8xlarge).
S3_SINGLE_MACHINE_WRITE_BW = 501.13 * MB

# Fig 4: <1 ms synchronous put/get; ~700 txn/s/worker; two c3.8xlarge shards
# saturate around 1000 workers => per-shard cap ~= 1000*700/2.
REDIS_2017 = StorageProfile(
    name="redis-2017",
    read_latency_s=0.0008,
    write_latency_s=0.0008,
    read_bw_per_conn=80 * MB,
    write_bw_per_conn=80 * MB,
    aggregate_read_bw=10 * GB,   # per shard; scaled by shard count at use
    aggregate_write_bw=10 * GB,
    ops_per_s_per_shard=350_000.0,
)

# §4 trend extrapolation: disaggregated flash, flat-datacenter storage.
DISAGG_2026 = StorageProfile(
    name="disagg-2026",
    read_latency_s=0.0002,
    write_latency_s=0.0003,
    read_bw_per_conn=1.2 * GB,
    write_bw_per_conn=1.0 * GB,
    aggregate_read_bw=4000 * GB,
    aggregate_write_bw=3000 * GB,
    ops_per_s_per_shard=2_000_000.0,
)

PROFILES = {
    p.name: p
    for p in (
        S3_2017,
        LOCAL_SSD_C3,
        LOCAL_SSD_I2,
        LOCAL_SSD_I2_RAID,
        REDIS_2017,
        DISAGG_2026,
    )
}
