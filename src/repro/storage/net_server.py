"""``repro-kvd``: the wire-protocol KV/object server.

One process owns a data directory and serves the :mod:`.net_kv` protocol
over TCP.  Persistence is the PR-5 log-structured engine for BOTH
planes — a :class:`~repro.storage.file_kv.FileKVStore` in *exclusive*
mode (sole owner: no cross-process flock, no per-op stat, same framed
crash-safe appends) for the KV plane, and a second one holding blobs for
the object plane (:class:`_LogBlobs`).  That is the whole performance
story: a wire round-trip to a process that answers from materialized
state and persists by appending beats a shared-disk transaction that
must flock, stat, and replay — or open, write, and rename a file per
object.

Request execution
-----------------
Each connection is served by one thread: requests pipelined on a
connection execute in arrival order; concurrency comes from concurrent
connections, serialized per shard by the engine's shard locks exactly as
concurrent in-process threads are.  Ops dispatch through explicit
allowlists (``_KV_OPS`` / ``_OB_OPS``) — an unknown op is a clean
``err`` frame, and a malformed frame closes only the offending
connection (the decoder raises before anything executes, so a torn or
corrupt pipeline can never leave a transaction half-applied).

Three ops don't pass straight through:

* ``kv.eval`` / ``kv.eval_many`` — run ``fn(old)`` inside the shard
  transaction but return the *pre-image* (snapshotted by value before
  ``fn`` can mutate it); the client replays ``fn`` on that pre-image to
  reproduce closure side effects.  See :mod:`.net_kv`.
* ``kv.lpop_n`` — destructive reads journal non-empty results under
  ``net-ack/{client}/{rid}`` *in the popped key's own shard
  transaction*, so a client retrying a pop whose response was lost gets
  the journaled items instead of popping again (ack records are only
  ever addressed through the popped key's shard, which keeps the
  journal and the pop atomic).  The client retires ack records with its
  next pop of the same key.

Watch push
----------
The server keeps per-shard KV sequences and one object sequence.  Every
mutation broadcasts a keyed wake frame — ``("kv", shard, seq, keys)`` or
``("obj", seq, keys)`` — to every subscribed connection *including the
writer's own* (clients charge locally but never self-touch; the echo is
what advances their local shard sequences).  Wakes are hints: a waiter
re-probes its predicate on wake, so cross-shard ordering races between
handler threads are benign.  The ``hello`` reply carries the server
generation (fresh UUID per boot) and current sequences, which is what
lets a reconnecting client resync after a restart.
"""

from __future__ import annotations

import os
import pickle
import socket
import threading
import uuid
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import cloudpickle

from .file_kv import FileKVStore
from .kv_store import DELETE
from .net_kv import (
    FrameDecoder,
    ProtocolError,
    _sendall_parts,
    encode_wire,
    encode_wire_parts,
    extract_buffers,
)

_ABSENT = object()

# Responses whose payloads may ride zero-copy buffer frames when the
# client advertised ``zero_copy`` at sub time.  Only the bulk read paths
# qualify — everything else stays one small pickle.
_ZC_RESPONSES = frozenset({"ob.get", "ob.get_many", "kv.get", "kv.mget", "kv.lrange"})

# Watch-event frames queued per connection before backpressure kicks in.
# On overflow the whole backlog collapses into one conservative resync
# wake — wakes are hints, so dropping them loses precision, never a wake.
MAX_PUSH_QUEUE = 256


def _eval_preimage(fn, stored, default):
    """``(pre_image, fn_argument)`` for one eval key.  An arbitrary fn may
    mutate its argument in place, so it gets a deep copy and the pristine
    copy becomes the returned pre-image.  Functions marked with
    :func:`repro.storage.kv_pure` promise not to, so the stored object is
    handed over (and returned) directly — skipping a pickle round-trip per
    key that dominates eval cost when records carry whole task specs."""
    if getattr(getattr(fn, "func", fn), "__kv_pure__", False):
        return (default, default) if stored is _ABSENT else (stored, stored)
    if stored is _ABSENT:
        return default, pickle.loads(pickle.dumps(default))
    return pickle.loads(pickle.dumps(stored)), stored


class _LogBlobs:
    """The server-side object tier, persisted in the SAME log-structured
    engine as the KV plane: a second exclusive :class:`FileKVStore` whose
    values are the blobs.  A put is one framed crash-safe append plus a
    RAM index update; gets answer from materialized state with no file
    opens.  This is what makes the wire tier faster than the shared-disk
    ``FileBackend`` on the object plane — that backend pays an open +
    write + rename (and a readdir per list) per object, where a log
    append is a single buffered write.  ``ckpt/`` keys keep FileBackend's
    machine-crash durability via the engine's ``durable_prefixes``."""

    def __init__(self, root: str, *, num_shards: int, fsync: str) -> None:
        self.kv = FileKVStore(
            root,
            num_shards=num_shards,
            fsync=fsync,
            durable_prefixes=("ckpt/",),
            exclusive=True,
            charged=False,
        )

    def put(self, key: str, blob: bytes, *, if_absent: bool) -> bool:
        if if_absent:
            return self.kv.setnx(key, blob)
        self.kv.set(key, blob)
        return True

    def put_many(self, items: Dict[str, bytes], *, if_absent: bool) -> int:
        if if_absent:
            return sum(1 for k, b in items.items() if self.kv.setnx(k, b))
        self.kv.mset(dict(items))
        return len(items)

    def get(self, key: str) -> bytes:
        blob = self.kv.get(key, _ABSENT)
        if blob is _ABSENT:
            raise KeyError(key)
        return blob

    def get_many(self, keys: List[str]) -> Dict[str, bytes]:
        out = self.kv.mget(list(keys), default=_ABSENT)
        return {k: v for k, v in zip(keys, out) if v is not _ABSENT}

    def exists(self, key: str) -> bool:
        return self.kv.exists(key)

    def exists_many(self, keys: List[str]) -> set:
        out = self.kv.mget(list(keys), default=_ABSENT)
        return {k for k, v in zip(keys, out) if v is not _ABSENT}

    def delete(self, key: str) -> None:
        self.kv.delete(key)

    def list(self, prefix: str) -> List[str]:
        return sorted(self.kv.scan(prefix))

    def close(self) -> None:
        self.kv.close()

# Straight pass-through ops (server-side method name == wire op name).
_KV_OPS = frozenset(
    {
        "set", "get", "mget", "mset", "setnx", "incr", "cas", "delete",
        "mdel", "exists", "scan", "rpush", "rpush_many", "lrange", "llen",
    }
)
_OB_OPS = frozenset(
    {"get", "get_many", "exists", "exists_many", "delete", "list"}
)

# Which KV pass-through ops mutate, and what they touch (conditional
# writers touch only when they won — the returned value says).
_KV_WRITES = {
    "set": lambda args, value: [args[0]],
    "incr": lambda args, value: [args[0]],
    "delete": lambda args, value: [args[0]],
    "rpush": lambda args, value: [args[0]],
    "setnx": lambda args, value: [args[0]] if value else [],
    "cas": lambda args, value: [args[0]] if value else [],
    "mset": lambda args, value: list(args[0]),
    "rpush_many": lambda args, value: list(args[0]),
    "mdel": lambda args, value: list(args[0]),
}


class _ServerConn:
    """One accepted connection: socket, its subscription, and a send lock
    (responses from the conn's own thread interleave with pushes from the
    conn's own pusher thread).

    Watch events never block a writer: they enqueue on a BOUNDED per-
    connection queue drained by a dedicated pusher thread (started only
    for subscribed connections).  A slow watcher fills its queue; on
    overflow the backlog is dropped and replaced by one conservative
    resync wake (unknown keys, current sequences) — every waiter
    re-probes, so backpressure costs precision, never a lost wake, and a
    stalled consumer can no longer grow server memory without bound or
    stall op threads in ``sendall``."""

    def __init__(self, sock: socket.socket, peer: str) -> None:
        self.sock = sock
        self.peer = peer
        self.send_lock = threading.Lock()
        self.client_id: Optional[str] = None
        self.topics: Tuple[str, ...] = ()
        self.zero_copy = False
        self.alive = True
        self._push_q: deque = deque()
        self._push_cond = threading.Condition()
        self._push_overflow = False
        self._push_thread: Optional[threading.Thread] = None
        self._push_closed = False

    def send(self, msg: Any, *, pickler=pickle) -> None:
        self.send_bytes(encode_wire(msg, pickler=pickler))

    def send_bytes(self, frame: bytes) -> None:
        with self.send_lock:
            self.sock.sendall(frame)

    def send_parts(self, parts: List[Any]) -> None:
        with self.send_lock:
            _sendall_parts(self.sock, parts)

    # ---- backpressured event push ---------------------------------------
    def start_pusher(self, resync_frames) -> None:
        """Start the pusher thread (idempotent).  ``resync_frames(conn)``
        supplies the conservative wake frames sent after an overflow."""
        with self._push_cond:
            if self._push_thread is not None or self._push_closed:
                return
            self._push_thread = threading.Thread(
                target=self._push_loop,
                args=(resync_frames,),
                daemon=True,
                name=f"kvd-push-{self.peer}",
            )
            self._push_thread.start()

    def push(self, frame: bytes) -> None:
        """Enqueue one event frame; never blocks the calling op thread."""
        with self._push_cond:
            if self._push_closed:
                return
            if len(self._push_q) >= MAX_PUSH_QUEUE:
                self._push_q.clear()
                self._push_overflow = True
            else:
                self._push_q.append(frame)
            self._push_cond.notify()

    def _push_loop(self, resync_frames) -> None:
        while True:
            with self._push_cond:
                while not (self._push_q or self._push_overflow or self._push_closed):
                    self._push_cond.wait()
                if self._push_closed:
                    return
                overflow, self._push_overflow = self._push_overflow, False
                batch = list(self._push_q)
                self._push_q.clear()
            try:
                if overflow:
                    # The dropped backlog becomes one unknown-keys wake per
                    # subscribed stream, carrying the CURRENT sequences
                    # (computed now, so nothing that happened during the
                    # stall is missed).  Frames enqueued after the overflow
                    # follow behind; their older sequences are harmless
                    # (clients take the max and touches are additive).
                    for frame in resync_frames(self):
                        self.send_bytes(frame)
                for frame in batch:
                    self.send_bytes(frame)
            except OSError:
                self.alive = False
                return

    def close_push(self) -> None:
        with self._push_cond:
            self._push_closed = True
            self._push_q.clear()
            self._push_cond.notify_all()


class KVDServer:
    """The ``repro-kvd`` server.  ``start()`` begins accepting; ``port`` is
    the bound port (pass ``port=0`` to let the OS pick).  ``num_shards``
    must match across restarts over the same root (it is the layout of the
    persisted shard logs)."""

    def __init__(
        self,
        root: str,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        num_shards: int = 8,
        fsync: str = "auto",
    ) -> None:
        self.root = os.path.abspath(root)
        self.kv = FileKVStore(
            os.path.join(self.root, "kv"),
            num_shards=num_shards,
            fsync=fsync,
            exclusive=True,
            charged=False,
        )
        self.ob = _LogBlobs(
            os.path.join(self.root, "obj"), num_shards=num_shards, fsync=fsync
        )
        self.generation = uuid.uuid4().hex
        self.num_shards = num_shards
        self._kv_seqs = [0] * num_shards
        self._obj_seq = 0
        self._seq_lock = threading.Lock()
        self._conns: Dict[int, _ServerConn] = {}
        self._watches: Dict[str, set] = {}  # client_id -> watched kv keys
        # Lock-free push prefilters, rebuilt under _conn_lock on the rare
        # mutations (watch registration, subscription, connection close) and
        # read WITHOUT the lock on every write op.  Safe against the
        # register race: a watch registration updates the union BEFORE it
        # reads the shard seq for its reply, so a write that misses the
        # fresh union necessarily bumped the seq first — the client sees
        # the mismatch in the registration reply and self-wakes.
        self._watch_union: frozenset = frozenset()
        self._obj_subs = False
        self._conn_lock = threading.Lock()
        self._conn_ids = iter(range(1, 1 << 62))
        self._stop = threading.Event()
        if host.startswith("unix:"):
            # Same-host transport: a Unix socket halves the per-round-trip
            # syscall cost vs loopback TCP (no TCP stack traversal).
            path = host[len("unix:"):]
            try:
                os.unlink(path)  # stale socket from a SIGKILLed predecessor
            except FileNotFoundError:
                pass
            self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._listener.bind(path)
            self.host, self.port = host, 0
        else:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            self.host, self.port = self._listener.getsockname()[:2]
        self._listener.listen(128)
        self._accepter = threading.Thread(
            target=self._accept_loop, daemon=True, name=f"kvd-accept-{self.port}"
        )

    @property
    def address(self) -> str:
        if self.host.startswith("unix:"):
            return self.host
        return f"{self.host}:{self.port}"

    def start(self) -> "KVDServer":
        self._accepter.start()
        return self

    def serve_forever(self) -> None:
        self.start()
        self._stop.wait()

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for conn in conns:
            conn.close_push()
            try:
                conn.sock.close()
            except OSError:
                pass
        if self._accepter.is_alive():
            self._accepter.join(timeout=2.0)
        self.kv.close()
        self.ob.close()

    # ---- accept / connection plane --------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return  # listener closed
            if sock.family != socket.AF_UNIX:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            peer = f"{addr[0]}:{addr[1]}" if isinstance(addr, tuple) else str(addr)
            conn = _ServerConn(sock, peer)
            cid = next(self._conn_ids)
            with self._conn_lock:
                self._conns[cid] = conn
            threading.Thread(
                target=self._conn_loop,
                args=(cid, conn),
                daemon=True,
                name=f"kvd-conn-{cid}",
            ).start()

    def _conn_loop(self, cid: int, conn: _ServerConn) -> None:
        decoder = FrameDecoder()
        try:
            while not self._stop.is_set():
                if decoder.wanted():
                    # Mid-buffer-frame: recv straight into the payload's
                    # final bytearray (a large zero-copy put lands without
                    # intermediate copies).
                    got = conn.sock.recv_into(decoder.fill_view())
                    if not got:
                        return
                    decoder.filled(got)
                    continue
                data = conn.sock.recv(1 << 16)
                if not data:
                    return
                for msg in decoder.feed(data):
                    self._on_msg(conn, msg)
        except ProtocolError:
            # Malformed input: this connection is garbage — drop it, serve
            # everyone else.  Nothing was applied for the corrupt frame
            # (ops only run on whole, CRC-valid frames).
            return
        except OSError:
            return
        finally:
            conn.alive = False
            with self._conn_lock:
                self._conns.pop(cid, None)
                # Reap the client's watch set once its LAST connection is
                # gone (request and event channels share a client_id).
                if conn.client_id is not None and not any(
                    c.client_id == conn.client_id for c in self._conns.values()
                ):
                    self._watches.pop(conn.client_id, None)
                self._rebuild_push_filters()
            conn.close_push()
            try:
                conn.sock.close()
            except OSError:
                pass

    def _on_msg(self, conn: _ServerConn, msg: Any) -> None:
        if not (isinstance(msg, tuple) and msg and isinstance(msg[0], str)):
            raise ProtocolError(f"malformed message: {msg!r}")
        kind = msg[0]
        if kind == "sub":
            conn.client_id = str(msg[1])
            conn.topics = tuple(msg[2])
            if len(msg) > 3 and isinstance(msg[3], dict):
                conn.zero_copy = bool(msg[3].get("zero_copy", False))
            if conn.topics:
                conn.start_pusher(self._resync_frames)
            with self._conn_lock:
                self._rebuild_push_filters()
            with self._seq_lock:
                hello = {
                    "gen": self.generation,
                    "num_shards": self.num_shards,
                    "kv_seqs": list(self._kv_seqs),
                    "obj_seq": self._obj_seq,
                }
            conn.send(("hello", hello))
            return
        if kind == "cast":
            # Fire-and-forget op: execute, push wakes, send nothing back.
            # A failing cast is dropped (the client holds no handle to fail)
            # — malformed *framing* still kills the connection above.
            if conn.client_id is None:
                raise ProtocolError("cast before sub handshake")
            _kind, op, args, kwargs = msg
            try:
                _value, frames = self._execute(conn, 0, op, args, kwargs)
            except ProtocolError:
                raise
            except Exception:
                return
            self._push_events(frames)
            return
        if kind != "req":
            raise ProtocolError(f"unknown message kind {kind!r}")
        if conn.client_id is None:
            raise ProtocolError("req before sub handshake")
        _kind, rid, op, args, kwargs = msg
        try:
            value, frames = self._execute(conn, rid, op, args, kwargs)
        except ProtocolError:
            raise
        except Exception as exc:  # clean per-op failure, never a crash
            conn.send(("err", rid, type(exc).__name__, str(exc)))
            return
        buffers: List[Any] = []
        if conn.zero_copy and op in _ZC_RESPONSES:
            value = extract_buffers(value, buffers)
        res = ("res", rid, value)
        try:
            parts = encode_wire_parts(res, buffers)
        except Exception:
            # Values that arrived by value (cloudpickle) may need it back.
            parts = encode_wire_parts(res, buffers, pickler=cloudpickle)
        conn.send_parts(parts)
        self._push_events(frames)

    # ---- op execution ----------------------------------------------------
    def _execute(
        self, conn: _ServerConn, rid: int, op: str, args: tuple, kwargs: dict
    ) -> Tuple[Any, List[Tuple[str, tuple]]]:
        plane, _, name = op.partition(".")
        if plane == "watch":
            # Watch registration: this client wants pushed wakes for ``key``
            # (on=True) or no longer does.  Replies with the key's current
            # server-side shard sequence so the client can detect writes
            # that landed while it was not watching (resync — no wake is
            # ever lost to the register window).
            key, on = args
            with self._conn_lock:
                watched = self._watches.setdefault(conn.client_id, set())
                if on:
                    watched.add(key)
                else:
                    watched.discard(key)
                self._rebuild_push_filters()
            sidx = self.kv.shard_of(key)
            with self._seq_lock:
                return self._kv_seqs[sidx], []
        if plane == "kv":
            if name == "eval":
                return self._kv_eval(*args)
            if name == "eval_many":
                return self._kv_eval_many(*args)
            if name == "lpop_n":
                return self._kv_lpop_n(conn.client_id, rid, *args)
            if name not in _KV_OPS:
                raise ValueError(f"unknown kv op {name!r}")
            value = getattr(self.kv, name)(*args, **kwargs)
            touched = _KV_WRITES.get(name)
            if touched is None:
                return value, []
            return value, self._kv_frames(touched(args, value))
        if plane == "ob":
            if name == "put":
                won = self.ob.put(args[0], args[1], if_absent=args[2])
                return won, (self._ob_frames([args[0]]) if won else [])
            if name == "put_many":
                n_won = self.ob.put_many(args[0], if_absent=args[1])
                # Superset hint on partial if_absent wins: waiters re-probe.
                return n_won, (self._ob_frames(list(args[0])) if n_won else [])
            if name not in _OB_OPS:
                raise ValueError(f"unknown ob op {name!r}")
            value = getattr(self.ob, name)(*args)
            if name == "delete":
                return value, self._ob_frames([args[0]])
            return value, []
        raise ValueError(f"unknown op plane {plane!r}")

    def _kv_eval(self, key: str, fn, default: Any) -> Tuple[Any, list]:
        sidx = self.kv.shard_of(key)
        with self.kv._txn(sidx) as txn:
            stored = txn.state.get(key, _ABSENT)
            pre, arg = _eval_preimage(fn, stored, default)
            new = fn(arg)
            if new is DELETE:
                txn.drop(key)
            else:
                txn.put(key, new)
        return pre, self._kv_frames([key])

    def _kv_eval_many(self, updates: Dict[str, Any], default: Any) -> Tuple[Any, list]:
        by_shard: Dict[int, List[str]] = {}
        for key in updates:
            by_shard.setdefault(self.kv.shard_of(key), []).append(key)
        pres: Dict[str, Any] = {}
        for sidx, group in sorted(by_shard.items()):
            with self.kv._txn(sidx) as txn:
                for key in group:
                    stored = txn.state.get(key, _ABSENT)
                    fn = updates[key]
                    pres[key], arg = _eval_preimage(fn, stored, default)
                    new = fn(arg)
                    if new is DELETE:
                        txn.drop(key)
                    else:
                        txn.put(key, new)
        return pres, self._kv_frames(list(updates))

    def _kv_lpop_n(
        self, client_id: str, rid: int, key: str, max_n: int, acked: List[int]
    ) -> Tuple[List[Any], list]:
        sidx = self.kv.shard_of(key)
        ack_key = f"net-ack/{client_id}/{rid}"
        with self.kv._txn(sidx) as txn:
            for old_rid in acked:
                txn.drop(f"net-ack/{client_id}/{old_rid}")
            cached = txn.state.get(ack_key, _ABSENT)
            if cached is not _ABSENT:
                # Retry of a pop whose response was lost: hand back the
                # journaled items — popping again would LOSE the originals.
                return list(cached), []
            out = txn.popleft_n(key, max_n)
            if out:
                txn.put(ack_key, list(out))
        return out, (self._kv_frames([key]) if out else [])

    # ---- watch push ------------------------------------------------------
    def _rebuild_push_filters(self) -> None:
        """Recompute the lock-free push prefilters.  Caller holds
        ``_conn_lock``; plain attribute assignment publishes the snapshot."""
        self._watch_union = frozenset().union(*self._watches.values()) \
            if self._watches else frozenset()
        self._obj_subs = any("obj" in c.topics for c in self._conns.values())

    def _kv_frames(self, keys: List[str]) -> List[Tuple[str, set, tuple]]:
        by_shard: Dict[int, List[str]] = {}
        for key in keys:
            by_shard.setdefault(self.kv.shard_of(key), []).append(key)
        frames: List[Tuple[str, set, tuple]] = []
        with self._seq_lock:
            for sidx, group in sorted(by_shard.items()):
                self._kv_seqs[sidx] += 1
                frames.append(
                    ("kv", set(group), ("kv", sidx, self._kv_seqs[sidx], group))
                )
        return frames

    def _ob_frames(self, keys: List[str]) -> List[Tuple[str, set, tuple]]:
        with self._seq_lock:
            self._obj_seq += 1
            return [("obj", set(keys), ("obj", self._obj_seq, list(keys)))]

    def _push_events(self, frames: List[Tuple[str, set, tuple]]) -> None:
        """Deliver wake frames to the connections that care.  KV events go
        only to clients whose registered watch set intersects the touched
        keys — in a running cluster the overwhelming share of writes
        (status evals, heartbeats, result records) has no watcher at all,
        and skipping those sends is a large constant-factor win on both
        sides of the wire.  Object events are topic-scoped (a client with
        an object event channel is waiting on result keys)."""
        if not frames:
            return
        # Lock-free prefilter (see __init__): in a running cluster the
        # overwhelming share of writes has no watcher and no object
        # subscriber, and a per-write _conn_lock acquisition plus conn scan
        # is measurable on the map hot path.
        union, obj_subs = self._watch_union, self._obj_subs
        frames = [
            f
            for f in frames
            if (not union.isdisjoint(f[1]) if f[0] == "kv" else obj_subs)
        ]
        if not frames:
            return
        plan: List[Tuple[tuple, List[_ServerConn]]] = []
        with self._conn_lock:
            conns = list(self._conns.values())
            for topic, keys, event in frames:
                if topic == "kv":
                    targets = [
                        c
                        for c in conns
                        if topic in c.topics
                        and c.client_id in self._watches
                        and not self._watches[c.client_id].isdisjoint(keys)
                    ]
                else:
                    targets = [c for c in conns if topic in c.topics]
                if targets:
                    plan.append((event, targets))
        for event, targets in plan:
            frame = encode_wire(event)
            for conn in targets:
                # Enqueue, never send: a slow watcher's socket can't stall
                # this (writer) thread — its pusher thread owns the send.
                conn.push(frame)

    def _resync_frames(self, conn: _ServerConn) -> List[bytes]:
        """Conservative wakes sent after a connection's push queue
        overflowed: one unknown-keys event per subscribed stream carrying
        the current sequences.  Every waiter behind the connection
        re-probes its predicate once — the dropped backlog loses no
        wake."""
        frames: List[bytes] = []
        with self._seq_lock:
            kv_seqs = list(self._kv_seqs)
            obj_seq = self._obj_seq
        if "kv" in conn.topics:
            for sidx, seq in enumerate(kv_seqs):
                frames.append(encode_wire(("kv", sidx, seq, None)))
        if "obj" in conn.topics:
            frames.append(encode_wire(("obj", obj_seq, None)))
        return frames


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    parser = argparse.ArgumentParser(
        prog="repro-kvd",
        description="Wire-protocol KV/object server over a log-structured "
        "data directory (see repro.storage.net_kv).",
    )
    parser.add_argument("--root", required=True, help="data directory")
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=0, help="0 = OS-assigned")
    parser.add_argument(
        "--uds", default=None, help="Unix socket path (overrides --host/--port)"
    )
    parser.add_argument("--num-shards", type=int, default=8)
    parser.add_argument(
        "--fsync", default="auto", choices=("auto", "commit", "batch", "never")
    )
    args = parser.parse_args(argv)
    if os.environ.get("REPRO_SANITIZE") == "1":
        from repro.analysis.sanitizer import install

        install()
    server = KVDServer(
        args.root,
        f"unix:{args.uds}" if args.uds else args.host,
        args.port,
        num_shards=args.num_shards,
        fsync=args.fsync,
    ).start()
    print(f"LISTENING {server.address}", flush=True)
    try:
        server._stop.wait()
    except KeyboardInterrupt:
        server.close()


if __name__ == "__main__":
    main()
