"""Minimal ctypes binding to Linux inotify for the cross-process watchers.

The container ships no inotify Python package, so the binding talks to
libc directly: ``inotify_init1`` / ``inotify_add_watch`` / ``read``.  The
:class:`~repro.storage.object_store._PollWatcher` uses it (when available)
to block on real filesystem events instead of exponential-backoff polling —
zero wakeups between events, sub-millisecond wake on an append from another
process.  On non-Linux platforms, or if libc refuses, ``Inotify.available()``
is False and the watcher keeps the portable backoff poll.

Only what the watchers need is bound: watches are added on *directories*
(per inotify(7), a directory watch reports events for the files inside it,
which also survives the atomic-rename pattern every writer here uses —
a ``rename`` onto a watched directory's entry raises ``IN_MOVED_TO``
where a watch on the replaced file itself would have died with it).
"""

from __future__ import annotations

import ctypes
import os
import struct
import sys
import threading
from typing import List, Optional, Tuple

# Event masks (linux/inotify.h)
IN_MODIFY = 0x00000002
IN_ATTRIB = 0x00000004
IN_CLOSE_WRITE = 0x00000008
IN_MOVED_FROM = 0x00000040
IN_MOVED_TO = 0x00000080
IN_CREATE = 0x00000100
IN_DELETE = 0x00000200

# Everything a writer can do to a log/seq/object file in a watched dir.
WATCH_MASK = (
    IN_MODIFY
    | IN_ATTRIB
    | IN_CLOSE_WRITE
    | IN_MOVED_FROM
    | IN_MOVED_TO
    | IN_CREATE
    | IN_DELETE
)

_IN_NONBLOCK = 0o4000  # O_NONBLOCK
_IN_CLOEXEC = 0o2000000  # O_CLOEXEC

_EVENT_HDR = struct.Struct("iIII")  # wd, mask, cookie, name length

_libc = None
_libc_guard = threading.Lock()
_probe_result: Optional[bool] = None


def _get_libc():
    global _libc
    with _libc_guard:
        if _libc is None:
            _libc = ctypes.CDLL(None, use_errno=True)
        return _libc


class Inotify:
    """One inotify instance (non-blocking fd; poll/select it, then drain
    with :meth:`read_events`)."""

    def __init__(self) -> None:
        libc = _get_libc()
        fd = libc.inotify_init1(_IN_NONBLOCK | _IN_CLOEXEC)
        if fd < 0:
            raise OSError(ctypes.get_errno(), "inotify_init1 failed")
        self._fd = fd
        self._libc = libc

    @staticmethod
    def available() -> bool:
        """Can this platform serve inotify?  Probed once (cheap init/close)."""
        global _probe_result
        if _probe_result is None:
            if not sys.platform.startswith("linux"):
                _probe_result = False
            else:
                try:
                    Inotify().close()
                    _probe_result = True
                except Exception:
                    _probe_result = False
        return _probe_result

    def fileno(self) -> int:
        return self._fd

    def add_watch(self, path: str, mask: int = WATCH_MASK) -> int:
        wd = self._libc.inotify_add_watch(self._fd, os.fsencode(path), mask)
        if wd < 0:
            raise OSError(ctypes.get_errno(), f"inotify_add_watch({path!r}) failed")
        return wd

    def read_events(self) -> List[Tuple[int, int, str]]:
        """Drain pending events: ``[(wd, mask, name), ...]``.  Non-blocking —
        returns [] when the kernel queue is empty."""
        out: List[Tuple[int, int, str]] = []
        while True:
            try:
                buf = os.read(self._fd, 65536)
            except BlockingIOError:
                return out
            except OSError:
                return out
            off = 0
            while off + _EVENT_HDR.size <= len(buf):
                wd, mask, _cookie, nlen = _EVENT_HDR.unpack_from(buf, off)
                off += _EVENT_HDR.size
                name = buf[off : off + nlen].split(b"\0", 1)[0].decode(
                    "utf-8", "surrogateescape"
                )
                off += nlen
                out.append((wd, mask, name))

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1
