"""Cross-process KV store over a shared directory — log-structured.

The in-memory :class:`~repro.storage.kv_store.KVStore` models ElastiCache
for a single driver process.  A *multi-process* driver — the paper's "N
concurrent drivers are as elastic as the workers" end state — needs the
same Redis semantics reachable from every process, so this module gives the
KV a file substrate with the same public API and the same per-shard
accounting.  Since PR 5 the substrate is **log-structured**: the whole-shard
``pickle.dump``-per-transaction engine (PR 4) paid O(shard size) for every
op; this one pays O(record):

  * **per-shard append-only logs** — every commit appends one framed record
    batch (:func:`~repro.storage.kv_store.encode_frame`) to ``shard-N.log``
    under the shard's ``flock``.  A batched op (``mset``/``rpush_many``/
    ``eval_many``/``mdel``) is **one multi-record frame** — one disk append
    per shard touched, not N snapshot rewrites;
  * **replay-the-tail reads** — each process keeps a materialized snapshot
    of the shard keyed by ``(generation, log offset)``; a transaction that
    finds the log unchanged reuses it outright, one that finds it grown
    replays only the tail it hasn't seen.  Deltas (not operations) are
    logged, so replay is pure assignment — see ``apply_record``;
  * **the log file is the seq** — the log's stat signature *is* the shard's
    cross-process write sequence (PR 4's separate ``.seq`` file and its
    double write are gone).  The same waiter-gated watcher
    (:class:`~repro.storage.object_store._PollWatcher`, inotify-backed on
    Linux) watches log sizes directly and converts foreign appends into
    this process's shard-condition broadcasts, so ``blpop``/``wait_key``
    block event-driven across processes;
  * **compaction** — when a shard's log outgrows
    ``max(compact_min_bytes, compact_ratio × last snapshot size)``, the
    live state is rewritten as the generation-suffixed
    ``shard-N.snap.{G+1}`` (pickled ``(G+1, state)``, fsynced, atomic
    rename) and the log is replaced by a fresh one carrying G+1 in its
    header (the G snapshot is unlinked).  Every step is crash-safe: a
    reader pairs a log strictly with its own generation's snapshot, so a
    crash between the two renames leaves the new snapshot inert — the old
    log (and anything a live peer appends to it afterwards) keeps reading
    correctly, and the stale snapshot is overwritten by the next
    successful compaction;
  * **off-thread compaction (PR 9)** — the snapshot rewrite is O(shard
    size), so running it inline would stall the committing transaction
    (and, behind ``repro-kvd``, every client of that shard).  With
    ``compaction="thread"`` (the default) a commit that crosses the
    threshold only *flags* the shard; a per-store compactor thread then
    runs the rewrite in two phases.  Phase A holds **no locks**: it reads
    the log file, replays it over its generation's snapshot, and lands the
    ``(G+1, state)`` pickle in a private tmp file.  Phase B takes the
    normal shard transaction (thread lock + flock) and re-checks the
    generation fence — if a peer compacted meanwhile the plan is
    discarded — then renames the snapshot into place and installs a fresh
    G+1 log carrying the frames committed *during* phase A.  Commit-path
    cost is one flag write; the crash windows are the same two renames as
    before.  ``compaction="inline"`` keeps the PR-5 behavior for
    deterministic tests;
  * **crash safety at the record level** — a writer killed mid-append
    leaves a torn tail; length/CRC framing detects it, replay stops at the
    committed prefix, and the next writer truncates the garbage before
    appending (it holds the exclusive flock, so this is race-free).

Durability is a **policy**, not a constant (``fsync=``):

  ========== =========================================================
  ``auto``    (default) fsync per commit for control keys — any key
              under ``durable_prefixes`` (``sched/``) — batched for
              data-plane keys: control transitions survive a machine
              crash, bulk churn rides the page cache
  ``commit``  fsync after every commit
  ``batch``   fsync after every ``fsync_batch_n`` commits (group
              commit; also flushed at compaction and ``close``)
  ``never``   OS-buffered only (the PR-4 behavior)
  ========== =========================================================

Note that *visibility* is independent of fsync — commits are in the page
cache the instant the flock drops, so other processes always see them;
the policy only decides what survives a machine (not process) crash.

The PR-4 snapshot-per-transaction engine survives as ``engine="snapshot"``
for measurement (``benchmarks/microbench.py file_substrate`` prices both);
``engine="log"`` is the default.

Virtual-time charging is identical to the in-memory KV (same op names,
same per-shard amortization), so benchmarks and ledgers compare directly.
"""

from __future__ import annotations

import fcntl
import os
import pickle
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from .kv_store import (
    DELETE,
    LOG_HEADER_SIZE,
    KVStore,
    _sizeof,
    apply_record,
    decode_log_header,
    encode_frame,
    encode_log_header,
    iter_frames,
)
from .object_store import Ledger, _PollWatcher
from .perf_model import REDIS_2017, StorageProfile

# Commit fsync modes an engine understands (derived from the store policy).
_SYNC, _LAZY, _NONE = "sync", "lazy", "none"


class _Txn:
    """One shard transaction: a mutable ``state`` dict plus the framed
    state-delta ``records`` that describe every mutation made to it.  The
    helpers mutate and record in one step so state and log can't drift."""

    __slots__ = ("state", "records")

    def __init__(self, state: Dict[str, Any]) -> None:
        self.state = state
        self.records: List[Tuple[str, str, Any]] = []

    def put(self, key: str, value: Any) -> None:
        self.state[key] = value
        self.records.append(("s", key, value))

    def drop(self, key: str) -> bool:
        existed = self.state.pop(key, _MISS) is not _MISS
        if existed:
            self.records.append(("d", key, None))
        return existed

    def extend(self, key: str, values: List[Any]) -> List[Any]:
        lst = self.state.setdefault(key, [])
        lst.extend(values)
        self.records.append(("a", key, list(values)))
        return lst

    def popleft(self, key: str) -> Any:
        """Pop the head, or the ``_MISS`` sentinel when the list is empty —
        a stored ``None`` is a real element and must round-trip (Redis LPOP
        nil vs. stored-empty distinction)."""
        lst = self.state.get(key)
        if not lst:
            return _MISS
        value = lst.pop(0)
        self.records.append(("p", key, 1))
        return value

    def popleft_n(self, key: str, max_n: int) -> List[Any]:
        lst = self.state.get(key)
        out = list(lst[:max_n]) if lst else []
        if out:
            del lst[: len(out)]
            self.records.append(("p", key, len(out)))
        return out


_MISS = object()


class _LogShard:
    """One shard's log-structured engine.  Every method runs under the
    shard's exclusive ``flock`` (the store guarantees it), so file mutations
    never race; the generation header makes cross-process cache validation
    exact (see module docstring for the protocol)."""

    def __init__(
        self,
        root: str,
        sidx: int,
        *,
        compact_min_bytes: int,
        compact_ratio: float,
        fsync_batch_n: int,
    ) -> None:
        self.log_path = os.path.join(root, f"shard-{sidx}.log")
        # Snapshots are GENERATION-SUFFIXED (shard-N.snap.G): recovery pairs
        # a log strictly with its own generation's snapshot, so a crash
        # between compaction's two renames leaves a gen-G+1 snapshot that is
        # simply ignored (and later overwritten) while the gen-G log — and
        # any frames a live peer appended to it after the crash — replays
        # over the gen-G snapshot with nothing lost.
        self.snap_base = os.path.join(root, f"shard-{sidx}.snap")
        self._compact_min_bytes = compact_min_bytes
        self._compact_ratio = compact_ratio
        self._fsync_batch_n = fsync_batch_n
        self._fd: Optional[int] = None
        self._ino = -1
        self._gen = 0
        self._state: Optional[Dict[str, Any]] = None
        self._valid_end = 0  # committed prefix: absolute offset of last whole frame
        self._file_size = 0  # actual size (== _valid_end unless the tail is torn)
        self._snap_bytes = 0
        self._pending_syncs = 0
        self.bytes_written = 0  # real bytes this process wrote to disk (bench metric)
        self.compact_wanted = False  # set by commit, consumed by the compactor

    # The log's stat signature is the cross-process write sequence.
    @property
    def watch_path(self) -> str:
        return self.log_path

    # ---- file plumbing --------------------------------------------------
    def _open_fd(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
        self._fd = os.open(self.log_path, os.O_RDWR)
        self._ino = os.fstat(self._fd).st_ino

    def _write_fresh_log(self, generation: int) -> None:
        """Install an empty log carrying ``generation`` via atomic rename
        (a log file is *always* whole: it either exists with a full header
        or not at all)."""
        tmp = f"{self.log_path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(encode_log_header(generation))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.log_path)
        self._open_fd()
        self._gen = generation
        self._valid_end = self._file_size = LOG_HEADER_SIZE
        self._pending_syncs = 0

    def _snap_path(self, generation: int) -> str:
        return f"{self.snap_base}.{generation}"

    def _read_snapshot(self, generation: int) -> Dict[str, Any]:
        """State at ``generation``'s compaction point.  Generation 0 has no
        snapshot by construction.  Absence of the file is legitimate (never
        compacted at this generation); any OTHER error is re-raised — a
        transient EMFILE/EIO treated as "empty" would rebuild wrong state
        and then commit deltas against it."""
        if generation == 0:
            self._snap_bytes = 0
            return {}
        try:
            with open(self._snap_path(generation), "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            self._snap_bytes = 0
            return {}
        gen, state = pickle.loads(blob)
        if int(gen) != generation:  # pragma: no cover - naming guarantees it
            raise RuntimeError(
                f"snapshot {self._snap_path(generation)} carries gen {gen}"
            )
        self._snap_bytes = len(blob)
        return dict(state)

    def _latest_snapshot_gen(self) -> int:
        """Highest generation with a snapshot on disk (0 if none) — the
        fallback anchor when a log header is unreadable."""
        best = 0
        prefix = os.path.basename(self.snap_base) + "."
        try:
            names = os.listdir(os.path.dirname(self.snap_base))
        except OSError:
            return 0
        for name in names:
            if name.startswith(prefix):
                try:
                    best = max(best, int(name[len(prefix):]))
                except ValueError:
                    continue
        return best

    # ---- load / replay --------------------------------------------------
    def load(self) -> Dict[str, Any]:
        """Current shard state (must hold the flock).  Fast path: log inode
        and size unchanged → reuse the materialized snapshot; grown → replay
        only the tail; anything else (compaction by a peer, first touch,
        crash leftovers) → full reload."""
        try:
            pst = os.stat(self.log_path)
        except FileNotFoundError:
            return self._reload()
        if (
            self._state is not None
            and pst.st_ino == self._ino
            and self._file_size == self._valid_end  # no torn tail on record
        ):
            if pst.st_size == self._file_size:
                return self._state  # unchanged: reuse outright
            if pst.st_size > self._valid_end:
                self._replay_tail(pst.st_size)  # grown: replay only the tail
                return self._state
            # Shrunk: offsets can't be trusted — reload.
        # Note the cached-torn-tail case always reloads: size alone can't
        # distinguish "garbage still there" from "a peer truncated it and
        # committed exactly as many bytes" — trusting the stale offsets
        # there would let our next commit ftruncate a peer's frame away.
        return self._reload()

    def load_fast(self) -> Dict[str, Any]:
        """:meth:`load` for an *exclusive* store: no other process writes
        this log, so a clean materialized state needs no stat round-trip.
        Falls back to the full load on first touch, after a failed commit
        (invalidate), or while a torn tail is on record."""
        if self._state is not None and self._file_size == self._valid_end:
            return self._state
        return self.load()

    def _replay_tail(self, size: int) -> None:
        tail = os.pread(self._fd, size - self._valid_end, self._valid_end)
        end = 0
        for records, end in iter_frames(tail):
            for rec in records:
                apply_record(self._state, rec)
        self._valid_end += end
        self._file_size = size  # > _valid_end iff the tail is torn

    def _reload(self) -> Dict[str, Any]:
        try:
            with open(self.log_path, "rb") as f:
                buf = f.read()
        except FileNotFoundError:
            buf = None
        log_gen = decode_log_header(buf) if buf is not None else None
        if log_gen is None:
            # Log missing or header unreadable (external truncation; our own
            # log creation is atomic).  Anchor on the newest snapshot — the
            # log's post-snapshot frames are unrecoverable without a header,
            # but the snapshot state is — and install a fresh log there.
            gen = self._latest_snapshot_gen()
            self._state = self._read_snapshot(gen)
            self._write_fresh_log(gen)
            return self._state
        # The log's own generation names its snapshot: a crashed compaction
        # may have left a NEWER snapshot (gen+1) behind, but this log — and
        # anything a live peer appended to it since — pairs with gen's, so
        # nothing committed is ever discarded.  The stale gen+1 snapshot is
        # overwritten by the next successful compaction.
        self._state = self._read_snapshot(log_gen)
        self._open_fd()
        self._gen = log_gen
        # Replay from the buffer already in hand (one read, not a second
        # pread of the same bytes through the fd).
        end = LOG_HEADER_SIZE
        for records, end in iter_frames(buf, LOG_HEADER_SIZE):
            for rec in records:
                apply_record(self._state, rec)
        self._valid_end = end
        self._file_size = len(buf)
        return self._state

    # ---- commit / compaction -------------------------------------------
    def commit(self, state: Dict[str, Any], records: List[tuple], mode: str) -> None:
        """Append one frame for this transaction's records (must hold the
        flock; ``state`` is the dict ``load`` returned, already mutated)."""
        if self._file_size > self._valid_end:
            # A crashed writer's torn tail sits after the committed prefix;
            # drop it so our frame lands contiguously (flock makes this safe).
            os.ftruncate(self._fd, self._valid_end)
            self._file_size = self._valid_end
        frame = encode_frame(records)
        written = 0
        while written < len(frame):
            # pwrite may write short (ENOSPC mid-frame returns a count, not
            # an exception): advancing offsets on a short write would record
            # a phantom commit that replay drops at the torn frame.
            n = os.pwrite(self._fd, frame[written:], self._valid_end + written)
            if n <= 0:
                raise OSError(f"short log append: {written}/{len(frame)} bytes")
            written += n
        self._valid_end += len(frame)
        self._file_size = self._valid_end
        self.bytes_written += len(frame)
        self._pending_syncs += 1
        if mode == _SYNC or (
            mode == _LAZY and self._pending_syncs >= self._fsync_batch_n
        ):
            self.sync()
        log_bytes = self._valid_end - LOG_HEADER_SIZE
        if log_bytes >= max(
            self._compact_min_bytes, self._compact_ratio * self._snap_bytes
        ):
            # Only flag: the snapshot rewrite is O(shard size) and must not
            # run inside the commit path — the store decides whether to run
            # it inline (tests) or hand it to the compactor thread.
            self.compact_wanted = True

    def sync(self) -> None:
        if self._fd is not None and self._pending_syncs:
            os.fsync(self._fd)
            self._pending_syncs = 0

    def _publish_snapshot(self, state: Dict[str, Any]) -> int:
        """Step 1 of compaction: land ``(gen+1, state)`` as the gen+1
        snapshot via fsync + atomic rename.  Split out so crash tests can
        stop here — until step 2 swaps the log, the gen+1 snapshot is inert
        (readers pair the gen-G log with the gen-G snapshot), so the state
        must read back identically, including later appends by live
        peers."""
        new_gen = self._gen + 1
        blob = pickle.dumps((new_gen, state), protocol=pickle.HIGHEST_PROTOCOL)
        tmp = f"{self.snap_base}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._snap_path(new_gen))
        self._snap_bytes = len(blob)
        self.bytes_written += len(blob)
        return new_gen

    def _compact(self, state: Dict[str, Any]) -> None:
        """Rewrite live state as a snapshot and truncate the log (both via
        atomic rename).  Crash-safe: until step 2 installs the gen+1 log,
        the gen+1 snapshot is ignored by every reader; after it, the old
        generation's snapshot is garbage and is unlinked best-effort."""
        old_gen = self._gen
        new_gen = self._publish_snapshot(state)
        self._write_fresh_log(new_gen)
        self.compact_wanted = False
        if old_gen:
            try:
                os.unlink(self._snap_path(old_gen))
            except OSError:
                pass

    # ---- two-phase off-thread compaction --------------------------------
    def _peek_snapshot(self, generation: int) -> Optional[Dict[str, Any]]:
        """Read-only :meth:`_read_snapshot`: no engine bookkeeping is
        touched, corruption returns ``None`` (abort the plan) instead of
        raising — the compactor runs without locks and must never poison
        the engine's own state."""
        if generation == 0:
            return {}
        try:
            with open(self._snap_path(generation), "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            return {}
        except OSError:
            return None
        try:
            gen, state = pickle.loads(blob)
        except Exception:
            return None
        if int(gen) != generation:
            return None
        return dict(state)

    def plan_compaction(self) -> Optional[tuple]:
        """Phase A — runs on the compactor thread with NO locks held.  Reads
        the log file as any crash-recovery reader would (header names the
        snapshot, replay whole frames, stop at a torn tail), pickles the
        folded state, and lands it fsynced in a *private* tmp file.
        Concurrent commits only append, so the replayed prefix is a
        consistent point-in-time state; anything committed after it rides
        into the next generation's log as the tail (phase B).  Returns the
        plan ``(gen, end_offset, tmp_path, blob_len)`` or ``None`` when
        there is nothing to do / a peer compacted first."""
        gen = self._gen
        try:
            with open(self.log_path, "rb") as f:
                buf = f.read()
        except OSError:
            return None
        if decode_log_header(buf) != gen:
            return None  # a peer swapped the log since we were flagged
        state = self._peek_snapshot(gen)
        if state is None:
            return None
        end = LOG_HEADER_SIZE
        for records, end in iter_frames(buf, LOG_HEADER_SIZE):
            for rec in records:
                apply_record(state, rec)
        if end <= LOG_HEADER_SIZE:
            return None  # empty log: nothing to fold in
        blob = pickle.dumps((gen + 1, state), protocol=pickle.HIGHEST_PROTOCOL)
        tmp = f"{self.snap_base}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        return (gen, end, tmp, len(blob))

    def finish_compaction(self, plan: tuple) -> bool:
        """Phase B — must hold the shard transaction (thread lock + flock,
        state freshly loaded).  Re-checks the generation fence: if this
        engine is no longer at the plan's generation (a peer compacted, the
        log was replaced) the plan is stale and is discarded unapplied.
        Otherwise the tmp snapshot renames into place and a fresh gen+1 log
        is installed carrying the frames committed after the plan's replay
        point — the same two atomic renames (and crash windows) as
        :meth:`_compact`."""
        gen, end, tmp, blob_len = plan
        if self._gen != gen or end > self._valid_end:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        new_gen = gen + 1
        os.replace(tmp, self._snap_path(new_gen))
        self._snap_bytes = blob_len
        self.bytes_written += blob_len
        # Frames committed while phase A ran carry over into the new log.
        tail = b""
        if self._valid_end > end:
            tail = os.pread(self._fd, self._valid_end - end, end)
        ltmp = f"{self.log_path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(ltmp, "wb") as f:
            f.write(encode_log_header(new_gen))
            if tail:
                f.write(tail)
            f.flush()
            os.fsync(f.fileno())
        os.replace(ltmp, self.log_path)
        self._open_fd()
        self._gen = new_gen
        self._valid_end = self._file_size = LOG_HEADER_SIZE + len(tail)
        self.bytes_written += len(tail)
        self._pending_syncs = 0
        self.compact_wanted = False
        if gen:
            try:
                os.unlink(self._snap_path(gen))
            except OSError:
                pass
        return True

    def invalidate(self) -> None:
        """Drop the materialized snapshot (a transaction body raised after
        mutating it): the next load replays from disk."""
        self._state = None

    def close(self) -> None:
        if self._fd is not None:
            self.sync()
            os.close(self._fd)
            self._fd = None
        self._state = None  # a reused handle reloads (and reopens) cleanly


class _SnapshotShard:
    """The PR-4 engine: whole-shard pickle per transaction, per-shard seq
    file appended under the flock.  O(shard size) per op — kept only so the
    microbench can price the log engine against it (``engine="snapshot"``)."""

    def __init__(self, root: str, sidx: int, *, fsync_batch_n: int) -> None:
        self.data_path = os.path.join(root, f"shard-{sidx}.pkl")
        self.seq_path = os.path.join(root, f"shard-{sidx}.seq")
        self._fsync_batch_n = fsync_batch_n
        self._snap: Optional[Tuple[int, Dict[str, Any]]] = None
        self._pending_syncs = 0
        self.bytes_written = 0  # real bytes this process wrote to disk (bench metric)

    @property
    def watch_path(self) -> str:
        return self.seq_path

    def load(self) -> Dict[str, Any]:
        try:
            size = os.path.getsize(self.seq_path)
        except OSError:
            size = 0
        if self._snap is not None and self._snap[0] == size:
            return self._snap[1]
        try:
            with open(self.data_path, "rb") as f:
                state = pickle.load(f)
        except (OSError, EOFError):
            state = {}
        self._snap = (size, state)
        return state

    def load_fast(self) -> Dict[str, Any]:
        return self.load()  # snapshot engine: no exclusive fast path

    def commit(self, state: Dict[str, Any], records: List[tuple], mode: str) -> None:
        tmp = f"{self.data_path}.tmp.{os.getpid()}.{threading.get_ident()}"
        self._pending_syncs += 1
        durable = mode == _SYNC or (
            mode == _LAZY and self._pending_syncs >= self._fsync_batch_n
        )
        with open(tmp, "wb") as f:
            pickle.dump(state, f, protocol=pickle.HIGHEST_PROTOCOL)
            if durable:
                f.flush()
                os.fsync(f.fileno())
                self._pending_syncs = 0
            self.bytes_written += f.tell() + 1  # whole snapshot + the seq byte
        os.replace(tmp, self.data_path)
        fd = os.open(self.seq_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, b"x")
        finally:
            os.close(fd)
        try:
            size = os.path.getsize(self.seq_path)
        except OSError:
            size = 0
        self._snap = (size, state)

    def sync(self) -> None:
        self._pending_syncs = 0

    def invalidate(self) -> None:
        self._snap = None

    def close(self) -> None:
        pass


class FileKVStore(KVStore):
    """Sharded KV store over a shared directory (cross-process Redis model).

    Same public API and notification contract as :class:`KVStore`; see the
    module docstring for the log-structured substrate and the durability
    policy.  Construct one handle per process over the same ``root`` — all
    handles see one keyspace and wake each other's waiters."""

    def __init__(
        self,
        root: str,
        num_shards: int = 1,
        profile: StorageProfile = REDIS_2017,
        ledger: Optional[Ledger] = None,
        *,
        engine: str = "log",
        fsync: str = "auto",
        durable_prefixes: Tuple[str, ...] = ("sched/",),
        fsync_batch_n: int = 64,
        compact_min_bytes: int = 64 * 1024,
        compact_ratio: float = 4.0,
        compaction: str = "thread",
        exclusive: bool = False,
        charged: bool = True,
    ) -> None:
        if engine not in ("log", "snapshot"):
            raise ValueError(f"engine must be 'log' or 'snapshot', got {engine!r}")
        if fsync == "always":
            fsync = "commit"  # FileBackend's name for the same policy
        if fsync not in ("auto", "commit", "batch", "never"):
            raise ValueError(f"unknown fsync policy {fsync!r}")
        if compaction not in ("thread", "inline"):
            raise ValueError(f"compaction must be 'thread' or 'inline', got {compaction!r}")
        super().__init__(
            num_shards=num_shards, profile=profile, ledger=ledger, charged=charged
        )
        self.root = os.path.abspath(root)
        self.engine = engine
        self.fsync = fsync
        # Exclusive mode: this handle is the directory's SOLE writer and
        # reader (the repro-kvd server owning its data dir, like Redis its
        # AOF).  Transactions then skip the cross-process flock and the
        # per-op stat validation — shard thread locks and the materialized
        # state are authoritative — which is where the wire tier's speed
        # over the shared-disk substrate comes from.  Crash safety is
        # unchanged: every commit is still one framed append.
        self.exclusive = exclusive
        self.durable_prefixes = tuple(durable_prefixes)
        os.makedirs(self.root, exist_ok=True)
        if engine == "log":
            self._engines = [
                _LogShard(
                    self.root,
                    i,
                    compact_min_bytes=compact_min_bytes,
                    compact_ratio=compact_ratio,
                    fsync_batch_n=fsync_batch_n,
                )
                for i in range(num_shards)
            ]
        else:
            self._engines = [
                _SnapshotShard(self.root, i, fsync_batch_n=fsync_batch_n)
                for i in range(num_shards)
            ]
        self._lock_fds: List[Optional[int]] = [None] * num_shards
        self._fd_guard = threading.Lock()
        self._watcher: Optional[_PollWatcher] = None
        self._watch_guard = threading.Lock()
        # Off-thread compaction: flagged shards queue here; one lazy daemon
        # thread per store drains the queue (see _LogShard.plan_compaction).
        self.compaction = compaction
        self._compact_pending: set = set()
        self._compact_cond = threading.Condition()
        self._compactor: Optional[threading.Thread] = None
        self._compact_busy = False
        self._closing = False

    def _endpoint_spec(self):
        # Cross-process pickling: a closure capturing this handle reconnects
        # over the same directory in a foreign process (one shared handle per
        # (kind, root) there — see object_store._Endpoint), which is what
        # lets an adopting driver's workers run a dead driver's registered
        # task functions.
        return {
            "kind": "file_kv",
            "root": self.root,
            "num_shards": self.num_shards,
            "engine": self.engine,
            "fsync": self.fsync,
        }

    # ---- durability policy ----------------------------------------------
    def _commit_mode(self, records: List[tuple]) -> str:
        if self.fsync == "commit":
            return _SYNC
        if self.fsync == "never":
            return _NONE
        if self.fsync == "batch":
            return _LAZY
        # auto: control keys fsync per commit, data-plane keys batch
        for _op, key, _val in records:
            if key.startswith(self.durable_prefixes):
                return _SYNC
        return _LAZY

    # ---- locks -----------------------------------------------------------
    def _lock_fd(self, sidx: int) -> int:
        fd = self._lock_fds[sidx]
        if fd is None:
            with self._fd_guard:
                fd = self._lock_fds[sidx]
                if fd is None:
                    fd = os.open(
                        os.path.join(self.root, f"shard-{sidx}.lock"),
                        os.O_WRONLY | os.O_CREAT,
                        0o644,
                    )
                    self._lock_fds[sidx] = fd
        return fd

    # ---- transactions ----------------------------------------------------
    def _txn(self, sidx: int):
        """Context manager: shard thread lock + cross-process flock around a
        load → mutate → (append frame if dirty) → in-process notify cycle."""
        store = self

        class _Ctx:
            def __enter__(self) -> _Txn:
                self._sh = store._shards[sidx]
                self._sh.lock.acquire()
                eng = store._engines[sidx]
                if store.exclusive:
                    # Sole-owner fast path: no flock, no stat — the shard
                    # thread lock is the whole mutual exclusion.
                    try:
                        self._txn = _Txn(eng.load_fast())
                    except BaseException:
                        self._sh.lock.release()
                        raise
                    return self._txn
                fd = store._lock_fd(sidx)
                # reprolint: disable=LOCK001(thread-lock-then-flock is the txn protocol's fixed lock order; every shard txn takes both)
                fcntl.flock(fd, fcntl.LOCK_EX)
                try:
                    self._txn = _Txn(eng.load())
                except BaseException:
                    fcntl.flock(fd, fcntl.LOCK_UN)
                    self._sh.lock.release()
                    raise
                return self._txn

            def __exit__(self, *exc) -> bool:
                eng = store._engines[sidx]
                dirty = bool(self._txn.records)
                committed = False
                try:
                    if exc[0] is None and dirty:
                        try:
                            eng.commit(
                                self._txn.state,
                                self._txn.records,
                                store._commit_mode(self._txn.records),
                            )
                            committed = True
                            if getattr(eng, "compact_wanted", False):
                                if store.compaction == "inline":
                                    # Still under the flock: safe to rewrite.
                                    eng._compact(self._txn.state)
                                else:
                                    store._request_compact(sidx)
                        except BaseException:
                            # The append failed (unpicklable value, ENOSPC,
                            # …): the materialized state was already mutated
                            # and now diverges from disk — drop it, or every
                            # later read in this process would return the
                            # phantom write no other process can see.
                            eng.invalidate()
                            raise
                    elif dirty:
                        # The body raised after mutating the materialized
                        # state: it no longer matches disk — drop it.
                        eng.invalidate()
                finally:
                    if not store.exclusive:
                        fcntl.flock(store._lock_fd(sidx), fcntl.LOCK_UN)
                    if committed:
                        # Keyed wake: the frame's records name exactly the
                        # keys this commit touched.
                        self._sh.touch({k for _op, k, _v in self._txn.records})
                    self._sh.lock.release()
                return False

        return _Ctx()

    # ---- cross-process watch --------------------------------------------
    def _ensure_watcher(self) -> _PollWatcher:
        with self._watch_guard:
            if self._watcher is None:
                paths = [eng.watch_path for eng in self._engines]

                def _on_change(changed: List[int]) -> None:
                    for sidx in changed:
                        sh = self._shards[sidx]
                        with sh.lock:
                            sh.touch()

                self._watcher = _PollWatcher(paths, _on_change)
            return self._watcher

    # ---- off-thread compaction ------------------------------------------
    def _request_compact(self, sidx: int) -> None:
        """Queue a shard for the compactor thread (idempotent: a shard is
        queued at most once; requests while it runs re-queue it)."""
        with self._compact_cond:
            if self._closing:
                return
            self._compact_pending.add(sidx)
            if self._compactor is None:
                self._compactor = threading.Thread(
                    target=self._compact_loop, name="filekv-compactor", daemon=True
                )
                self._compactor.start()
            self._compact_cond.notify_all()

    def _compact_loop(self) -> None:
        while True:
            with self._compact_cond:
                while not self._compact_pending and not self._closing:
                    self._compact_cond.wait()
                if not self._compact_pending:  # closing and drained
                    return
                sidx = self._compact_pending.pop()
                self._compact_busy = True
            try:
                self._compact_shard(sidx)
            except Exception:
                # A failed rewrite must never kill the compactor: the flag
                # re-queues the shard at its next threshold-crossing commit.
                self._engines[sidx].invalidate()
            finally:
                with self._compact_cond:
                    self._compact_busy = False
                    self._compact_cond.notify_all()

    def _compact_shard(self, sidx: int) -> None:
        eng = self._engines[sidx]
        plan = eng.plan_compaction()  # phase A: no locks
        if plan is None:
            # Nothing to fold (or a peer got there first): drop the flag so
            # sub-threshold commits stop re-queueing the shard.
            eng.compact_wanted = False
            return
        with self._txn(sidx):  # phase B: under the normal shard transaction
            eng.finish_compaction(plan)

    def compact_now(self, timeout_s: float = 30.0) -> None:
        """Drain the compactor: block until every queued request has run
        (durability/test barrier — commits flag shards asynchronously, so a
        size assertion needs this fence first)."""
        for sidx, eng in enumerate(self._engines):
            if getattr(eng, "compact_wanted", False):
                self._request_compact(sidx)
        deadline = time.monotonic() + timeout_s
        with self._compact_cond:
            while self._compact_pending or self._compact_busy:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("compaction drain timed out")
                self._compact_cond.wait(remaining)

    def _stop_compactor(self) -> None:
        with self._compact_cond:
            self._closing = True
            self._compact_cond.notify_all()
            thread = self._compactor
        if thread is not None:
            thread.join(timeout=30.0)
        with self._compact_cond:
            self._compactor = None
            self._closing = False  # a reused handle may compact again

    def disk_bytes_written(self) -> int:
        """Real bytes this handle wrote to disk (logs + snapshots, or
        whole-shard pickles for the snapshot engine).  The deterministic
        half of the engine comparison: wall time varies with the host's
        I/O weather, write volume does not."""
        return sum(eng.bytes_written for eng in self._engines)

    def sync(self) -> None:
        """Flush every shard's pending lazy fsyncs (durability barrier)."""
        for sidx in range(self.num_shards):
            sh = self._shards[sidx]
            with sh.lock:
                if self.exclusive:
                    self._engines[sidx].sync()
                    continue
                fd = self._lock_fd(sidx)
                # reprolint: disable=LOCK001(durability barrier takes the same thread-lock-then-flock order as _txn)
                fcntl.flock(fd, fcntl.LOCK_EX)
                try:
                    self._engines[sidx].sync()
                finally:
                    fcntl.flock(fd, fcntl.LOCK_UN)

    def close(self) -> None:
        """Drain the compactor, stop the watch thread, flush lazy fsyncs,
        release fds (tests)."""
        self._stop_compactor()
        with self._watch_guard:
            if self._watcher is not None:
                self._watcher.close()
                self._watcher = None
        for eng in self._engines:
            eng.close()
        with self._fd_guard:
            for i, fd in enumerate(self._lock_fds):
                if fd is not None:
                    os.close(fd)
                    self._lock_fds[i] = None

    def wait_key(self, key: str, last_seq: int, timeout_s: float) -> int:
        """Blocking shard watch, cross-process: while registered, the
        watcher converts foreign log growth into shard-condition
        broadcasts, so the inherited condition wait needs no tick."""
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            return super().wait_key(key, last_seq, timeout_s)
        finally:
            watcher.remove_waiter()

    # ---- atomic single-key ops ------------------------------------------
    def set(self, key: str, value: Any, *, worker: str = "-") -> None:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            t.put(key, value)
            self._charge(self._shards[sidx], worker, "set", key, _sizeof(value), write=True)

    def get(self, key: str, default: Any = None, *, worker: str = "-") -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            value = t.state.get(key, default)
            self._charge(self._shards[sidx], worker, "get", key, _sizeof(value), write=False)
            return value

    def mget(
        self, keys: List[str], default: Any = None, *, worker: str = "-"
    ) -> List[Any]:
        by_shard: Dict[int, List[int]] = {}
        for i, key in enumerate(keys):
            by_shard.setdefault(self.shard_of(key), []).append(i)
        out: List[Any] = [default] * len(keys)
        for sidx, positions in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for i in positions:
                    value = t.state.get(keys[i], default)
                    out[i] = value
                    nbytes += _sizeof(value)
                self._charge(
                    self._shards[sidx], worker, "mget",
                    f"[{len(positions)} keys@s{sidx}]", nbytes, write=False,
                )
        return out

    def mset(self, mapping: Dict[str, Any], *, worker: str = "-") -> None:
        by_shard: Dict[int, List[str]] = {}
        for key in mapping:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    t.put(key, mapping[key])
                    nbytes += _sizeof(mapping[key])
                self._charge(
                    self._shards[sidx], worker, "mset",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )

    def setnx(self, key: str, value: Any, *, worker: str = "-") -> bool:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "setnx", key, _sizeof(value), write=True)
            if key in t.state:
                return False
            t.put(key, value)
            return True

    def incr(self, key: str, amount: float = 1, *, worker: str = "-") -> float:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            new = t.state.get(key, 0) + amount
            t.put(key, new)
            self._charge(self._shards[sidx], worker, "incr", key, 8, write=True)
            return new

    def cas(self, key: str, expect: Any, value: Any, *, worker: str = "-") -> bool:
        sentinel = object()
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "cas", key, _sizeof(value), write=True)
            cur = t.state.get(key, sentinel)
            matched = (cur is not sentinel and cur == expect) or (
                cur is sentinel and expect is None
            )
            if matched:
                t.put(key, value)
                return True
            return False

    def delete(self, key: str, *, worker: str = "-") -> None:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            t.drop(key)
            self._charge(self._shards[sidx], worker, "del", key, 0, write=True)

    def mdel(self, keys: List[str], *, worker: str = "-") -> int:
        by_shard: Dict[int, List[str]] = {}
        for key in keys:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        removed = 0
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                for key in group:
                    if t.drop(key):
                        removed += 1
                self._charge(
                    self._shards[sidx], worker, "mdel",
                    f"[{len(group)} keys@s{sidx}]", 0, write=True,
                )
        return removed

    def exists(self, key: str, *, worker: str = "-") -> bool:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "exists", key, 0, write=False)
            return key in t.state

    def scan(self, prefix: str, *, worker: str = "-") -> List[str]:
        out: List[str] = []
        for sidx in range(self.num_shards):
            with self._txn(sidx) as t:
                found = [k for k in t.state if k.startswith(prefix)]
                self._charge(
                    self._shards[sidx], worker, "scan", f"[{prefix}*@s{sidx}]",
                    sum(len(k.encode()) for k in found), write=False,
                )
                out.extend(found)
        return sorted(out)

    # ---- server-side scripting ------------------------------------------
    def eval(
        self,
        key: str,
        fn: Callable[[Any], Any],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            new = fn(t.state.get(key, default))
            if new is DELETE:
                t.drop(key)
                self._charge(self._shards[sidx], worker, "eval", key, 0, write=True)
                return None
            t.put(key, new)
            self._charge(self._shards[sidx], worker, "eval", key, _sizeof(new), write=True)
            return new

    def eval_many(
        self,
        updates: Dict[str, Callable[[Any], Any]],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Dict[str, Any]:
        by_shard: Dict[int, List[str]] = {}
        for key in updates:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        out: Dict[str, Any] = {}
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    new = updates[key](t.state.get(key, default))
                    if new is DELETE:
                        t.drop(key)
                        out[key] = None
                        continue
                    t.put(key, new)
                    out[key] = new
                    nbytes += _sizeof(new)
                self._charge(
                    self._shards[sidx], worker, "meval",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )
        return out

    # ---- lists (queues) --------------------------------------------------
    def rpush(self, key: str, *values: Any, worker: str = "-") -> int:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            lst = t.extend(key, list(values))
            self._charge(
                self._shards[sidx], worker, "rpush", key,
                sum(_sizeof(v) for v in values), write=True,
            )
            return len(lst)

    def rpush_many(
        self, pushes: Dict[str, List[Any]], *, worker: str = "-"
    ) -> Dict[str, int]:
        by_shard: Dict[int, List[str]] = {}
        for key in pushes:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        lengths: Dict[str, int] = {}
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    values = pushes[key]
                    lst = t.extend(key, list(values))
                    lengths[key] = len(lst)
                    nbytes += sum(_sizeof(v) for v in values)
                self._charge(
                    self._shards[sidx], worker, "mrpush",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )
        return lengths

    def lpop(self, key: str, *, worker: str = "-") -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            popped = t.popleft(key)
            value = None if popped is _MISS else popped
            self._charge(self._shards[sidx], worker, "lpop", key, _sizeof(value), write=True)
            return value

    def lpop_n(self, key: str, max_n: int, *, worker: str = "-") -> List[Any]:
        """Batched left pop: one flock transaction, one framed ``("p", key,
        n)`` record — a worker leasing a batch pays one disk append."""
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            out = t.popleft_n(key, max_n)
            self._charge(
                self._shards[sidx], worker, "lpopn", key,
                sum(_sizeof(v) for v in out), write=True,
            )
            return out

    def blpop(self, key: str, timeout_s: float, *, worker: str = "-") -> Any:
        """Blocking left pop across processes.  The flock is held only for
        each pop *attempt*, never across the wait — otherwise a waiting
        consumer would lock every producer out of the shard.  Between
        attempts the consumer blocks on the shard condition; a local push
        notifies it directly, a remote push grows the shard log and the
        watcher relays the notify."""
        deadline = time.monotonic() + timeout_s
        sidx = self.shard_of(key)
        sh = self._shards[sidx]
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            while True:
                with self._txn(sidx) as t:
                    popped = t.popleft(key)
                    if popped is not _MISS:
                        # a stored None is a real element: pop and return it
                        self._charge(sh, worker, "blpop", key, _sizeof(popped), write=True)
                        return popped
                    seq = sh.seq
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                with sh.lock:
                    if sh.seq == seq:
                        sh.cond.wait(remaining)
        finally:
            watcher.remove_waiter()

    def lrange(self, key: str, start: int = 0, stop: int = -1, *, worker: str = "-") -> List[Any]:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            lst = list(t.state.get(key, []))
            out = lst[start:] if stop == -1 else lst[start : stop + 1]
            self._charge(
                self._shards[sidx], worker, "lrange", key,
                sum(_sizeof(v) for v in out), write=False,
            )
            return out

    def llen(self, key: str, *, worker: str = "-") -> int:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "llen", key, 8, write=False)
            return len(t.state.get(key, []))
