"""Cross-process KV store over a shared directory.

The in-memory :class:`~repro.storage.kv_store.KVStore` models ElastiCache
for a single driver process.  A *multi-process* driver — the paper's "N
concurrent drivers are as elastic as the workers" end state — needs the
same Redis semantics reachable from every process, so this module gives the
KV a file substrate with the same public API and the same per-shard
accounting:

  * **per-shard state files** — each shard is one pickled dict
    (``shard-N.pkl``), rewritten atomically (temp + ``os.replace``) on
    every write transaction.  Control-plane state (queues of task specs,
    lease records, counters) is small, so whole-shard rewrite is the
    simplest correct granularity;
  * **cross-process atomicity** — every operation is a transaction under
    the shard's ``flock`` (``shard-N.lock``): load state, apply, store.
    The in-process shard lock is taken first (threads serialize on it; a
    single ``flock`` fd is per open-file-description, not per thread), the
    file lock second (processes serialize on it).  ``eval`` therefore keeps
    its server-side-scripting guarantee across processes: the update
    function runs while the shard is locked machine-wide;
  * **per-shard seq files** — each write transaction appends one byte to
    ``shard-N.seq`` *while still holding the flock*; the file's size is the
    shard's cross-process write sequence.  A waiter-gated
    :class:`~repro.storage.object_store._PollWatcher` (same exponential-
    backoff design as ``FileBackend``'s) stats the seq files and converts a
    foreign process's writes into this process's shard-condition
    broadcasts, so ``blpop``/``wait_key`` block event-driven across
    processes — a worker pool in process B wakes on a queue push from
    process A without any fallback tick;
  * **snapshot cache** — the shard state is cached per process keyed by
    seq-file size: a transaction that finds the size unchanged reuses the
    cached dict instead of re-unpickling, so a busy single process pays
    pickling only when another process actually wrote.

Durability note: shard files are replaced atomically but *not* fsynced —
the KV is the coordination plane (leases, queues, counters), all of it
reconstructible or re-drivable after a crash, unlike the object store's
checkpoint writes which do fsync.

Virtual-time charging is identical to the in-memory KV (same op names,
same per-shard amortization), so benchmarks and ledgers compare directly.
"""

from __future__ import annotations

import fcntl
import os
import pickle
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from .kv_store import DELETE, KVStore, _sizeof
from .object_store import Ledger, _PollWatcher
from .perf_model import REDIS_2017, StorageProfile


class _Txn:
    """One shard transaction: mutate ``state`` and set ``dirty`` to flush."""

    __slots__ = ("state", "dirty")

    def __init__(self, state: Dict[str, Any]) -> None:
        self.state = state
        self.dirty = False


class FileKVStore(KVStore):
    """Sharded KV store over a shared directory (cross-process Redis model).

    Same public API and notification contract as :class:`KVStore`; see the
    module docstring for the substrate.  Construct one handle per process
    over the same ``root`` — all handles see one keyspace and wake each
    other's waiters."""

    def __init__(
        self,
        root: str,
        num_shards: int = 1,
        profile: StorageProfile = REDIS_2017,
        ledger: Optional[Ledger] = None,
    ) -> None:
        super().__init__(num_shards=num_shards, profile=profile, ledger=ledger)
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock_fds: List[Optional[int]] = [None] * num_shards
        self._fd_guard = threading.Lock()
        # per-shard (seq_file_size, state_dict) snapshot, valid under flock
        self._snap: List[Optional[tuple]] = [None] * num_shards
        self._watcher: Optional[_PollWatcher] = None
        self._watch_guard = threading.Lock()

    # ---- files -----------------------------------------------------------
    def _data_path(self, sidx: int) -> str:
        return os.path.join(self.root, f"shard-{sidx}.pkl")

    def _seq_path(self, sidx: int) -> str:
        return os.path.join(self.root, f"shard-{sidx}.seq")

    def _lock_fd(self, sidx: int) -> int:
        fd = self._lock_fds[sidx]
        if fd is None:
            with self._fd_guard:
                fd = self._lock_fds[sidx]
                if fd is None:
                    fd = os.open(
                        os.path.join(self.root, f"shard-{sidx}.lock"),
                        os.O_WRONLY | os.O_CREAT,
                        0o644,
                    )
                    self._lock_fds[sidx] = fd
        return fd

    # ---- transactions ----------------------------------------------------
    def _load(self, sidx: int) -> Dict[str, Any]:
        """Load shard state (must hold the flock).  Reuses the process-local
        snapshot when the seq file hasn't grown since it was taken."""
        try:
            size = os.path.getsize(self._seq_path(sidx))
        except OSError:
            size = 0
        snap = self._snap[sidx]
        if snap is not None and snap[0] == size:
            return snap[1]
        try:
            with open(self._data_path(sidx), "rb") as f:
                state = pickle.load(f)
        except (OSError, EOFError):
            state = {}
        self._snap[sidx] = (size, state)
        return state

    def _flush(self, sidx: int, state: Dict[str, Any]) -> None:
        """Store shard state and advance the cross-process sequence (must
        hold the flock).  State lands via atomic replace *before* the seq
        byte is appended, so a remote reader woken by the seq growth always
        sees the new state."""
        path = self._data_path(sidx)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            pickle.dump(state, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
        fd = os.open(self._seq_path(sidx), os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, b"x")
        finally:
            os.close(fd)
        try:
            size = os.path.getsize(self._seq_path(sidx))
        except OSError:
            size = 0
        self._snap[sidx] = (size, state)

    def _txn(self, sidx: int):
        """Context manager: shard thread lock + cross-process flock around a
        load → mutate → (flush if dirty) → in-process notify cycle."""
        store = self

        class _Ctx:
            def __enter__(self) -> _Txn:
                self._sh = store._shards[sidx]
                self._sh.lock.acquire()
                fd = store._lock_fd(sidx)
                fcntl.flock(fd, fcntl.LOCK_EX)
                self._txn = _Txn(store._load(sidx))
                return self._txn

            def __exit__(self, *exc) -> bool:
                try:
                    if exc[0] is None and self._txn.dirty:
                        store._flush(sidx, self._txn.state)
                finally:
                    fcntl.flock(store._lock_fd(sidx), fcntl.LOCK_UN)
                    if exc[0] is None and self._txn.dirty:
                        self._sh.touch()  # wake this process's waiters
                    self._sh.lock.release()
                return False

        return _Ctx()

    # ---- cross-process watch --------------------------------------------
    def _ensure_watcher(self) -> _PollWatcher:
        with self._watch_guard:
            if self._watcher is None:
                paths = [self._seq_path(i) for i in range(self.num_shards)]

                def _on_change(changed: List[int]) -> None:
                    for sidx in changed:
                        sh = self._shards[sidx]
                        with sh.lock:
                            sh.touch()

                self._watcher = _PollWatcher(paths, _on_change)
            return self._watcher

    def close(self) -> None:
        """Stop the watch thread and release lock fds (tests)."""
        with self._watch_guard:
            if self._watcher is not None:
                self._watcher.close()
                self._watcher = None
        with self._fd_guard:
            for i, fd in enumerate(self._lock_fds):
                if fd is not None:
                    os.close(fd)
                    self._lock_fds[i] = None

    def wait_key(self, key: str, last_seq: int, timeout_s: float) -> int:
        """Blocking shard watch, cross-process: while registered, the
        watcher converts foreign seq-file growth into shard-condition
        broadcasts, so the inherited condition wait needs no tick."""
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            return super().wait_key(key, last_seq, timeout_s)
        finally:
            watcher.remove_waiter()

    # ---- atomic single-key ops ------------------------------------------
    def set(self, key: str, value: Any, *, worker: str = "-") -> None:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            t.state[key] = value
            t.dirty = True
            self._charge(self._shards[sidx], worker, "set", key, _sizeof(value), write=True)

    def get(self, key: str, default: Any = None, *, worker: str = "-") -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            value = t.state.get(key, default)
            self._charge(self._shards[sidx], worker, "get", key, _sizeof(value), write=False)
            return value

    def mget(
        self, keys: List[str], default: Any = None, *, worker: str = "-"
    ) -> List[Any]:
        by_shard: Dict[int, List[int]] = {}
        for i, key in enumerate(keys):
            by_shard.setdefault(self.shard_of(key), []).append(i)
        out: List[Any] = [default] * len(keys)
        for sidx, positions in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for i in positions:
                    value = t.state.get(keys[i], default)
                    out[i] = value
                    nbytes += _sizeof(value)
                self._charge(
                    self._shards[sidx], worker, "mget",
                    f"[{len(positions)} keys@s{sidx}]", nbytes, write=False,
                )
        return out

    def mset(self, mapping: Dict[str, Any], *, worker: str = "-") -> None:
        by_shard: Dict[int, List[str]] = {}
        for key in mapping:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    t.state[key] = mapping[key]
                    nbytes += _sizeof(mapping[key])
                t.dirty = True
                self._charge(
                    self._shards[sidx], worker, "mset",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )

    def setnx(self, key: str, value: Any, *, worker: str = "-") -> bool:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "setnx", key, _sizeof(value), write=True)
            if key in t.state:
                return False
            t.state[key] = value
            t.dirty = True
            return True

    def incr(self, key: str, amount: float = 1, *, worker: str = "-") -> float:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            new = t.state.get(key, 0) + amount
            t.state[key] = new
            t.dirty = True
            self._charge(self._shards[sidx], worker, "incr", key, 8, write=True)
            return new

    def cas(self, key: str, expect: Any, value: Any, *, worker: str = "-") -> bool:
        sentinel = object()
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "cas", key, _sizeof(value), write=True)
            cur = t.state.get(key, sentinel)
            matched = (cur is not sentinel and cur == expect) or (
                cur is sentinel and expect is None
            )
            if matched:
                t.state[key] = value
                t.dirty = True
                return True
            return False

    def delete(self, key: str, *, worker: str = "-") -> None:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            t.state.pop(key, None)
            t.dirty = True
            self._charge(self._shards[sidx], worker, "del", key, 0, write=True)

    def mdel(self, keys: List[str], *, worker: str = "-") -> int:
        by_shard: Dict[int, List[str]] = {}
        for key in keys:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        removed = 0
        sentinel = object()
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                for key in group:
                    if t.state.pop(key, sentinel) is not sentinel:
                        removed += 1
                t.dirty = True
                self._charge(
                    self._shards[sidx], worker, "mdel",
                    f"[{len(group)} keys@s{sidx}]", 0, write=True,
                )
        return removed

    def exists(self, key: str, *, worker: str = "-") -> bool:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "exists", key, 0, write=False)
            return key in t.state

    def scan(self, prefix: str, *, worker: str = "-") -> List[str]:
        out: List[str] = []
        for sidx in range(self.num_shards):
            with self._txn(sidx) as t:
                found = [k for k in t.state if k.startswith(prefix)]
                self._charge(
                    self._shards[sidx], worker, "scan", f"[{prefix}*@s{sidx}]",
                    sum(len(k.encode()) for k in found), write=False,
                )
                out.extend(found)
        return sorted(out)

    # ---- server-side scripting ------------------------------------------
    def eval(
        self,
        key: str,
        fn: Callable[[Any], Any],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            new = fn(t.state.get(key, default))
            if new is DELETE:
                t.state.pop(key, None)
                t.dirty = True
                self._charge(self._shards[sidx], worker, "eval", key, 0, write=True)
                return None
            t.state[key] = new
            t.dirty = True
            self._charge(self._shards[sidx], worker, "eval", key, _sizeof(new), write=True)
            return new

    def eval_many(
        self,
        updates: Dict[str, Callable[[Any], Any]],
        *,
        default: Any = None,
        worker: str = "-",
    ) -> Dict[str, Any]:
        by_shard: Dict[int, List[str]] = {}
        for key in updates:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        out: Dict[str, Any] = {}
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    new = updates[key](t.state.get(key, default))
                    if new is DELETE:
                        t.state.pop(key, None)
                        out[key] = None
                        continue
                    t.state[key] = new
                    out[key] = new
                    nbytes += _sizeof(new)
                t.dirty = True
                self._charge(
                    self._shards[sidx], worker, "meval",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )
        return out

    # ---- lists (queues) --------------------------------------------------
    def rpush(self, key: str, *values: Any, worker: str = "-") -> int:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            lst = t.state.setdefault(key, [])
            lst.extend(values)
            t.dirty = True
            self._charge(
                self._shards[sidx], worker, "rpush", key,
                sum(_sizeof(v) for v in values), write=True,
            )
            return len(lst)

    def rpush_many(
        self, pushes: Dict[str, List[Any]], *, worker: str = "-"
    ) -> Dict[str, int]:
        by_shard: Dict[int, List[str]] = {}
        for key in pushes:
            by_shard.setdefault(self.shard_of(key), []).append(key)
        lengths: Dict[str, int] = {}
        for sidx, group in by_shard.items():
            with self._txn(sidx) as t:
                nbytes = 0
                for key in group:
                    values = pushes[key]
                    lst = t.state.setdefault(key, [])
                    lst.extend(values)
                    lengths[key] = len(lst)
                    nbytes += sum(_sizeof(v) for v in values)
                t.dirty = True
                self._charge(
                    self._shards[sidx], worker, "mrpush",
                    f"[{len(group)} keys@s{sidx}]", nbytes, write=True,
                )
        return lengths

    def lpop(self, key: str, *, worker: str = "-") -> Any:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            lst = t.state.get(key)
            value = lst.pop(0) if lst else None
            if value is not None:
                t.dirty = True
            self._charge(self._shards[sidx], worker, "lpop", key, _sizeof(value), write=True)
            return value

    def blpop(self, key: str, timeout_s: float, *, worker: str = "-") -> Any:
        """Blocking left pop across processes.  The flock is held only for
        each pop *attempt*, never across the wait — otherwise a waiting
        consumer would lock every producer out of the shard.  Between
        attempts the consumer blocks on the shard condition; a local push
        notifies it directly, a remote push grows the seq file and the
        watcher relays the notify."""
        deadline = time.monotonic() + timeout_s
        sidx = self.shard_of(key)
        sh = self._shards[sidx]
        watcher = self._ensure_watcher()
        watcher.add_waiter()
        try:
            while True:
                with self._txn(sidx) as t:
                    lst = t.state.get(key)
                    if lst:
                        value = lst.pop(0)
                        t.dirty = True
                        self._charge(sh, worker, "blpop", key, _sizeof(value), write=True)
                        return value
                    seq = sh.seq
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                with sh.lock:
                    if sh.seq == seq:
                        sh.cond.wait(remaining)
        finally:
            watcher.remove_waiter()

    def lrange(self, key: str, start: int = 0, stop: int = -1, *, worker: str = "-") -> List[Any]:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            lst = list(t.state.get(key, []))
            out = lst[start:] if stop == -1 else lst[start : stop + 1]
            self._charge(
                self._shards[sidx], worker, "lrange", key,
                sum(_sizeof(v) for v in out), write=False,
            )
            return out

    def llen(self, key: str, *, worker: str = "-") -> int:
        sidx = self.shard_of(key)
        with self._txn(sidx) as t:
            self._charge(self._shards[sidx], worker, "llen", key, 8, write=False)
            return len(t.state.get(key, []))
