"""Common neural-net layers (pure-functional, pytree params).

Everything takes/returns plain jnp arrays; params are nested dicts of
arrays.  Initializers return (params, apply) separation is avoided — each
layer exposes `init_*` and a pure `*_apply` so layers compose under scan /
remat / shard_map without framework machinery.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


from repro.util import scan_unroll  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, *out_dims: int, dtype=jnp.float32, scale: Optional[float] = None):
    shape = (in_dim, *out_dims)
    fan_out = math.prod(out_dims)
    std = scale if scale is not None else (2.0 / (in_dim + fan_out)) ** 0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * dim**-0.5).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((dim,), dtype)  # stored as offset-from-1 (gemma) or raw


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6, offset: bool = True) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if offset else w.astype(jnp.float32)
    return (xn * scale).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # (..., S, H, D) or (..., H, D) with positions given
    positions: jnp.ndarray,  # broadcastable to (..., S)
    theta: float,
) -> jnp.ndarray:
    """Rotary embedding over the last dim (pairs split as [0:D/2], [D/2:D])."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : D // 2], x[..., D // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    fn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    gate = fn(x @ p["w_gate"])
    return (gate * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None or cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def causal_conv1d(
    x: jnp.ndarray,  # (B, S, C)
    kernel: jnp.ndarray,  # (K, C) depthwise
    bias: Optional[jnp.ndarray] = None,
    state: Optional[jnp.ndarray] = None,  # (B, K-1, C) left context (decode)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv; returns (y, new_state)."""
    K = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    # cache states may live in a different dtype (fp32 cache, bf16 compute);
    # concat must not promote the activation dtype
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    # depthwise conv as sum of shifted scalings (K is tiny: 4)
    S = x.shape[1]
    y = sum(xp[:, i : i + S, :] * kernel[i][None, None, :] for i in range(K))
    if bias is not None:
        y = y + bias[None, None, :]
    new_state = xp[:, -(K - 1) :, :] if K > 1 else jnp.zeros_like(state)
    return y, new_state


def grouped_rmsnorm(x: jnp.ndarray, w: jnp.ndarray, n_groups: int, eps: float = 1e-6) -> jnp.ndarray:
    """Per-group RMS norm over the channel dim (xLSTM/Mamba gated norm)."""
    B, S, C = x.shape
    xg = x.reshape(B, S, n_groups, C // n_groups).astype(jnp.float32)
    var = jnp.mean(xg * xg, axis=-1, keepdims=True)
    xn = (xg * jax.lax.rsqrt(var + eps)).reshape(B, S, C)
    return (xn * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
