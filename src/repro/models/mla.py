"""Multi-head Latent Attention (DeepSeek-V2/V3).

KV state is compressed to a per-token latent c_kv (rank 512) plus a shared
decoupled-RoPE key k_pe (64), cutting KV-cache bytes ~14x vs GQA at 128
heads.  Two execution forms:

  * train/prefill: up-project latent to per-head K (nope‖rope, 192) and
    V (128), run flash attention (Dv != Dqk handled by the jnp path);
  * decode: *weight absorption* — fold W_UK into the query so scores are
    taken directly against the latent cache: q_lat = q_nope · W_UK, then
    scores = q_lat·c_kv + q_rope·k_pe; context is accumulated in latent
    space and up-projected once with W_UV.  FLOPs per token drop from
    O(S·H·192) to O(S·(512+64)) on the score side.

Cache sharding: (B, S, r) latent is head-free, so the sequence dim shards
over the model axis (the decode softmax reductions become all-reduces —
flash-decoding via SPMD).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

from .cache_update import write_row, write_segment
from .layers import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init
from .sharding import DP, TP, shard


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk_head = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "q_down": dense_init(ks[0], D, m.q_lora_rank, dtype=dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "q_up": dense_init(ks[1], m.q_lora_rank, H, qk_head, dtype=dtype),
        "kv_down": dense_init(ks[2], D, m.kv_lora_rank + m.rope_head_dim, dtype=dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "kv_up": dense_init(
            ks[3], m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim, dtype=dtype
        ),
        "wo": dense_init(ks[4], H, m.v_head_dim, D, dtype=dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }


def mla_cache_spec() -> Tuple:
    return (DP, TP, None)  # sequence-sharded latent


def _q_heads(p: Params, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray):
    m = cfg.mla
    q_lat = rmsnorm(x @ p["q_down"], p["q_norm"], eps=cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["q_up"])
    q_nope = q[..., : m.nope_head_dim]
    pos_b = positions if positions.ndim == 2 else positions[None, :]
    q_pe = apply_rope(q[..., m.nope_head_dim :], pos_b, cfg.rope_theta)
    return q_nope, q_pe


def _latent(p: Params, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray):
    m = cfg.mla
    kv = x @ p["kv_down"]  # (B, S, r + rope)
    c_kv = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], eps=cfg.rms_eps)
    pos_b = positions if positions.ndim == 2 else positions[None, :]
    k_pe = apply_rope(
        kv[..., m.kv_lora_rank :][:, :, None, :], pos_b, cfg.rope_theta
    )[:, :, 0]  # (B, S, rope)
    return c_kv, k_pe


def mla_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    m = cfg.mla
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    if positions is None:
        positions = jnp.arange(S)

    q_nope, q_pe = _q_heads(p, x, cfg, positions)
    c_kv, k_pe = _latent(p, x, cfg, positions)

    if cache is not None and S == 1:
        # ---- absorbed decode ------------------------------------------
        # latent cache is sequence-sharded: masked write, never DUS
        new_ckv = write_row(cache["c_kv"], c_kv, cache_len, dus_ok=False)
        new_kpe = write_row(cache["k_pe"], k_pe, cache_len, dus_ok=False)
        new_ckv = shard(new_ckv, *mla_cache_spec())
        new_kpe = shard(new_kpe, *mla_cache_spec())

        kv_up_k = p["kv_up"][..., : m.nope_head_dim]  # (r, H, nope)
        kv_up_v = p["kv_up"][..., m.nope_head_dim :]  # (r, H, v)
        q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], kv_up_k)  # (B,H,r)
        q_lat = shard(q_lat, DP, TP, None)

        s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, new_ckv.astype(jnp.float32))
        s_pe = jnp.einsum("bhk,bsk->bhs", q_pe[:, 0], new_kpe.astype(jnp.float32))
        scores = (s_lat + s_pe) * scale  # (B, H, S)
        pos = jnp.arange(new_ckv.shape[1])[None, None, :]
        clen = cache_len
        if jnp.ndim(clen) == 1:
            clen = clen[:, None, None]  # per-slot lengths (continuous batching)
        scores = jnp.where(pos <= clen, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, new_ckv.astype(jnp.float32))
        ctx = jnp.einsum("bhr,rhv->bhv", ctx_lat, kv_up_v.astype(jnp.float32))
        out = jnp.einsum("bhv,hvd->bd", ctx.astype(x.dtype), p["wo"])[:, None]
        return out, {"c_kv": new_ckv, "k_pe": new_kpe}

    # ---- train / prefill: materialize per-head K and V ------------------
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["kv_up"])
    k_nope = kv[..., : m.nope_head_dim]
    v = kv[..., m.nope_head_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (*k_nope.shape[:3], m.rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    q = shard(q, DP, None, TP, None)
    k = shard(k, DP, None, TP, None)
    v = shard(v, DP, None, TP, None)
    out = ops.flash_attention(q, k, v, causal=True, scale=scale)
    out = shard(out, DP, None, TP, None)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])

    new_cache = None
    if cache is not None:
        new_ckv = write_segment(cache["c_kv"], c_kv, cache_len, dus_ok=False)
        new_kpe = write_segment(cache["k_pe"], k_pe, cache_len, dus_ok=False)
        new_cache = {
            "c_kv": shard(new_ckv, *mla_cache_spec()),
            "k_pe": shard(new_kpe, *mla_cache_spec()),
        }
    return y, new_cache
