"""Model facade: init / forward / prefill / decode for every assigned arch.

The batch dict carries family-specific inputs:
  tokens          (B, S_text)  int32  — always present
  prefix_embed    (B, P, D)            — vlm stub (precomputed patch embeds)
  audio_frames    (B, S_enc, D)        — audio stub (precomputed frames)
  labels          (B, S_text)  int32   — train mode (-1 = ignore)

Caches are family-specific pytrees with a shared scalar "len".
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn
from . import mamba2 as mb
from . import mla as mla_mod
from . import transformer as tfm
from . import xlstm as xl
from .layers import Params, dtype_of, embed_init, rmsnorm, rmsnorm_init, softcap
from .sharding import DP, TP, residual_shard, shard

Batch = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": {"tok": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt)},
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], cfg.d_model, cfg.vocab_size, dtype=dt)

    if cfg.pos_embedding == "learned":
        p["embed"]["pos"] = embed_init(ks[2], cfg.max_target_positions, cfg.d_model, dtype=dt)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["decoder"] = tfm.decoder_stage_init(ks[3], cfg, cfg.n_layers, use_moe=False, dtype=dt)
    elif fam == "moe":
        nd = cfg.moe.num_dense_layers
        if nd:
            p["dense_prefix"] = tfm.decoder_stage_init(ks[3], cfg, nd, use_moe=False, dtype=dt)
        p["decoder"] = tfm.decoder_stage_init(ks[4], cfg, cfg.n_layers - nd, use_moe=True, dtype=dt)
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": embed_init(ks[6], 2 * cfg.d_model, cfg.d_model, dtype=dt),
                "block": tfm.decoder_layer_init(ks[7], cfg, use_moe=False, dtype=dt),
                "norm": rmsnorm_init(cfg.d_model, dt),
            }
    elif fam == "hybrid":
        p["decoder"] = tfm.hybrid_stage_init(ks[3], cfg, dtype=dt)
    elif fam == "ssm":
        p["decoder"] = tfm.xlstm_stage_init(ks[3], cfg, dtype=dt)
    elif fam == "encdec":
        p["enc_pos"] = embed_init(ks[5], cfg.encoder_seq, cfg.d_model, dtype=dt)
        p["encoder"] = tfm.encoder_stage_init(ks[3], cfg, dtype=dt)
        p["encoder_norm"] = rmsnorm_init(cfg.d_model, dt)
        p["decoder"] = tfm.xdecoder_stage_init(ks[4], cfg, dtype=dt)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_tokens(p: Params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    h = jnp.take(p["embed"]["tok"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model**0.5, h.dtype)
    return h


def _lm_logits(p: Params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rmsnorm(h, p["final_norm"], eps=cfg.rms_eps)
    w = p["embed"]["tok"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (h @ w).astype(jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    return shard(logits, DP, None, TP)


def _assemble_input(
    p: Params, cfg: ModelConfig, batch: Batch, *, offset: jnp.ndarray | int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden (B,S,D), positions (S,))."""
    tokens = batch["tokens"]
    h = _embed_tokens(p, cfg, tokens)
    if cfg.frontend == "vision_stub" and "prefix_embed" in batch:
        h = jnp.concatenate([batch["prefix_embed"].astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S) + offset
    if cfg.pos_embedding == "learned":
        idx = jnp.minimum(positions, p["embed"]["pos"].shape[0] - 1)
        h = h + jnp.take(p["embed"]["pos"], idx, axis=0)[None]
    h = residual_shard(h)
    return h, positions


# ---------------------------------------------------------------------------
# backbone dispatch
# ---------------------------------------------------------------------------

def _backbone(
    p: Params,
    cfg: ModelConfig,
    h: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    remat: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam in ("dense", "vlm"):
        h, new_cache, aux = tfm.decoder_stage_apply(
            p["decoder"], h, cfg,
            positions=positions, cache=None if cache is None else cache["decoder"],
            cache_len=cache_len, use_moe=False, remat=remat,
        )
        new_cache = None if new_cache is None else {"decoder": new_cache}
    elif fam == "moe":
        new_cache_d = {}
        if "dense_prefix" in p:
            h, nc0, a0 = tfm.decoder_stage_apply(
                p["dense_prefix"], h, cfg,
                positions=positions,
                cache=None if cache is None else cache["dense_prefix"],
                cache_len=cache_len, use_moe=False, remat=remat,
            )
            aux = aux + a0
            if nc0 is not None:
                new_cache_d["dense_prefix"] = nc0
        h, nc1, a1 = tfm.decoder_stage_apply(
            p["decoder"], h, cfg,
            positions=positions,
            cache=None if cache is None else cache["decoder"],
            cache_len=cache_len, use_moe=True, remat=remat,
        )
        aux = aux + a1
        if nc1 is not None:
            new_cache_d["decoder"] = nc1
        new_cache = new_cache_d or None
    elif fam == "hybrid":
        h, new_cache = tfm.hybrid_stage_apply(
            p["decoder"], h, cfg,
            positions=positions, cache=None if cache is None else cache["decoder"],
            cache_len=cache_len, remat=remat,
        )
        new_cache = None if new_cache is None else {"decoder": new_cache}
    elif fam == "ssm":
        h, new_cache = tfm.xlstm_stage_apply(
            p["decoder"], h, cfg,
            cache=None if cache is None else cache["decoder"], remat=remat,
        )
        new_cache = None if new_cache is None else {"decoder": new_cache}
    elif fam == "encdec":
        h, new_cache = tfm.xdecoder_stage_apply(
            p["decoder"], h, cfg,
            enc_out=enc_out, positions=positions,
            cache=None if cache is None else cache["decoder"],
            cache_len=cache_len, remat=remat,
        )
        new_cache = None if new_cache is None else {"decoder": new_cache}
    else:
        raise ValueError(fam)
    return h, new_cache, aux


def _encode(p: Params, cfg: ModelConfig, batch: Batch, *, remat: bool = False) -> jnp.ndarray:
    frames = batch["audio_frames"]  # (B, S_enc, D) — conv frontend stub
    h = frames.astype(dtype_of(cfg.dtype)) + p["enc_pos"][None, : frames.shape[1]]
    h = shard(h, DP, None, None)
    h = tfm.encoder_stage_apply(p["encoder"], h, cfg, remat=remat)
    return rmsnorm(h, p["encoder_norm"], eps=cfg.rms_eps)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _forward_trunk(
    p: Params,
    cfg: ModelConfig,
    batch: Batch,
    *,
    remat: bool = False,
):
    """Shared trunk: returns (h_final (pre-final-norm), aux, h_mtp or None)."""
    enc_out = _encode(p, cfg, batch, remat=remat) if cfg.family == "encdec" else None
    h, positions = _assemble_input(p, cfg, batch)
    h = h.astype(dtype_of(cfg.dtype))
    h, _, aux = _backbone(p, cfg, h, positions, enc_out=enc_out, remat=remat)

    h_mtp = None
    if cfg.mtp_depth and "mtp" in p:
        # DeepSeek MTP: predict t+2 from [h_t ; embed(tok_{t+1})]
        emb_next = _embed_tokens(p, cfg, batch["tokens"])[:, 1:]  # (B, S-1, D)
        h_trunc = h[:, :-1]
        cat = jnp.concatenate([rmsnorm(h_trunc, p["mtp"]["norm"], eps=cfg.rms_eps), emb_next], axis=-1)
        h_mtp = cat @ p["mtp"]["proj"]
        h_mtp, _, _ = tfm.decoder_layer_apply(
            p["mtp"]["block"], h_mtp, cfg,
            window=None, positions=positions[:-1],
            cache=None, cache_len=None, use_moe=False,
        )
    return h, aux, h_mtp


def head_weight(p: Params, cfg: ModelConfig) -> jnp.ndarray:
    """(D, V) output head (tied or separate)."""
    return p["embed"]["tok"].T if cfg.tie_embeddings else p["lm_head"]


def forward(
    p: Params,
    cfg: ModelConfig,
    batch: Batch,
    *,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward (train / eval).  Returns (logits, aux_loss, extras)."""
    h, aux, h_mtp = _forward_trunk(p, cfg, batch, remat=remat)
    logits = _lm_logits(p, cfg, h)
    extras: Dict[str, jnp.ndarray] = {}
    if h_mtp is not None:
        extras["mtp_logits"] = _lm_logits(p, cfg, h_mtp)
    return logits, aux, extras


def forward_hidden(
    p: Params,
    cfg: ModelConfig,
    batch: Batch,
    *,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Forward without the head matmul: returns (h_normed, aux, extras with
    mtp hidden) for fused (chunked-vocab) loss computation."""
    h, aux, h_mtp = _forward_trunk(p, cfg, batch, remat=remat)
    h = rmsnorm(h, p["final_norm"], eps=cfg.rms_eps)
    extras: Dict[str, jnp.ndarray] = {}
    if h_mtp is not None:
        extras["mtp_hidden"] = rmsnorm(h_mtp, p["final_norm"], eps=cfg.rms_eps)
    return h, aux, extras


def init_cache(
    cfg: ModelConfig, batch_size: int, max_len: int, cache_dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """Family-specific stacked cache pytree."""
    fam = cfg.family
    period = cfg.global_every if (cfg.sliding_window and cfg.global_every) else 1

    def kv_stack(n_outer, per=period):
        # layout matches the scanned params: (outer, period, B, S, K, hd)
        one = attn.init_kv_cache(cfg, batch_size, max_len, cache_dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_outer, per, *a.shape)).copy(), one
        )

    if fam in ("dense", "vlm"):
        cache: Dict[str, Any] = {"decoder": kv_stack(cfg.n_layers // period)}
    elif fam == "moe":
        nd = cfg.moe.num_dense_layers
        cache = {}
        mk = (
            (lambda n: jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n, 1, *a.shape)).copy(),
                mla_mod.init_mla_cache(cfg, batch_size, max_len, cache_dtype),
            ))
            if cfg.mla is not None
            else (lambda n: kv_stack(n, 1))
        )
        if nd:
            cache["dense_prefix"] = mk(nd)
        cache["decoder"] = mk(cfg.n_layers - nd)
    elif fam == "hybrid":
        per = cfg.shared_attn_every
        n_super = cfg.n_layers // per
        n_tail = cfg.n_layers - n_super * per
        one_m = mb.init_mamba_state(cfg, batch_size)
        stack_m = lambda n, inner: jax.tree_util.tree_map(  # noqa: E731
            lambda a: jnp.broadcast_to(a, (n, *([inner] if inner else []), *a.shape)).copy()
            if inner
            else jnp.broadcast_to(a, (n, *a.shape)).copy(),
            one_m,
        )
        one_kv = attn.init_kv_cache(cfg, batch_size, max_len, cache_dtype)
        cache = {
            "decoder": {
                "super": {
                    "mamba": jax.tree_util.tree_map(
                        lambda a: jnp.broadcast_to(a, (n_super, per, *a.shape)).copy(), one_m
                    ),
                    "attn": jax.tree_util.tree_map(
                        lambda a: jnp.broadcast_to(a, (n_super, *a.shape)).copy(), one_kv
                    ),
                },
            }
        }
        if n_tail:
            cache["decoder"]["tail"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_tail, *a.shape)).copy(), one_m
            )
        else:
            cache["decoder"]["tail"] = None
    elif fam == "ssm":
        per = cfg.xlstm.slstm_every
        n_groups = cfg.n_layers // per
        one_m = xl.init_mlstm_state(cfg, batch_size)
        one_s = xl.init_slstm_state(cfg, batch_size)
        cache = {
            "decoder": {
                "m": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (n_groups, per - 1, *a.shape)).copy(), one_m
                ),
                "s": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)).copy(), one_s
                ),
            }
        }
    elif fam == "encdec":
        one = attn.init_kv_cache(cfg, batch_size, max_len, cache_dtype)
        cache = {
            "decoder": {
                "self": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)).copy(), one
                )
            }
        }
    else:
        raise ValueError(fam)
    return cache


def cache_batch_axes(
    cfg: ModelConfig, max_len: int, cache_dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """Pytree of ints: which axis of each cache leaf is the batch axis.

    Cache layouts are family-specific (stacked layers, per-group state),
    so the batch axis sits at a different position per leaf.  Discover it
    structurally: eval_shape the cache at two batch sizes and find the one
    axis that differs — no allocation, no per-family table to keep in sync.
    Feeds `cache_update.insert_rows` for continuous-batching slot inserts."""
    a = jax.eval_shape(lambda: init_cache(cfg, 3, max_len, cache_dtype))
    b = jax.eval_shape(lambda: init_cache(cfg, 5, max_len, cache_dtype))

    def _axis(x, y):
        for i, (m, n) in enumerate(zip(x.shape, y.shape)):
            if m != n:
                return i
        raise ValueError(f"no batch axis in cache leaf of shape {x.shape}")

    return jax.tree_util.tree_map(_axis, a, b)


def prefill(
    p: Params,
    cfg: ModelConfig,
    batch: Batch,
    cache: Dict[str, Any],
    *,
    remat: bool = False,
    all_logits: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, Any], jnp.ndarray]:
    """Process the prompt; returns (last-token logits, cache, new_len).

    ``all_logits=True`` returns logits for *every* prompt position
    (B, S, V) — the continuous-batching prefill microbatch right-pads
    prompts to a common length and gathers each row's logits at its own
    true last token, which causality makes identical to an unpadded run."""
    enc_out = _encode(p, cfg, batch, remat=remat) if cfg.family == "encdec" else None
    h, positions = _assemble_input(p, cfg, batch)
    h = h.astype(dtype_of(cfg.dtype))
    zero = jnp.zeros((), jnp.int32)
    h, new_cache, _ = _backbone(
        p, cfg, h, positions,
        cache=cache, cache_len=zero, enc_out=enc_out, remat=remat,
    )
    logits = _lm_logits(p, cfg, h if all_logits else h[:, -1:])
    return logits, new_cache, jnp.asarray(h.shape[1], jnp.int32)


def decode_step(
    p: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, 1)
    cache: Dict[str, Any],
    cache_len: jnp.ndarray,  # scalar int32, or (B,) int32 per-slot lengths
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode; returns (logits (B,1,V), new cache).

    A vector ``cache_len`` is the continuous-batching form: every batch
    row (slot) decodes at its own position, so positions become (B, 1)
    and the cache write / attention mask are per-row."""
    h = _embed_tokens(p, cfg, tokens).astype(dtype_of(cfg.dtype))
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cfg.pos_embedding == "learned":
        idx = jnp.minimum(cache_len, p["embed"]["pos"].shape[0] - 1)
        pe = p["embed"]["pos"][idx]  # scalar idx -> (D,); vector -> (B, D)
        h = h + (pe[:, None] if cache_len.ndim == 1 else pe[None, None])
    h = shard(h, DP, None, None)
    positions = cache_len[:, None] if cache_len.ndim == 1 else cache_len[None]
    h, new_cache, _ = _backbone(
        p, cfg, h, positions, cache=cache, cache_len=cache_len
    )
    logits = _lm_logits(p, cfg, h)
    return logits, new_cache
