"""Model zoo: composable JAX definitions for the assigned architectures."""

from . import attention, layers, mamba2, mla, model, moe, sharding, transformer, xlstm
from .model import (
    cache_batch_axes,
    decode_step,
    forward,
    forward_hidden,
    head_weight,
    init_cache,
    init_params,
    prefill,
)

__all__ = [
    "attention",
    "layers",
    "mamba2",
    "mla",
    "model",
    "moe",
    "sharding",
    "transformer",
    "xlstm",
    "init_params",
    "cache_batch_axes",
    "forward",
    "forward_hidden",
    "head_weight",
    "init_cache",
    "prefill",
    "decode_step",
]
