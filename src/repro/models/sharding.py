"""Mesh-aware logical sharding helpers.

Model code annotates activations with *logical* axes ("dp", "tp", None);
this module maps them onto whatever physical mesh is ambient:
  * production single-pod: (data=16, model=16)        dp=(data,) tp=model
  * production multi-pod:  (pod=2, data=16, model=16) dp=(pod,data) tp=model
  * CPU smoke tests: no mesh -> all constraints are no-ops.

Parameter shardings are assigned by path-pattern rules (`param_pspec`),
giving Megatron-style TP over "model" + ZeRO-3/FSDP over the combined
data axes.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "dp"  # data-parallel / FSDP logical axis -> ("pod","data") subset
TP = "tp"  # tensor/expert-parallel logical axis -> "model"


def current_mesh() -> Optional[Mesh]:
    try:  # jax >= 0.8: use_mesh / abstract mesh context
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001
        pass
    try:  # `with mesh:` (Mesh context manager) path
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001
        return None


def axis_map() -> str:
    """Logical->physical mapping scheme (a §Perf hillclimb lever):
      tp_model (default): dp -> (pod, data), tp -> model   (FSDP+TP16)
      fsdp_all:           dp -> (pod, data, model), tp -> —  (pure ZeRO-3;
                          kills TP activation all-reduces; right for models
                          whose layer params fit HBM when gathered)
    """
    import os

    return os.environ.get("REPRO_AXIS_MAP", "tp_model")


def seq_parallel() -> bool:
    """Megatron-style sequence parallelism for the residual stream: hidden
    states (B, S, D) are sharded over tp on S between blocks, shrinking the
    per-layer saved activations tp-fold (a §Perf hillclimb lever)."""
    import os

    return os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"


def physical_axes(mesh: Mesh, logical):
    if logical is None:
        return None
    if isinstance(logical, tuple):  # combined logical axes, e.g. ("dp","tp")
        out = []
        for l in logical:
            ax = physical_axes(mesh, l)
            if ax is None:
                continue
            out.extend(ax if isinstance(ax, tuple) else (ax,))
        return tuple(out) if out else None
    names = set(mesh.axis_names)
    scheme = axis_map()
    if logical == DP:
        pool = ("pod", "data", "model") if scheme == "fsdp_all" else ("pod", "data")
        axes = tuple(a for a in pool if a in names)
        return axes if axes else None
    if logical == TP:
        if scheme == "fsdp_all":
            return None
        return "model" if "model" in names else None
    # literal mesh axis name passthrough
    return logical if logical in names else None


def make_pspec(mesh: Mesh, *logical) -> P:
    return P(*(physical_axes(mesh, l) for l in logical))


def shard(x: jnp.ndarray, *logical) -> jnp.ndarray:
    """with_sharding_constraint against the ambient mesh (no-op without)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"spec {logical} does not match rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(x, make_pspec(mesh, *logical))


def residual_shard(x: jnp.ndarray) -> jnp.ndarray:
    """Constraint for the (B, S, D) residual stream between blocks: batch
    over dp, and — under sequence parallelism — S over tp."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    tp_ax = physical_axes(mesh, TP)
    if seq_parallel() and tp_ax is not None:
        tp_size = mesh.shape[tp_ax]
        if x.shape[1] % tp_size == 0 and x.shape[1] >= tp_size:
            return shard(x, DP, TP, None)
    return shard(x, DP, None, None)


# ---------------------------------------------------------------------------
# parameter sharding rules (path-pattern based)
# ---------------------------------------------------------------------------
# Each rule: (regex over 'a/b/c' param path, logical spec builder given ndim).
# Conventions (dims AFTER the scan-stacking axes, which are always None):
#   embeddings (V, D)           -> (tp, dp)    vocab-sharded
#   attn wq (D, H, hd)          -> (dp, tp, None)
#   attn wk/wv (D, K, hd)       -> (dp, tp, None)  (replicate tp if K < tp)
#   attn wo (H, hd, D)          -> (tp, None, dp)
#   mlp w_gate/w_up (D, F)      -> (dp, tp)
#   mlp w_down (F, D)           -> (tp, dp)
#   moe experts (E, D, F)       -> (tp, dp, None)   expert-parallel
#   moe w_down (E, F, D)        -> (tp, None, dp)
#   router (D, E)               -> (dp, None)
#   mamba in/out proj           -> (dp, tp) / (tp, dp)
#   norms / scalars / biases    -> replicated
# FSDP ("dp") on the non-tp dim gives ZeRO-3: XLA all-gathers per layer.

_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/tok$", (TP, DP)),
    (r"embed/pos$", (None, None)),
    (r"lm_head$", (DP, TP)),
    (r"(wq|q_up)$", (DP, TP, None)),
    (r"(wk|wv)$", (DP, None, None)),
    (r"wo$", (TP, None, DP)),
    (r"(wq_b|wk_b|wv_b)$", (None, None)),
    (r"q_down$", (DP, TP)),
    (r"kv_down$", (DP, None)),
    (r"kv_up$", (DP, TP, None)),
    (r"(w_gate|w_up)$", (DP, TP)),
    (r"w_down$", (TP, DP)),
    (r"experts/(w_gate|w_up)$", (TP, DP, None)),
    (r"experts/w_down$", (TP, None, DP)),
    (r"router$", (DP, None)),
    (r"in_proj$", (DP, TP)),
    (r"out_proj$", (TP, DP)),
    (r"(conv_kernel|conv_bias)$", (None, TP)),
    (r"(A_log|D|dt_bias)$", (TP,)),
    (r"(w_q|w_k|w_v)hw$", (TP, None, None)),  # headwise xlstm projections
    (r"(w_i|w_f)gate$", (DP, TP)),
    (r"r_kernel$", (TP, None, None, None)),
    (r"gates_x$", (DP, TP, None)),
    (r"skip$", (TP,)),
)


def _match_logical(path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    for pat, spec in _RULES:
        if re.search(pat, path):
            nlead = len(shape) - len(spec)
            if nlead < 0:
                return tuple([None] * len(shape))
            return tuple([None] * nlead + list(spec))
    return tuple([None] * len(shape))  # replicate


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(mesh: Mesh, params_tree: Any, *, verify_divisible: bool = True) -> Any:
    """PartitionSpec pytree for a param pytree (shapes or arrays)."""

    def spec_for(path, leaf):
        shape = leaf.shape
        logical = _match_logical(_path_str(path), shape)
        phys = []
        for dim, l in zip(shape, logical):
            ax = physical_axes(mesh, l)
            if ax is None:
                phys.append(None)
                continue
            size = (
                mesh.shape[ax]
                if isinstance(ax, str)
                else int(jnp.prod(jnp.array([mesh.shape[a] for a in ax])))
            )
            if verify_divisible and dim % size != 0:
                phys.append(None)  # fall back to replication
            else:
                phys.append(ax)
        return P(*phys)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def param_sharding(mesh: Mesh, params_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_pspec(mesh, params_tree)
    )
