"""GQA/MHA attention block: train (full), prefill (cache fill), decode
(single token), optional cross-attention (enc-dec).

KV-cache layout per layer: {"k": (B, Smax, K, hd), "v": (B, Smax, K, hd)};
`cache_len` is a scalar (aligned batched serving) or a per-row (B,) vector
(continuous batching: every slot decodes at its own position).  Sharding:
batch over dp.
For the cache's head dim: if K % tp == 0 heads shard over tp; otherwise the
*sequence* dim shards over tp and the decode softmax reductions become
all-reduces (flash-decoding across the model axis) — handled purely by
sharding constraints, see `cache_logical_spec`.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

from . import layers
from .cache_update import write_row, write_segment
from .layers import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init
from .sharding import DP, TP, current_mesh, shard


def attn_init(
    key,
    cfg: ModelConfig,
    *,
    q_in_dim: Optional[int] = None,
    kv_in_dim: Optional[int] = None,
    dtype=jnp.float32,
) -> Params:
    D = cfg.d_model
    qd = q_in_dim or D
    kvd = kv_in_dim or D
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    p: Params = {
        "wq": dense_init(ks[0], qd, H, hd, dtype=dtype),
        "wk": dense_init(ks[1], kvd, K, hd, dtype=dtype),
        "wv": dense_init(ks[2], kvd, K, hd, dtype=dtype),
        "wo": dense_init(ks[3], H, hd, D, dtype=dtype),
    }
    if cfg.attn_bias:
        p["wq_b"] = jnp.zeros((H, hd), dtype)
        p["wk_b"] = jnp.zeros((K, hd), dtype)
        p["wv_b"] = jnp.zeros((K, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, jnp.ndarray]:
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, K, hd), dtype),
        "v": jnp.zeros((batch, max_len, K, hd), dtype),
    }


def _dp_size() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    import numpy as _np

    return int(_np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]) or 1)


def cache_logical_spec(cfg: ModelConfig, tp_size: int, batch: int) -> Tuple:
    """(B, S, K, hd) logical axes for the KV cache.  Must agree with
    launch/shardings.py:cache_pspec."""
    dp_n = _dp_size()
    heads_ok = tp_size and cfg.n_kv_heads % tp_size == 0
    if batch % max(dp_n, 1) == 0 and batch >= dp_n:
        return (DP, None, TP, None) if heads_ok else (DP, TP, None, None)
    # tiny batch (long-context decode): shard the sequence dim
    return (None, DP, TP, None) if heads_ok else (None, (DP, TP), None, None)


def _project_qkv(p: Params, xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if cfg.attn_bias:
        q = q + p["wq_b"][None, None]
        k = k + p["wk_b"][None, None]
        v = v + p["wv_b"][None, None]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], eps=cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], eps=cfg.rms_eps)
    return q, k, v


def _tp_size() -> int:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 0
    return mesh.shape["model"]


def attn_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,  # (S,) or per-row (B, S)
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,  # scalar or per-row (B,) int32
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # encoder k, v
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (out, updated_cache)."""
    B, S, D = x.shape
    tp = _tp_size()
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / math.sqrt(cfg.hd)

    if cross_kv is not None:
        # cross-attention: kv precomputed from encoder (no cache update here)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.attn_bias:
            q = q + p["wq_b"][None, None]
        k, v = cross_kv
        out = ops.flash_attention(q, k, v, causal=False, scale=scale)
        out = shard(out, DP, None, TP, None)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None

    q, k, v = _project_qkv(p, x, x, cfg)
    if use_rope and cfg.pos_embedding == "rope":
        if positions is None:
            positions = jnp.arange(S)
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
    q = shard(q, DP, None, TP, None)

    if cache is None:
        # train / no-cache prefill
        out = ops.flash_attention(
            q, k, v,
            causal=causal,
            window=window,
            logit_cap=cfg.attn_softcap,
            scale=scale,
        )
        out = shard(out, DP, None, TP, None)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None

    spec = cache_logical_spec(cfg, tp, B)
    # seq-dim sharded caches cannot take dynamic_update_slice at a traced
    # index (SPMD would all-gather the cache); use local masked writes
    dus_ok = spec[1] is None
    if S > 1:
        # lay fresh k/v out like the cache BEFORE the update — otherwise SPMD
        # falls back to replicate-then-repartition around dynamic_update_slice
        k = shard(k, *spec)
        v = shard(v, *spec)
    if S == 1:
        # decode: append then attend against cache
        new_k = write_row(cache["k"], k, cache_len, dus_ok=dus_ok)
        new_v = write_row(cache["v"], v, cache_len, dus_ok=dus_ok)
        new_k = shard(new_k, *spec)
        new_v = shard(new_v, *spec)
        out = ops.decode_attention(
            q[:, 0],
            new_k,
            new_v,
            jnp.broadcast_to(jnp.atleast_1d(cache_len) + 1, (B,)).astype(jnp.int32),
            logit_cap=cfg.attn_softcap,
            window=window,
            scale=scale,
        )[:, None]  # (B, 1, H, hd)
    else:
        # prefill: write the whole segment, attend causally within it
        new_k = write_segment(cache["k"], k, cache_len, dus_ok=dus_ok)
        new_v = write_segment(cache["v"], v, cache_len, dus_ok=dus_ok)
        new_k = shard(new_k, *spec)
        new_v = shard(new_v, *spec)
        out = ops.flash_attention(
            q, k, v,
            causal=causal,
            window=window,
            logit_cap=cfg.attn_softcap,
            q_offset=0,
            scale=scale,
        )
    out = shard(out, DP, None, TP, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": new_k, "v": new_v}


def cross_kv_init(p: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Precompute encoder K/V for decoder cross-attention layers."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.attn_bias:
        k = k + p["wk_b"][None, None]
        v = v + p["wv_b"][None, None]
    return k, v
