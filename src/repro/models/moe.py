"""Mixture-of-Experts layer (OLMoE 64e/top-8, DeepSeek-V3 256e/top-8+shared).

Dispatch is the t5x/mesh-TF grouped one-hot-einsum formulation, which SPMD
partitions cleanly with experts sharded over the model axis (EP=TP):

  tokens (N, D) -> groups (G, g, D)               [G over dp, replicated tp]
  combine (G, g, E, C)  one-hot x gate weights    [E over tp]
  expert_in (G, E, C, D) = einsum(combine>0, x)   [local per tp rank]
  expert_out = per-expert SwiGLU                  [E sharded: true EP compute]
  out (G, g, D) = einsum(combine, expert_out)     [contraction over E -> psum]

The final all-reduce over the model axis is the same collective a dense TP
MLP needs, so EP costs no *extra* communication vs dense under this layout;
the price is dispatch-einsum FLOPs (~E*C/(g*k) of useful compute), which the
§Perf log attacks with a gather-based variant (`impl="gather"`).

Routing: softmax top-k (OLMoE) or sigmoid+normalized top-k (DeepSeek-V3),
with a switch-style load-balance aux loss.  Capacity-factor token dropping;
dropped tokens fall through on the residual path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops  # noqa: F401  (kept for parity with other blocks)

from .layers import Params, dense_init
from .sharding import DP, TP, shard


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 8)
    p: Params = {
        "router": dense_init(ks[0], D, E, dtype=jnp.float32),  # router in fp32
        "experts": {
            "w_gate": dense_init(ks[1], E, D, F, dtype=dtype),
            "w_up": dense_init(ks[2], E, D, F, dtype=dtype),
            "w_down": dense_init(ks[3], E, F, D, dtype=dtype),
        },
    }
    if m.num_shared:
        p["shared"] = {
            "w_gate": dense_init(ks[4], D, m.num_shared * F, dtype=dtype),
            "w_up": dense_init(ks[5], D, m.num_shared * F, dtype=dtype),
            "w_down": dense_init(ks[6], m.num_shared * F, D, dtype=dtype),
        }
    if getattr(m, "router_bias", False) or True:
        # DeepSeek-V3 aux-free balancing bias (updated outside grad)
        p["router_bias"] = jnp.zeros((E,), jnp.float32)
    return p


def _route(
    p: Params, tokens: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (gates (N,k), idx (N,k), aux_loss scalar)."""
    m = cfg.moe
    logits = tokens.astype(jnp.float32) @ p["router"]  # (N, E)
    if cfg.mla is not None:  # DeepSeek-V3: sigmoid scores + bias for selection
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, :]
        _, idx = jax.lax.top_k(sel, m.top_k)
        gates = jnp.take_along_axis(scores, idx, axis=1)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, axis=1, keepdims=True), 1e-9)
    else:  # OLMoE: softmax top-k
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=1, keepdims=True), 1e-9)
    # switch-style load-balance loss: E * sum_e f_e * p_e
    E = logits.shape[-1]
    onehot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    f = jnp.mean(onehot_top1, axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pbar)
    return gates, idx, aux


def _swiglu_experts(exp: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """x: (..., E, C, D) -> (..., E, C, D), weights (E, D, F)/(E, F, D)."""
    fn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    g = fn(jnp.einsum("...ecd,edf->...ecf", x, exp["w_gate"]))
    u = jnp.einsum("...ecd,edf->...ecf", x, exp["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", g * u, exp["w_down"])


def moe_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,D), aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    N = B * S
    tokens = x.reshape(N, D)

    gates, idx, aux = _route(p, tokens, cfg)

    # group to bound dispatch-tensor memory
    g = min(m.group_size, N)
    pad = (-N) % g
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=0)
        # padded tokens get zero gates
        gates = gates * (jnp.arange(N + pad)[:, None] < N)
    G = tokens.shape[0] // g
    cap = int(max(8, -(-g * k // E) * m.capacity_factor))
    cap = -(-cap // 8) * 8  # round up to multiple of 8

    xg = tokens.reshape(G, g, D)
    gg = gates.reshape(G, g, k)
    ig = idx.reshape(G, g, k)
    xg = shard(xg, DP, None, None)

    # build combine tensor (G, g, E, C): loop over the k slots with running
    # per-expert counts (slot-priority dropping)
    counts = jnp.zeros((G, E), jnp.int32)
    combine = jnp.zeros((G, g, E, cap), jnp.float32)
    for j in range(k):
        oh = jax.nn.one_hot(ig[:, :, j], E, dtype=jnp.int32)  # (G, g, E)
        pos = counts[:, None, :] + jnp.cumsum(oh, axis=1) - oh  # pos before self
        mypos = jnp.sum(pos * oh, axis=2)  # (G, g)
        keep = mypos < cap
        pos_oh = jax.nn.one_hot(jnp.where(keep, mypos, cap), cap + 1, dtype=jnp.float32)[
            ..., :cap
        ]  # (G, g, C)
        combine = combine + (
            gg[:, :, j][..., None, None]
            * oh.astype(jnp.float32)[..., None]
            * pos_oh[:, :, None, :]
        )
        counts = counts + jnp.sum(oh, axis=1)
    combine = shard(combine, DP, None, TP, None)
    dispatch = (combine > 0).astype(x.dtype)

    expert_in = jnp.einsum("Ggec,Ggd->Gecd", dispatch, xg.astype(x.dtype))
    expert_in = shard(expert_in, DP, TP, None, None)
    expert_out = _swiglu_experts(p["experts"], expert_in, cfg.act)
    expert_out = shard(expert_out, DP, TP, None, None)
    out = jnp.einsum("Ggec,Gecd->Ggd", combine.astype(x.dtype), expert_out)
    out = shard(out, DP, None, None)

    out = out.reshape(-1, D)[:N].reshape(B, S, D)

    if m.num_shared:
        sh = p["shared"]
        fn = jax.nn.silu if cfg.act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
        out = out + (fn(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]
    return out, aux
