"""Mamba2 mixer block (Zamba2 backbone).

in_proj fans out to [z | x | B | C | dt]; depthwise causal conv over
[x|B|C]; SSD scan over heads (Pallas kernel / chunked jnp via ops.ssd_scan);
gated RMSNorm; out_proj.  Decode keeps (conv_state, ssm_state) — O(1) per
token, which is what makes the hybrid run `long_500k`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

from .layers import Params, causal_conv1d, dense_init, grouped_rmsnorm
from .sharding import DP, TP, residual_shard, shard


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.num_groups * s.state_dim
    return s, d_in, n_heads, conv_dim


def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    s, d_in, nh, conv_dim = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.num_groups * s.state_dim + nh
    dt = jnp.exp(
        jax.random.uniform(ks[1], (nh,)) * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
        + jnp.log(s.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], D, proj_out, dtype=dtype),
        "conv_kernel": (jax.random.normal(ks[2], (s.conv_kernel, conv_dim)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gated_norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[3], d_in, D, dtype=dtype),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
    }


def mamba_state_spec() -> Dict[str, Tuple]:
    return {"conv": (DP, None, TP), "ssm": (DP, TP, None, None)}


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    s, d_in, nh, _ = _dims(cfg)
    gn = s.num_groups * s.state_dim
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : d_in + d_in + 2 * gn]
    dt = zxbcdt[..., -nh:]
    return z, xBC, dt


def mamba2_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (out, new_state).  state=None -> train (no state carried)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    B, S, D = x.shape
    gn = s.num_groups * s.state_dim

    zxbcdt = x @ p["in_proj"]
    zxbcdt = shard(zxbcdt, DP, None, TP)
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = causal_conv1d(xBC, p["conv_kernel"], p["conv_bias"], conv_state)
    xBC = jax.nn.silu(xBC)

    xs = xBC[..., :d_in].reshape(B, S, nh, s.head_dim)
    Bm = xBC[..., d_in : d_in + gn].reshape(B, S, s.num_groups, s.state_dim)
    Cm = xBC[..., d_in + gn :].reshape(B, S, s.num_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if state is not None and S == 1:
        new_ssm, y = ops.ssd_decode_step(
            state["ssm"], xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], p["D"]
        )
        y = y[:, None]  # (B, 1, nh, hd)
    else:
        new_ssm = None
        if state is not None:  # prefill: one pass, state returned by the scan
            y, new_ssm = ops.ssd_scan(
                xs, dt, A, Bm, Cm, p["D"], chunk=s.chunk, return_state=True
            )
        else:
            y = ops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=s.chunk)

    y = y.reshape(B, S, d_in)
    y = grouped_rmsnorm(y * jax.nn.silu(z), p["gated_norm"], n_groups=s.num_groups, eps=cfg.rms_eps)
    out = y @ p["out_proj"]
    out = residual_shard(out)

    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": new_ssm}
    return out, new_state
