"""SPMD-friendly KV-cache writes.

`dynamic_update_slice` at a *traced* index along a *sharded* sequence dim
makes XLA SPMD fall back to replicate-update-reshard — an all-gather of the
entire cache per layer per step (observed: ~347 GB/device/token for
llama3-405b decode).  Two local alternatives:

  * decode (one row): masked write `where(iota == len, new, cache)` —
    purely elementwise, partitions perfectly along every dim.  Costs a
    full cache rewrite of HBM traffic, which is the same order as the
    attention read of the cache itself (and donation keeps it in place).
  * prefill (whole buffer): when the segment covers the buffer, just
    replace; otherwise pad — no DUS at all.

`dus_ok=True` (head-sharded caches, sequence dim unsharded) keeps the
cheaper dynamic_update_slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def write_row(
    cache: jnp.ndarray,  # (B, S, ...) sequence on axis 1
    row: jnp.ndarray,  # (B, 1, ...)
    index: jnp.ndarray,  # scalar int32, or (B,) int32 for per-row positions
    *,
    dus_ok: bool,
) -> jnp.ndarray:
    """Write one sequence row at a traced index.

    A vector ``index`` writes each batch row at its *own* position — the
    continuous-batching case, where every slot's cache has a different
    length.  DUS can't express a per-row offset, so the vector path is
    always the masked write (which partitions fine anyway).
    """
    index = jnp.asarray(index)
    if index.ndim == 0 and dus_ok:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, row.astype(cache.dtype), index, axis=1
        )
    S = cache.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (cache.ndim - 2), 1)
    if index.ndim == 1:
        index = index.reshape(index.shape[0], *([1] * (cache.ndim - 1)))
    return jnp.where(pos == index, row.astype(cache.dtype), cache)


def insert_rows(
    big: jnp.ndarray,
    small: jnp.ndarray,
    slots: jnp.ndarray,  # (n,) int32 indices into big's batch axis
    axis: int,
) -> jnp.ndarray:
    """Scatter `small`'s batch rows into `big` at `slots` along `axis`.

    The slot-insert primitive for continuous batching: a freshly prefilled
    n-request cache leaf replaces the corresponding rows of the persistent
    max_batch cache leaf.  Whole-row replacement — the previous occupant's
    KV is structurally unreachable, not merely masked."""
    bm = jnp.moveaxis(big, axis, 0)
    sm = jnp.moveaxis(small, axis, 0)
    return jnp.moveaxis(bm.at[slots].set(sm.astype(bm.dtype)), 0, axis)


def write_segment(
    cache: jnp.ndarray,  # (B, S, ...)
    seg: jnp.ndarray,  # (B, L, ...), written at [index, index+L)
    index: jnp.ndarray,
    *,
    dus_ok: bool,
) -> jnp.ndarray:
    """Write a segment; prefill covering the whole buffer avoids DUS."""
    if seg.shape[1] == cache.shape[1]:
        return seg.astype(cache.dtype)  # full replace (standard prefill)
    if dus_ok:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, seg.astype(cache.dtype), index, axis=1
        )
    # segment shorter than buffer on a sharded seq dim: pad + mask
    S, L = cache.shape[1], seg.shape[1]
    seg_p = jnp.pad(seg, ((0, 0), (0, S - L)) + ((0, 0),) * (cache.ndim - 2))
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (cache.ndim - 2), 1)
    inside = (pos >= index) & (pos < index + L)
    # roll seg into place: positions are index+i; for prefill index==0 this
    # is the identity, which is the only case the launchers lower.
    return jnp.where(inside, seg_p.astype(cache.dtype), cache)
