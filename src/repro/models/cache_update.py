"""SPMD-friendly KV-cache writes.

`dynamic_update_slice` at a *traced* index along a *sharded* sequence dim
makes XLA SPMD fall back to replicate-update-reshard — an all-gather of the
entire cache per layer per step (observed: ~347 GB/device/token for
llama3-405b decode).  Two local alternatives:

  * decode (one row): masked write `where(iota == len, new, cache)` —
    purely elementwise, partitions perfectly along every dim.  Costs a
    full cache rewrite of HBM traffic, which is the same order as the
    attention read of the cache itself (and donation keeps it in place).
  * prefill (whole buffer): when the segment covers the buffer, just
    replace; otherwise pad — no DUS at all.

`dus_ok=True` (head-sharded caches, sequence dim unsharded) keeps the
cheaper dynamic_update_slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def write_row(
    cache: jnp.ndarray,  # (B, S, ...) sequence on axis 1
    row: jnp.ndarray,  # (B, 1, ...)
    index: jnp.ndarray,  # scalar int32
    *,
    dus_ok: bool,
) -> jnp.ndarray:
    """Write one sequence row at a traced index."""
    if dus_ok:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, row.astype(cache.dtype), index, axis=1
        )
    S = cache.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (cache.ndim - 2), 1)
    return jnp.where(pos == index, row.astype(cache.dtype), cache)


def write_segment(
    cache: jnp.ndarray,  # (B, S, ...)
    seg: jnp.ndarray,  # (B, L, ...), written at [index, index+L)
    index: jnp.ndarray,
    *,
    dus_ok: bool,
) -> jnp.ndarray:
    """Write a segment; prefill covering the whole buffer avoids DUS."""
    if seg.shape[1] == cache.shape[1]:
        return seg.astype(cache.dtype)  # full replace (standard prefill)
    if dus_ok:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, seg.astype(cache.dtype), index, axis=1
        )
    # segment shorter than buffer on a sharded seq dim: pad + mask
    S, L = cache.shape[1], seg.shape[1]
    seg_p = jnp.pad(seg, ((0, 0), (0, S - L)) + ((0, 0),) * (cache.ndim - 2))
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (cache.ndim - 2), 1)
    inside = (pos >= index) & (pos < index + L)
    # roll seg into place: positions are index+i; for prefill index==0 this
    # is the identity, which is the only case the launchers lower.
    return jnp.where(inside, seg_p.astype(cache.dtype), cache)
