"""Transformer block assembly: per-family layer stacks, scanned.

Scan-over-layers keeps compile time and HLO size O(1) in depth (126-layer
llama3-405b compiles one layer body).  Heterogeneous depth patterns are
expressed as *periods*: params are stacked (L/period, period, ...) and the
scan body unrolls the period statically (gemma2: [local, global]; xlstm:
[7 x mLSTM, sLSTM]; zamba2: [6 x mamba + shared-attn]).

Each stage function has signature
    stage_apply(params, h, cfg, mode, cache, cache_len, ...)
      -> (h, new_cache, aux_losses)
where cache is the stage's stacked cache pytree (or None in train mode).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn
from . import mamba2 as mb
from . import mla as mla_mod
from . import moe as moe_mod
from . import xlstm as xl
from .layers import Params, mlp_apply, mlp_init, rmsnorm, rmsnorm_init, scan_unroll
from .sharding import residual_shard, shard


def _stack_init(key, n: int, init_fn):
    """Initialize n copies of a param pytree, stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _remat(f, enabled: bool):
    if not enabled:
        return f
    import os

    pol = os.environ.get("REPRO_REMAT_POLICY", "nothing")
    if pol == "none":
        return f
    policy = {
        # full remat: save only layer inputs — the right default at scale
        "nothing": jax.checkpoint_policies.nothing_saveable,
        # save matmul outputs: cheaper recompute, ~4x the activation memory
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[pol]
    return jax.checkpoint(f, policy=policy)


# ---------------------------------------------------------------------------
# dense / vlm / moe decoder layer
# ---------------------------------------------------------------------------

def decoder_layer_init(key, cfg: ModelConfig, *, use_moe: bool, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype), "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.sandwich_norm:
        p["ln1_post"] = rmsnorm_init(cfg.d_model, dtype)
        p["ln2_post"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.mla is not None:
        p["attn"] = mla_mod.mla_init(k1, cfg, dtype=dtype)
    else:
        p["attn"] = attn.attn_init(k1, cfg, dtype=dtype)
    if use_moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype=dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def decoder_layer_apply(
    p: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    window: Optional[int],
    positions: jnp.ndarray,
    cache: Optional[Dict[str, jnp.ndarray]],
    cache_len: Optional[jnp.ndarray],
    use_moe: bool,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    h = residual_shard(h)
    x = rmsnorm(h, p["ln1"], eps=cfg.rms_eps)
    if cfg.mla is not None:
        a_out, new_cache = mla_mod.mla_apply(
            p["attn"], x, cfg, positions=positions, cache=cache, cache_len=cache_len
        )
    else:
        a_out, new_cache = attn.attn_apply(
            p["attn"], x, cfg,
            window=window, positions=positions, cache=cache, cache_len=cache_len,
        )
    if cfg.sandwich_norm:
        a_out = rmsnorm(a_out, p["ln1_post"], eps=cfg.rms_eps)
    h = h + a_out

    x = rmsnorm(h, p["ln2"], eps=cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        m_out, aux = moe_mod.moe_apply(p["moe"], x, cfg)
    else:
        m_out = mlp_apply(p["mlp"], x, cfg.act)
    if cfg.sandwich_norm:
        m_out = rmsnorm(m_out, p["ln2_post"], eps=cfg.rms_eps)
    return h + m_out, new_cache, aux


# ---------------------------------------------------------------------------
# decoder stage (scan over layers, period-aware)
# ---------------------------------------------------------------------------

def decoder_stage_init(
    key, cfg: ModelConfig, n_layers: int, *, use_moe: bool, dtype=jnp.float32
) -> Params:
    period = cfg.global_every if (cfg.sliding_window and cfg.global_every) else 1
    assert n_layers % period == 0, (n_layers, period)
    outer = n_layers // period

    def one(k):
        ks = jax.random.split(k, period)
        sub = [decoder_layer_init(ks[i], cfg, use_moe=use_moe, dtype=dtype) for i in range(period)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sub)

    return _stack_init(key, outer, one)  # (outer, period, ...)


def decoder_stage_apply(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    use_moe: bool,
    remat: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    period = cfg.global_every if (cfg.sliding_window and cfg.global_every) else 1

    def body(carry, xs):
        hh, aux = carry
        layer_params, layer_cache = xs
        new_caches = []
        for i in range(period):
            pi = jax.tree_util.tree_map(lambda a, i=i: a[i], layer_params)
            ci = None if layer_cache is None else jax.tree_util.tree_map(lambda a, i=i: a[i], layer_cache)
            window = None
            if cfg.sliding_window and period > 1 and i < period - 1:
                window = cfg.sliding_window
            elif cfg.sliding_window and period == 1:
                window = cfg.sliding_window
            hh, nc, a = decoder_layer_apply(
                pi, hh, cfg,
                window=window, positions=positions,
                cache=ci, cache_len=cache_len, use_moe=use_moe,
            )
            aux = aux + a
            new_caches.append(nc)
        nc_stacked = (
            None
            if new_caches[0] is None
            else jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_caches)
        )
        return (hh, aux), nc_stacked

    body = _remat(body, remat)
    (h, aux), new_cache = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (params, cache), unroll=scan_unroll()
    )
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# encoder stage (whisper): full attention, no cache
# ---------------------------------------------------------------------------

def encoder_layer_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype=dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def encoder_stage_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return _stack_init(
        key, cfg.n_encoder_layers, lambda k: encoder_layer_init(k, cfg, dtype=dtype)
    )


def encoder_stage_apply(params: Params, h: jnp.ndarray, cfg: ModelConfig, *, remat=False):
    def body(hh, layer):
        x = rmsnorm(hh, layer["ln1"], eps=cfg.rms_eps)
        a, _ = attn.attn_apply(layer["attn"], x, cfg, causal=False, use_rope=False)
        hh = hh + a
        x = rmsnorm(hh, layer["ln2"], eps=cfg.rms_eps)
        return hh + mlp_apply(layer["mlp"], x, cfg.act), None

    body = _remat(body, remat)
    h, _ = jax.lax.scan(body, h, params, unroll=scan_unroll())
    return h


# ---------------------------------------------------------------------------
# cross-decoder stage (whisper decoder: self + cross + mlp)
# ---------------------------------------------------------------------------

def xdecoder_layer_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "self_attn": attn.attn_init(k1, cfg, dtype=dtype),
        "cross_attn": attn.attn_init(k2, cfg, dtype=dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def xdecoder_stage_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return _stack_init(key, cfg.n_layers, lambda k: xdecoder_layer_init(k, cfg, dtype=dtype))


def xdecoder_stage_apply(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    enc_out: Optional[jnp.ndarray] = None,  # (B, Senc, D) or None if cached
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    remat: bool = False,
):
    """cache: {"self": {k,v}, "cross": {k,v}} stacked (L, ...)."""

    def body(carry, xs):
        hh = carry
        layer, layer_cache = xs
        x = rmsnorm(hh, layer["ln1"], eps=cfg.rms_eps)
        self_cache = None if layer_cache is None else layer_cache["self"]
        a, new_self = attn.attn_apply(
            layer["self_attn"], x, cfg,
            positions=positions, cache=self_cache, cache_len=cache_len,
            use_rope=False,
        )
        hh = hh + a
        x = rmsnorm(hh, layer["ln_x"], eps=cfg.rms_eps)
        if layer_cache is not None and "cross" in layer_cache:
            ck, cv = layer_cache["cross"]["k"], layer_cache["cross"]["v"]
        else:
            ck, cv = attn.cross_kv_init(layer["cross_attn"], enc_out, cfg)
        a, _ = attn.attn_apply(layer["cross_attn"], x, cfg, cross_kv=(ck, cv))
        hh = hh + a
        x = rmsnorm(hh, layer["ln2"], eps=cfg.rms_eps)
        hh = hh + mlp_apply(layer["mlp"], x, cfg.act)
        new_cache = None
        if layer_cache is not None:
            new_cache = {"self": new_self, "cross": {"k": ck, "v": cv}}
        return hh, new_cache

    body = _remat(body, remat)
    h, new_cache = jax.lax.scan(body, h, (params, cache), unroll=scan_unroll())
    return h, new_cache


# ---------------------------------------------------------------------------
# hybrid stage (zamba2): mamba superblocks + shared attention block
# ---------------------------------------------------------------------------

def shared_attn_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    D = cfg.d_model
    return {
        "ln": rmsnorm_init(2 * D, dtype),
        "attn": attn.attn_init(k1, cfg, q_in_dim=2 * D, kv_in_dim=2 * D, dtype=dtype),
        "ln2": rmsnorm_init(2 * D, dtype),
        "mlp": {
            "w_gate": jax.random.normal(k2, (2 * D, cfg.d_ff)).astype(dtype) * (2 * D) ** -0.5,
            "w_up": jax.random.normal(jax.random.fold_in(k2, 1), (2 * D, cfg.d_ff)).astype(dtype)
            * (2 * D) ** -0.5,
            "w_down": jax.random.normal(jax.random.fold_in(k2, 2), (cfg.d_ff, D)).astype(dtype)
            * cfg.d_ff**-0.5,
        },
    }


def shared_attn_block_apply(
    p: Params,
    h: jnp.ndarray,
    h0: jnp.ndarray,  # original embeddings (zamba concat trick)
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
):
    xcat = jnp.concatenate([h, h0], axis=-1)  # (B, S, 2D)
    x = rmsnorm(xcat, p["ln"], eps=cfg.rms_eps)
    a, new_cache = attn.attn_apply(
        p["attn"], x, cfg, positions=positions, cache=cache, cache_len=cache_len
    )
    h = h + a
    x2 = rmsnorm(xcat, p["ln2"], eps=cfg.rms_eps)
    h = h + mlp_apply(p["mlp"], x2, cfg.act)
    return h, new_cache


def hybrid_stage_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    per = cfg.shared_attn_every
    n_super = cfg.n_layers // per
    n_tail = cfg.n_layers - n_super * per
    k1, k2, k3 = jax.random.split(key, 3)

    def superblock(k):
        ks = jax.random.split(k, per)
        subs = [mb.mamba2_init(ks[i], cfg, dtype=dtype) for i in range(per)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *subs)

    p: Params = {
        "super": _stack_init(k1, n_super, superblock),  # (n_super, per, ...)
        "shared": shared_attn_block_init(k2, cfg, dtype=dtype),
    }
    if n_tail:
        p["tail"] = _stack_init(k3, n_tail, lambda k: mb.mamba2_init(k, cfg, dtype=dtype))
    return p


def hybrid_stage_apply(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    cache: Optional[Dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    remat: bool = False,
):
    per = cfg.shared_attn_every
    h0 = h  # embeddings for the concat trick

    def body(carry, xs):
        hh = carry
        layer_params, layer_cache = xs
        mstates = []
        for i in range(per):
            pi = jax.tree_util.tree_map(lambda a, i=i: a[i], layer_params["mamba"])
            si = (
                None
                if layer_cache is None
                else jax.tree_util.tree_map(lambda a, i=i: a[i], layer_cache["mamba"])
            )
            out, ns = mb.mamba2_apply(pi, hh, cfg, state=si)
            hh = hh + out
            mstates.append(ns)
        attn_cache = None if layer_cache is None else layer_cache["attn"]
        hh, new_attn = shared_attn_block_apply(
            params["shared"], hh, h0, cfg,
            positions=positions, cache=attn_cache, cache_len=cache_len,
        )
        new_cache = None
        if layer_cache is not None:
            new_cache = {
                "mamba": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *mstates),
                "attn": new_attn,
            }
        return hh, new_cache

    body = _remat(body, remat)
    super_xs_cache = None if cache is None else cache["super"]
    h, new_super = jax.lax.scan(
        body, h, ({"mamba": params["super"]}, super_xs_cache), unroll=scan_unroll()
    )

    new_tail = None
    if "tail" in params:
        def tail_body(carry, xs):
            hh = carry
            pi, si = xs
            out, ns = mb.mamba2_apply(pi, hh, cfg, state=si)
            return hh + out, ns

        tail_body = _remat(tail_body, remat)
        tail_cache = None if cache is None else cache["tail"]
        h, new_tail = jax.lax.scan(
            tail_body, h, (params["tail"], tail_cache), unroll=scan_unroll()
        )

    new_cache = None
    if cache is not None:
        new_cache = {"super": new_super, "tail": new_tail}
    return h, new_cache


# ---------------------------------------------------------------------------
# xlstm stage: groups of (slstm_every-1) mLSTM + 1 sLSTM
# ---------------------------------------------------------------------------

def xlstm_stage_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    per = cfg.xlstm.slstm_every
    n_groups = cfg.n_layers // per
    assert cfg.n_layers % per == 0
    k1, k2 = jax.random.split(key)

    def group_m(k):
        ks = jax.random.split(k, per - 1)
        subs = [xl.mlstm_block_init(ks[i], cfg, dtype=dtype) for i in range(per - 1)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *subs)

    return {
        "mlstm": _stack_init(k1, n_groups, group_m),  # (G, per-1, ...)
        "slstm": _stack_init(k2, n_groups, lambda k: xl.slstm_block_init(k, cfg, dtype=dtype)),
    }


def xlstm_stage_apply(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cache: Optional[Dict] = None,
    remat: bool = False,
):
    per = cfg.xlstm.slstm_every

    def body(carry, xs):
        hh = carry
        p_m, p_s, c_m, c_s = xs["m"], xs["s"], xs["cm"], xs["cs"]
        new_m = []
        for i in range(per - 1):
            pi = jax.tree_util.tree_map(lambda a, i=i: a[i], p_m)
            si = None if c_m is None else jax.tree_util.tree_map(lambda a, i=i: a[i], c_m)
            hh, ns = xl.mlstm_block_apply(pi, hh, cfg, state=si)
            new_m.append(ns)
        hh, new_s = xl.slstm_block_apply(p_s, hh, cfg, state=c_s)
        nm = (
            None
            if new_m[0] is None
            else jax.tree_util.tree_map(lambda *xs_: jnp.stack(xs_), *new_m)
        )
        return hh, {"m": nm, "s": new_s}

    body = _remat(body, remat)
    xs = {
        "m": params["mlstm"],
        "s": params["slstm"],
        "cm": None if cache is None else cache["m"],
        "cs": None if cache is None else cache["s"],
    }
    h, new_cache = jax.lax.scan(body, h, xs, unroll=scan_unroll())
    return h, (new_cache if cache is not None else None)
