"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, strictly recurrent), composed 7:1.

mLSTM block (pre-up-projection design, xLSTM paper Fig. 10 left):
  norm -> up-proj to (x, z) at 2x width -> causal conv+silu on x ->
  headwise q,k (from conv branch), v (from x branch) -> mLSTM cell
  (ops.mlstm_parallel / recurrent step) -> group-norm -> +learnable skip of
  conv branch -> gate with silu(z) -> down-proj -> residual.

sLSTM block (post-up-projection): norm -> causal conv+silu -> 4-gate cell
with headwise recurrence (ops.slstm_scan) -> group-norm -> gated
ffn (proj_factor 4/3) -> residual.

Decode state: mLSTM (C, n, m) matrix memory — O(1) per token; sLSTM
(c, n, m, h) — O(1).  This is why xlstm runs the `long_500k` cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

from .layers import Params, causal_conv1d, dense_init, grouped_rmsnorm, rmsnorm, rmsnorm_init
from .sharding import DP, TP, shard


def _mdims(cfg: ModelConfig):
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    hd = d_in // nh
    return x, d_in, nh, hd


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    x, d_in, nh, hd = _mdims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(D, dtype),
        "w_up": dense_init(ks[0], D, 2 * d_in, dtype=dtype),
        "conv_kernel": (jax.random.normal(ks[1], (x.conv_kernel, d_in)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((d_in,), dtype),
        "w_qhw": dense_init(ks[2], nh, hd, hd, dtype=dtype),  # headwise
        "w_khw": dense_init(ks[3], nh, hd, hd, dtype=dtype),
        "w_vhw": dense_init(ks[4], nh, hd, hd, dtype=dtype),
        "w_igate": dense_init(ks[5], 3 * d_in, nh, dtype=jnp.float32, scale=0.01),
        "w_fgate": dense_init(ks[6], 3 * d_in, nh, dtype=jnp.float32, scale=0.01),
        "fgate_bias": jnp.linspace(3.0, 6.0, nh).astype(jnp.float32),
        "igate_bias": jnp.full((nh,), -10.0, jnp.float32),
        "skip": jnp.ones((d_in,), dtype),
        "gn": rmsnorm_init(d_in, dtype),
        "w_down": dense_init(ks[7], d_in, D, dtype=dtype),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int):
    x, d_in, nh, hd = _mdims(cfg)
    return {
        "conv": jnp.zeros((batch, x.conv_kernel - 1, d_in), jnp.float32),
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e9, jnp.float32),
    }


def mlstm_state_spec():
    return {"conv": (DP, None, TP), "c": (DP, TP, None, None), "n": (DP, TP, None), "m": (DP, TP)}


def _headwise(x: jnp.ndarray, w: jnp.ndarray, nh: int) -> jnp.ndarray:
    """(B,S,d_in) x (nh,hd,hd) -> (B,S,nh,hd)"""
    B, S, d_in = x.shape
    xh = x.reshape(B, S, nh, d_in // nh)
    return jnp.einsum("bshi,hij->bshj", xh, w)


def mlstm_block_apply(
    p: Params,
    h: jnp.ndarray,  # (B, S, D) residual stream
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    x, d_in, nh, hd = _mdims(cfg)
    B, S, D = h.shape

    xin = rmsnorm(h, p["norm"], eps=cfg.rms_eps)
    up = xin @ p["w_up"]
    up = shard(up, DP, None, TP)
    xb, z = up[..., :d_in], up[..., d_in:]

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(xb, p["conv_kernel"], p["conv_bias"], conv_state)
    xc = jax.nn.silu(xc)

    q = _headwise(xc, p["w_qhw"], nh)
    k = _headwise(xc, p["w_khw"], nh)
    v = _headwise(xb, p["w_vhw"], nh)

    gate_in = jnp.concatenate([q.reshape(B, S, -1), k.reshape(B, S, -1), v.reshape(B, S, -1)], axis=-1)
    ig = gate_in.astype(jnp.float32) @ p["w_igate"] + p["igate_bias"]
    fg = gate_in.astype(jnp.float32) @ p["w_fgate"] + p["fgate_bias"]

    if state is not None and S == 1:
        (c_new, n_new, m_new), out = ops.mlstm_decode_step(
            state["c"], state["n"], state["m"],
            q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0],
        )
        out = out[:, None]
        new_state = {"conv": new_conv, "c": c_new, "n": n_new, "m": m_new}
    else:
        out = ops.mlstm_parallel(q, k, v, ig, fg)
        new_state = None
        if state is not None:
            # prefill: replay recurrence to obtain final state (scan once)
            def step(carry, t):
                (c, n, m) = carry
                (c, n, m), _ = ops.mlstm_decode_step(
                    c, n, m, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t]
                )
                return (c, n, m), None

            (c_new, n_new, m_new), _ = jax.lax.scan(
                step, (state["c"], state["n"], state["m"]), jnp.arange(S)
            )
            new_state = {"conv": new_conv, "c": c_new, "n": n_new, "m": m_new}

    out = out.reshape(B, S, d_in)
    out = grouped_rmsnorm(out, p["gn"], n_groups=nh, eps=cfg.rms_eps)
    out = out + xc * p["skip"][None, None, :]
    out = out * jax.nn.silu(z)
    return h + out @ p["w_down"], new_state


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    x = cfg.xlstm
    D = cfg.d_model
    nh = cfg.n_heads
    hd = D // nh
    f = int(x.slstm_proj_factor * D)
    ks = jax.random.split(key, 6)
    return {
        "norm": rmsnorm_init(D, dtype),
        "conv_kernel": (jax.random.normal(ks[0], (x.conv_kernel, D)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((D,), dtype),
        "gates_x": dense_init(ks[1], D, nh, hd * 4, dtype=jnp.float32).reshape(D, nh, hd, 4) * 1.0,
        "gates_b": jnp.zeros((nh, hd, 4), jnp.float32)
        .at[..., 1]
        .set(3.0),  # forget-gate bias
        "r_kernel": (jax.random.normal(ks[2], (nh, hd, hd, 4)) * (hd**-0.5)).astype(jnp.float32),
        "gn": rmsnorm_init(D, dtype),
        "w_gate": dense_init(ks[3], D, f, dtype=dtype),
        "w_up": dense_init(ks[4], D, f, dtype=dtype),
        "w_down": dense_init(ks[5], f, D, dtype=dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    x = cfg.xlstm
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {
        "conv": jnp.zeros((batch, x.conv_kernel - 1, cfg.d_model), jnp.float32),
        "c": z,
        "n": z,
        "m": z - 1e9,
        "h": z,
    }


def slstm_state_spec():
    s = (DP, TP, None)
    return {"conv": (DP, None, TP), "c": s, "n": s, "m": s, "h": s}


def _slstm_cell_step(r_kernel, carry, gx_t):
    c, n, m, h = carry
    rec = jnp.einsum("bhd,hdke->bhke", h, r_kernel)
    pre = gx_t + rec
    i_t, f_t = pre[..., 0], pre[..., 1]
    z_t = jnp.tanh(pre[..., 2])
    o_t = jax.nn.sigmoid(pre[..., 3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    igate = jnp.exp(i_t - m_new)
    fgate = jnp.exp(logf + m - m_new)
    c_new = fgate * c + igate * z_t
    n_new = fgate * n + igate
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block_apply(
    p: Params,
    h: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    B, S, D = h.shape

    xin = rmsnorm(h, p["norm"], eps=cfg.rms_eps)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(xin, p["conv_kernel"], p["conv_bias"], conv_state)
    xc = jax.nn.silu(xc)

    gx = jnp.einsum("bsd,dhke->bshke", xc.astype(jnp.float32), p["gates_x"]) + p["gates_b"]

    carry0 = (
        (state["c"], state["n"], state["m"], state["h"])
        if state is not None
        else (
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.zeros((B, nh, hd), jnp.float32),
            jnp.full((B, nh, hd), -1e9, jnp.float32),
            jnp.zeros((B, nh, hd), jnp.float32),
        )
    )
    step = lambda carry, gx_t: _slstm_cell_step(p["r_kernel"], carry, gx_t)  # noqa: E731
    (c, n, m, hh), hs = jax.lax.scan(step, carry0, gx.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).reshape(B, S, D).astype(h.dtype)
    out = grouped_rmsnorm(out, p["gn"], n_groups=nh, eps=cfg.rms_eps)

    # gated FFN (proj factor 4/3)
    ff = (jax.nn.gelu(out @ p["w_gate"], approximate=True) * (out @ p["w_up"])) @ p["w_down"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "c": c, "n": n, "m": m, "h": hh}
    return h + ff, new_state
