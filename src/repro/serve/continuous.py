"""Continuous batching: a persistent slot-based decode batch.

The engine owns ONE cache of `max_batch` slots for its whole life.  Every
iteration runs a single jitted one-token decode step over all slots — live
or not — with a per-slot `cache_len` vector (the decode kernels mask
variable lengths, so prompts are never left-padded to a common length).
Finished rows are evicted immediately; freed slots are refilled at chunk
boundaries by an interleaved *prefill microbatch*: new prompts prefill
into a fresh small cache which is scattered into the persistent one with
`cache_update.insert_rows` (whole-row replacement — a new occupant can
never read its predecessor's KV).  The running batch never drains.

Shapes are jit-stable by construction: the decode step always sees
(max_batch, 1) tokens against the (max_batch, …) cache, so it compiles
exactly once; prefill compiles per (group size, bucketed prompt length).

`ContinuousEngine.run` plugs the slot machinery into the lease-driven
request plane (`serve.request_plane`): lease -> admit -> decode chunk ->
stream -> publish, with lease heartbeats and expired-lease reaping riding
the chunk cadence.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import cache_batch_axes, decode_step, init_cache, prefill
from repro.models.cache_update import insert_rows

from . import request_plane as rp
from .engine import ServeConfig, request_keys, sample_tokens


@dataclass
class Slot:
    req_id: str
    prompt_len: int
    max_new: int
    out: List[int] = field(default_factory=list)  # sampled tokens so far
    streamed: int = 0  # tokens already pushed to serve/stream/{req}
    done: bool = False
    t_admit: float = 0.0
    t_first: float = 0.0  # wall time of the first sampled token (TTFT)


class ContinuousEngine:
    """Slot-based continuous-batching engine over one persistent cache."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig) -> None:
        if cfg.family == "encdec":
            raise NotImplementedError("encdec serving needs encoder inputs per request")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[scfg.cache_dtype]
        # recurrent-state families carry prompt state, not a masked KV
        # buffer: right-pad tokens would corrupt the state, so prefill
        # microbatches group by *exact* prompt length instead of buckets.
        self._exact_len = cfg.family in ("ssm", "hybrid")

        self._decode = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l))
        self._prefill = jax.jit(
            lambda p, b, c: prefill(p, cfg, b, c, all_logits=True)
        )
        axes = cache_batch_axes(cfg, scfg.max_len, self._dtype)
        self._insert = jax.jit(
            lambda big, small, slots: jax.tree_util.tree_map(
                lambda b, s, ax: insert_rows(b, s, slots, ax), big, small, axes
            )
        )

        B = scfg.max_batch
        self.cache = init_cache(cfg, B, scfg.max_len, cache_dtype=self._dtype)
        self.cache_lens = np.zeros((B,), np.int32)
        self.tokens = np.zeros((B,), np.int32)  # next token fed per slot
        self.steps = np.zeros((B,), np.int32)  # per-request sample index
        self.keys = np.zeros((B, 2), np.uint32)  # per-request PRNG keys
        self.slots: List[Optional[Slot]] = [None] * B
        self.stats: Dict[str, int] = {
            "served": 0,
            "tokens_out": 0,
            "admissions": 0,
            "mid_batch_admissions": 0,
            "prefill_groups": 0,
            "decode_steps": 0,
        }

    # ---- slot bookkeeping ------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def n_live(self) -> int:
        return sum(s is not None for s in self.slots)

    def live_req_ids(self) -> List[str]:
        return [s.req_id for s in self.slots if s is not None]

    def _evict(self, i: int) -> None:
        self.slots[i] = None
        self.cache_lens[i] = 0
        self.tokens[i] = 0
        self.steps[i] = 0
        self.keys[i] = 0

    # ---- admission: interleaved prefill microbatch -----------------------

    def _pad_len(self, plen: int) -> int:
        if self._exact_len:
            return plen
        b = max(1, self.scfg.prefill_bucket)
        return min(-(-plen // b) * b, self.scfg.max_len - 1)

    def admit(self, requests: Sequence[Tuple[str, Sequence[int], int]]) -> int:
        """Admit requests into free slots: [(req_id, prompt, max_new), ...].

        Runs at chunk boundaries while other slots hold live decodes — the
        running batch is untouched (their rows of the persistent cache are
        not written by `insert_rows`).  Each admitted slot samples its
        first token here, from the prefill logits at its own true last
        prompt position (right-padding is invisible under causal
        attention).  Returns the number admitted."""
        free = self.free_slots()
        if len(requests) > len(free):
            raise ValueError(f"admit {len(requests)} > {len(free)} free slots")
        if not requests:
            return 0
        was_live = self.n_live() > 0
        scfg = self.scfg
        groups: Dict[int, List[Tuple[str, Sequence[int], int]]] = {}
        for req_id, prompt, max_new in requests:
            prompt = list(prompt)[: scfg.max_len - 1]  # leave room to decode
            groups.setdefault(self._pad_len(len(prompt)), []).append(
                (req_id, prompt, max_new)
            )
        for Lpad, group in groups.items():
            n = len(group)
            toks = np.zeros((n, Lpad), np.int32)
            lens = np.zeros((n,), np.int32)
            for j, (_, prompt, _) in enumerate(group):
                toks[j, : len(prompt)] = prompt
                lens[j] = len(prompt)
            small = init_cache(self.cfg, n, scfg.max_len, cache_dtype=self._dtype)
            logits_all, small, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, small
            )
            # each row's logits at its own last true token
            last = jnp.take_along_axis(
                logits_all, jnp.asarray(lens - 1)[:, None, None], axis=1
            )[:, 0]  # (n, V)
            slot_ids = [free.pop(0) for _ in group]
            self.cache = self._insert(self.cache, small, jnp.asarray(slot_ids))
            gkeys = None
            if scfg.temperature > 0:
                gkeys = request_keys([rp.request_seed(r) for r, _, _ in group])
            tok0 = np.asarray(sample_tokens(last, gkeys, 0, scfg.temperature))
            now = time.time()
            for j, (req_id, prompt, max_new) in enumerate(group):
                i = slot_ids[j]
                s = Slot(req_id, len(prompt), max_new, t_admit=now, t_first=now)
                s.out.append(int(tok0[j]))
                if (
                    len(s.out) >= max_new
                    or (scfg.eos_id >= 0 and s.out[-1] == scfg.eos_id)
                ):
                    s.done = True
                self.slots[i] = s
                self.cache_lens[i] = lens[j]
                self.tokens[i] = tok0[j]
                self.steps[i] = 1
                if gkeys is not None:
                    self.keys[i] = np.asarray(gkeys[j])
            self.stats["prefill_groups"] += 1
        self.stats["admissions"] += len(requests)
        if was_live:
            self.stats["mid_batch_admissions"] += len(requests)
        return len(requests)

    # ---- the decode chunk ------------------------------------------------

    def step_chunk(
        self, n_steps: Optional[int] = None
    ) -> Tuple[Dict[str, Slot], Dict[str, Tuple[int, List[int]]]]:
        """Run up to `n_steps` jitted decode iterations over all slots.

        Returns (finished, chunks): finished maps req_id -> its Slot
        (evicted, `out` complete); chunks maps req_id -> (offset, new
        tokens since last stream push) for every slot that progressed —
        the stream payloads for `request_plane.stream_chunks`."""
        scfg = self.scfg
        n_steps = scfg.decode_chunk if n_steps is None else n_steps
        finished: Dict[str, Slot] = {}
        touched: List[Slot] = []

        def _finish(i: int, s: Slot) -> None:
            finished[s.req_id] = s
            self.stats["served"] += 1
            self.stats["tokens_out"] += len(s.out)
            self._evict(i)

        # slots completed at admission (max_new==1 / instant eos)
        for i, s in enumerate(self.slots):
            if s is not None and s.done:
                touched.append(s)
                _finish(i, s)

        for _ in range(n_steps):
            live = [i for i, s in enumerate(self.slots) if s is not None]
            if not live:
                break
            logits, self.cache = self._decode(
                self.params,
                jnp.asarray(self.tokens[:, None]),
                self.cache,
                jnp.asarray(self.cache_lens),
            )
            self.stats["decode_steps"] += 1
            keys = jnp.asarray(self.keys) if scfg.temperature > 0 else None
            toks = np.asarray(
                sample_tokens(logits[:, 0], keys, self.steps, scfg.temperature)
            )
            for i in live:
                s = self.slots[i]
                self.cache_lens[i] += 1  # fed token now resides in the cache
                t = int(toks[i])
                s.out.append(t)
                self.steps[i] += 1
                self.tokens[i] = t
                if s not in touched:
                    touched.append(s)
                if (
                    len(s.out) >= s.max_new
                    or (scfg.eos_id >= 0 and t == scfg.eos_id)
                    or self.cache_lens[i] >= scfg.max_len - 1
                ):
                    _finish(i, s)

        chunks: Dict[str, Tuple[int, List[int]]] = {}
        for s in touched:
            new = s.out[s.streamed :]
            if new:
                chunks[s.req_id] = (s.streamed, new)
                s.streamed = len(s.out)
        return finished, chunks

    # ---- request-plane loop ----------------------------------------------

    def run(
        self,
        store,
        kv,
        *,
        engine_id: str = "engine-0",
        idle_timeout_s: float = 2.0,
        max_requests: Optional[int] = None,
        reap: bool = True,
    ) -> Dict[str, int]:
        """Serve until the queue stays empty for `idle_timeout_s` (or
        `max_requests` have been served).  Leases, heartbeats, streaming
        and publishing all ride the chunk cadence; an idle engine parks in
        `blpop` on its home queue shard and is pushed awake by a submit."""
        scfg = self.scfg
        last_beat = 0.0
        last_reap = 0.0
        idle_deadline = time.monotonic() + idle_timeout_s
        while True:
            if max_requests is not None and self.stats["served"] >= max_requests:
                break
            now = time.time()
            if reap and now - last_reap >= scfg.lease_timeout_s:
                rp.reap_expired(store, kv, n_queues=scfg.n_queues, worker=engine_id)
                last_reap = now
            free = self.free_slots()
            if free:
                wait_s = 0.0
                if self.n_live() == 0:
                    wait_s = max(0.0, min(0.5, idle_deadline - time.monotonic()))
                leased = rp.lease_requests(
                    store, kv, engine_id, len(free),
                    lease_timeout_s=scfg.lease_timeout_s,
                    wait_s=wait_s,
                    n_queues=scfg.n_queues,
                )
                if leased:
                    self.admit([
                        (r, body["prompt"], int(body.get("max_new", scfg.max_new_tokens)))
                        for r, body in leased
                    ])
            if self.n_live() == 0:
                if time.monotonic() >= idle_deadline:
                    break
                continue  # the blpop above is the idle wait — no sleep loop
            idle_deadline = time.monotonic() + idle_timeout_s

            finished, chunks = self.step_chunk()
            rp.stream_chunks(kv, chunks, worker=engine_id)
            if finished:
                t_done = time.time()
                rp.publish_results(
                    store, kv, engine_id,
                    {
                        r: {
                            "tokens": s.out,
                            "t_first": s.t_first,
                            "t_done": t_done,
                        }
                        for r, s in finished.items()
                    },
                )
            now = time.time()
            if now - last_beat >= scfg.heartbeat_interval_s:
                rp.heartbeat_leases(
                    kv, engine_id, self.live_req_ids(),
                    lease_timeout_s=scfg.lease_timeout_s,
                )
                last_beat = now
        return dict(self.stats)
