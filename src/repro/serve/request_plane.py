"""Lease-driven serving request plane over the KV (the PyWren premise:
clients and engines share only storage).

Replaces the PR-6-era `store.list("serve/req/")` scan: clients `rpush`
request ids onto a sharded queue and engines lease them with
`blpop`/`lpop_n` — watch-driven wakeups end to end, zero polling.  An
engine heartbeats a lease per in-flight request; if it is SIGKILLed the
lease lapses, a peer's `reap_expired` requeues the id, and the request is
re-served idempotently: greedy/per-request-keyed decode is deterministic,
stream chunks carry offsets so clients dedup replays, and the final
result publishes first-writer-wins.

Keyspace (KV unless noted):
  serve/q/{i}          list   request-id queue, shard ``i`` of ``n_queues``
  serve/lease/{req}    value  {"engine", "expires", "term"}
  serve/stream/{req}   list   {"off": o, "toks": [...]} chunks, then
                              a {"done": total} terminator (advisory
                              ``rpush_nowait`` — the result record below
                              is the authoritative completion signal)
  serve/req/{req}      store  {"prompt": [...], "ts": ..., "max_new": ...}
  serve/done/{req}     store  {"tokens": [...]} — first-writer-wins
"""

from __future__ import annotations

import time
import zlib
from functools import partial
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.storage import DELETE, kv_pure

QUEUE_PREFIX = "serve/q/"
LEASE_PREFIX = "serve/lease/"
STREAM_PREFIX = "serve/stream/"
REQ_PREFIX = "serve/req/"
DONE_PREFIX = "serve/done/"


def request_seed(req_id: str) -> int:
    """Deterministic per-request sampling seed (satellite fix for the
    fixed-PRNGKey engine): same request id -> same stream, which is what
    makes a SIGKILLed engine's re-serve byte-identical at temperature>0."""
    return zlib.crc32(req_id.encode("utf-8"))


def queue_key(i: int) -> str:
    return f"{QUEUE_PREFIX}{i}"


def queue_of(req_id: str, n_queues: int) -> int:
    return zlib.crc32(req_id.encode("utf-8")) % max(1, n_queues)


def lease_key(req_id: str) -> str:
    return f"{LEASE_PREFIX}{req_id}"


def stream_key(req_id: str) -> str:
    return f"{STREAM_PREFIX}{req_id}"


def req_key(req_id: str) -> str:
    return f"{REQ_PREFIX}{req_id}"


def done_key(req_id: str) -> str:
    return f"{DONE_PREFIX}{req_id}"


# ---------------------------------------------------------------------------
# client side
# ---------------------------------------------------------------------------

def submit(
    store,
    kv,
    req_id: str,
    prompt: Sequence[int],
    *,
    max_new_tokens: Optional[int] = None,
    n_queues: int = 1,
    worker: str = "client",
) -> str:
    """Write the request body, then enqueue the id (body-before-id means a
    leased id always has a readable body).  Returns the result key."""
    body: Dict[str, Any] = {"prompt": list(prompt), "ts": time.time()}
    if max_new_tokens is not None:
        body["max_new"] = int(max_new_tokens)
    store.put(req_key(req_id), body, worker=worker)
    kv.rpush(queue_key(queue_of(req_id, n_queues)), req_id, worker=worker)
    return done_key(req_id)


def submit_many(
    store,
    kv,
    requests: Dict[str, Sequence[int]],
    *,
    n_queues: int = 1,
    worker: str = "client",
) -> List[str]:
    """Batched submit: one store round-trip for every body, one KV
    round-trip per queue shard touched (each shard's blocked engines wake
    once for the whole batch)."""
    now = time.time()
    store.put_many(
        {req_key(r): {"prompt": list(p), "ts": now} for r, p in requests.items()},
        worker=worker,
    )
    pushes: Dict[str, List[Any]] = {}
    for r in requests:
        pushes.setdefault(queue_key(queue_of(r, n_queues)), []).append(r)
    kv.rpush_many(pushes, worker=worker)
    return [done_key(r) for r in requests]


def stream_result(
    store,
    kv,
    req_id: str,
    *,
    timeout_s: float = 60.0,
    worker: str = "client",
) -> Iterator[List[int]]:
    """Yield token chunks as the engine streams them, deduping replays.

    Chunks are offset-tagged, so a re-serving engine restarting the stream
    at offset 0 (after its predecessor was SIGKILLed) yields nothing the
    client has already seen — decode is deterministic per request, so the
    replayed prefix is byte-identical.  Terminates on the {"done": n}
    marker; since that marker is advisory (``rpush_nowait``), the
    authoritative result record is consulted as a fallback before timing
    out, and any tail the stream never carried is yielded from it."""
    skey, dkey = stream_key(req_id), done_key(req_id)
    deadline = time.monotonic() + timeout_s
    seen = 0  # tokens already yielded
    while True:
        seq = kv.shard_seq(skey)
        total: Optional[int] = None
        for chunk in kv.lrange(skey, worker=worker):
            if "done" in chunk:
                total = int(chunk["done"])
                continue
            off, toks = int(chunk["off"]), list(chunk["toks"])
            if off + len(toks) <= seen:
                continue  # replayed prefix
            fresh = toks[max(0, seen - off):]
            seen = off + len(toks)
            yield fresh
        if total is not None and seen >= total:
            return
        remaining = deadline - time.monotonic()
        if remaining <= 0 or total is not None:
            break  # done-marker with missing chunks, or timed out
        # event-driven wait for the next stream append (bounded slices so
        # the done-record fallback below stays reachable even if every
        # advisory stream append was dropped on a reconnect window).
        kv.wait_key(skey, seq, min(remaining, 1.0))
    # fall back to the authoritative result record (at most once per stream)
    try:
        store.wait_keys([dkey], timeout_s=max(0.05, deadline - time.monotonic()))
    except TimeoutError:
        raise TimeoutError(f"stream {req_id!r}: no result within {timeout_s}s")
    toks = store.get(dkey, worker=worker)["tokens"]
    if len(toks) > seen:
        yield toks[seen:]


def get_results(
    store,
    req_ids: Sequence[str],
    *,
    timeout_s: float = 60.0,
    worker: str = "client",
) -> Dict[str, Any]:
    """Block until every request's result record exists; one batched wait +
    one batched read."""
    keys = [done_key(r) for r in req_ids]
    store.wait_keys(keys, timeout_s=timeout_s)
    got = store.get_many(keys, worker=worker, missing="error")
    return {r: got[done_key(r)] for r in req_ids}


# ---------------------------------------------------------------------------
# engine side: leases (fenced, kv_pure — pickle-by-reference on the wire)
# ---------------------------------------------------------------------------

@kv_pure
def _lease_take(engine: str, now: float, expires: float, cur):
    """First-writer-wins within the expiry window; a lapsed lease is won at
    term+1 (the re-serve is a new term of the same request)."""
    if cur is not None and float(cur["expires"]) > now and cur["engine"] != engine:
        return cur  # live foreign lease: lose
    term = int(cur["term"]) + 1 if cur is not None else 1
    return {"engine": engine, "expires": expires, "term": term}


@kv_pure
def _lease_extend(engine: str, expires: float, cur):
    if cur is None:
        return DELETE  # released/reaped meanwhile: stay absent
    if cur["engine"] != engine:
        return cur  # stolen: do not revive
    return {**cur, "expires": expires}


@kv_pure
def _lease_free(engine: str, cur):
    if cur is None:
        return DELETE
    if cur["engine"] != engine:
        return cur  # not ours anymore
    return DELETE


@kv_pure
def _lease_reap(now: float, out: Dict[str, Any], cur):
    if cur is None:
        return DELETE  # already released
    if cur.get("requeued"):
        return cur  # a peer already requeued it; it awaits re-lease
    if float(cur["expires"]) > now:
        return cur  # revived by a heartbeat since we looked
    out["rec"] = cur
    # tombstone, not DELETE: concurrent reapers requeue exactly once, and
    # the term survives so the re-serving engine takes term+1.
    return {**cur, "expires": 0.0, "requeued": True}


def lease_requests(
    store,
    kv,
    engine_id: str,
    max_n: int,
    *,
    lease_timeout_s: float = 2.0,
    wait_s: float = 0.0,
    n_queues: int = 1,
) -> List[Tuple[str, Dict[str, Any]]]:
    """Pop up to ``max_n`` request ids off the queue shards and fence them.

    ``wait_s > 0`` blocks on the engine's home shard via ``blpop`` when the
    queues are empty — the idle engine parks on the KV watch condition and
    is *pushed* awake by a client's rpush (EVENT001: no sleep loop).  Ids
    whose result already exists are dropped (consumed, not requeued); ids
    whose lease is held live by another engine are dropped likewise.
    Returns [(req_id, body), ...] for the requests this engine now owns."""
    home = queue_of(engine_id, n_queues)
    order = [(home + j) % n_queues for j in range(n_queues)]
    ids: List[str] = []
    for qi in order:
        if len(ids) >= max_n:
            break
        ids.extend(kv.lpop_n(queue_key(qi), max_n - len(ids), worker=engine_id))
    if not ids and wait_s > 0:
        got = kv.blpop(queue_key(home), wait_s, worker=engine_id)
        if got is not None:
            ids = [got]
            ids.extend(kv.lpop_n(queue_key(home), max_n - 1, worker=engine_id))
    ids = list(dict.fromkeys(ids))
    if not ids:
        return []
    served = store.exists_many([done_key(r) for r in ids], worker=engine_id)
    live = [r for r in ids if done_key(r) not in served]
    if not live:
        return []
    now = time.time()
    expires = now + lease_timeout_s
    res = kv.eval_many(
        {lease_key(r): partial(_lease_take, engine_id, now, expires) for r in live},
        worker=engine_id,
    )
    won = [
        r for r in live
        if res[lease_key(r)]["engine"] == engine_id
        and float(res[lease_key(r)]["expires"]) >= expires
    ]
    if not won:
        return []
    bodies = store.get_many([req_key(r) for r in won], worker=engine_id, missing="error")
    return [(r, bodies[req_key(r)]) for r in won]


def heartbeat_leases(
    kv,
    engine_id: str,
    req_ids: Sequence[str],
    *,
    lease_timeout_s: float = 2.0,
) -> None:
    """Extend every in-flight lease in one batched eval."""
    if not req_ids:
        return
    expires = time.time() + lease_timeout_s
    kv.eval_many(
        {lease_key(r): partial(_lease_extend, engine_id, expires) for r in req_ids},
        worker=engine_id,
    )


def release_leases(kv, engine_id: str, req_ids: Sequence[str]) -> None:
    if not req_ids:
        return
    kv.eval_many(
        {lease_key(r): partial(_lease_free, engine_id) for r in req_ids},
        worker=engine_id,
    )


def reap_expired(
    store,
    kv,
    *,
    n_queues: int = 1,
    now: Optional[float] = None,
    worker: str = "reaper",
) -> int:
    """Requeue every request whose lease has lapsed (its engine died
    mid-serve).  The expired-compare-then-DELETE runs atomically per key,
    so concurrent reapers requeue each request exactly once; requests
    whose result landed before the reap are dropped instead of requeued.
    Returns the number requeued."""
    now = time.time() if now is None else now
    keys = kv.scan(LEASE_PREFIX, worker=worker)
    if not keys:
        return 0
    recs = kv.mget(keys, worker=worker)
    expired = {
        k[len(LEASE_PREFIX):]: rec
        for k, rec in zip(keys, recs)
        if rec is not None and float(rec["expires"]) <= now
    }
    if not expired:
        return 0
    served = store.exists_many([done_key(r) for r in expired], worker=worker)
    finished = [r for r in expired if done_key(r) in served]
    if finished:
        # lapsed leases of already-published requests (incl. tombstones a
        # done-filter consumed): drop the record, nothing to requeue
        kv.eval_many(
            {lease_key(r): partial(_lease_free, expired[r]["engine"]) for r in finished},
            worker=worker,
        )
    stale = [
        r for r in expired
        if done_key(r) not in served and not expired[r].get("requeued")
    ]
    if not stale:
        return 0
    outs: Dict[str, Dict[str, Any]] = {r: {} for r in stale}
    kv.eval_many(
        {lease_key(r): partial(_lease_reap, now, outs[r]) for r in stale},
        worker=worker,
    )
    requeue = [r for r in stale if "rec" in outs[r]]
    if requeue:
        pushes: Dict[str, List[Any]] = {}
        for r in requeue:
            pushes.setdefault(queue_key(queue_of(r, n_queues)), []).append(r)
        kv.rpush_many(pushes, worker=worker)
    return len(requeue)


# ---------------------------------------------------------------------------
# engine side: streaming + publish
# ---------------------------------------------------------------------------

def stream_chunks(kv, chunks: Dict[str, Tuple[int, List[int]]], *, worker: str) -> None:
    """Push one offset-tagged chunk per request — a single batched append
    (one round-trip / one wake per KV shard touched), so streaming N live
    slots does not cost N round-trips per chunk boundary."""
    if not chunks:
        return
    kv.rpush_many(
        {stream_key(r): [{"off": off, "toks": toks}] for r, (off, toks) in chunks.items()},
        worker=worker,
    )


def publish_results(
    store,
    kv,
    engine_id: str,
    results: Dict[str, Dict[str, Any]],
) -> None:
    """Finish a set of requests (each record carries at least "tokens"):
    results land first-writer-wins (a zombie predecessor's identical
    replay is silently discarded), the advisory done-markers ride
    fire-and-forget appends, and the leases drop."""
    if not results:
        return
    store.put_many(
        {done_key(r): {**rec, "engine": engine_id} for r, rec in results.items()},
        worker=engine_id,
        if_absent=True,
    )
    for r, rec in results.items():
        kv.rpush_nowait(stream_key(r), {"done": len(rec["tokens"])}, worker=engine_id)
    release_leases(kv, engine_id, list(results))
