"""Batched serving engine: prefill + decode with KV-cache management.

Requests flow through the object store (PyWren style): clients `submit`
prompts as objects; the engine leases batches, prefills, decodes with a
jitted single-token step, and publishes results atomically.  The engine
itself is a stateless function over (model version, request batch): kill it
mid-stream and a restart re-serves the batch idempotently.

Serving modes:
  * `generate`: greedy/temperature sampling for N steps (batch-synchronous
    continuous batching-lite: finished rows are masked, new rows join at
    chunk boundaries);
  * `serve_step` export for the dry-run: the one-token decode step lowered
    at (arch x decode shape).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from repro.storage import ObjectStore


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    cache_dtype: str = "float32"
    eos_id: int = -1  # -1 = never stop early
    # ---- continuous batching / request plane (serve.continuous) ----
    decode_chunk: int = 8  # decode steps between admission boundaries
    prefill_bucket: int = 16  # right-pad prompts up to a multiple of this
    n_queues: int = 1  # request-queue shards (serve/q/{i})
    lease_timeout_s: float = 2.0
    heartbeat_interval_s: float = 0.5


def sample_tokens(
    logits: jnp.ndarray,  # (B, V)
    keys: Optional[jnp.ndarray],  # (B, 2) uint32 per-request PRNG keys
    steps,  # scalar or (B,) int32: per-request decode step index
    temperature: float,
) -> jnp.ndarray:
    """Per-row sampling: row i draws from fold_in(keys[i], steps[i]).

    Keying by (request, step) — not by engine-global state — is what makes
    sampling deterministic per request, independent across requests, and
    invariant to batch composition: the same request produces the same
    stream whether it decodes alone, in a full batch, or on the engine
    that re-serves it after a SIGKILL."""
    if temperature <= 0 or keys is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    B = logits.shape[0]
    steps = jnp.broadcast_to(jnp.asarray(steps, jnp.uint32), (B,))

    def one(k, s, row):
        return jax.random.categorical(jax.random.fold_in(k, s), row / temperature)

    return jax.vmap(one)(keys, steps, logits).astype(jnp.int32)


def request_keys(seeds) -> jnp.ndarray:
    """(B, 2) uint32 key array from per-request integer seeds."""
    return jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds]))


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig) -> None:
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l))
        self._prefill = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))

    # ---- batch generation ------------------------------------------------
    def generate(
        self,
        prompts: jnp.ndarray,
        extras: Optional[Dict[str, jnp.ndarray]] = None,
        *,
        seeds: Optional[List[int]] = None,
    ) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, max_new_tokens) int32.

        ``seeds`` (one per row, e.g. `request_plane.request_seed(req_id)`)
        key the sampling stream per request: deterministic per request,
        independent across requests.  Default `range(B)` — previously every
        row of every batch shared one fixed PRNGKey(0) stream."""
        B, S = prompts.shape
        scfg = self.scfg
        dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[scfg.cache_dtype]
        cache = init_cache(self.cfg, B, scfg.max_len, cache_dtype=dtype)
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, cache, clen = self._prefill(self.params, batch, cache)

        keys = None
        if scfg.temperature > 0:
            keys = request_keys(range(B) if seeds is None else seeds)
        out = np.zeros((B, scfg.max_new_tokens), np.int32)
        done = np.zeros((B,), bool)
        tok = sample_tokens(logits[:, -1], keys, 0, scfg.temperature)
        for t in range(scfg.max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if scfg.eos_id >= 0:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
            logits, cache = self._decode(self.params, tok[:, None], cache, clen)
            clen = clen + 1
            tok = sample_tokens(logits[:, 0], keys, t + 1, scfg.temperature)
        return out


# ---------------------------------------------------------------------------
# storage-mediated request plane (the PyWren pattern)
# ---------------------------------------------------------------------------

def submit_request(store: ObjectStore, req_id: str, prompt: List[int]) -> str:
    key = f"serve/req/{req_id}"
    store.put(key, {"prompt": prompt, "ts": time.time()})
    return key


def serve_pending(
    store: ObjectStore, engine: Engine, *, batch_size: int = 8, worker: str = "engine"
) -> int:
    """Lease pending requests, serve a batch, publish results atomically.
    Returns number served.  Idempotent: results publish with put_if_absent.

    Batched control plane end to end: one list + one ``exists_many``
    filters out already-served requests, one ``get_many`` fetches the
    batch, and the whole result set publishes in one
    ``put_many(if_absent=True)`` — per-key first-writer-wins semantics
    are unchanged, but N requests cost a handful of amortized
    round-trips instead of ~3N."""
    def _done_key(k: str) -> str:
        return k.replace("serve/req/", "serve/done/")

    all_reqs = store.list("serve/req/", worker=worker)
    served = store.exists_many([_done_key(k) for k in all_reqs], worker=worker)
    req_keys = [k for k in all_reqs if _done_key(k) not in served][:batch_size]
    if not req_keys:
        return 0
    got = store.get_many(req_keys, worker=worker, missing="error")
    reqs = [got[k] for k in req_keys]
    maxlen = max(len(r["prompt"]) for r in reqs)
    prompts = np.zeros((len(reqs), maxlen), np.int32)
    for i, r in enumerate(reqs):
        prompts[i, maxlen - len(r["prompt"]):] = r["prompt"]  # left-pad
    out = engine.generate(jnp.asarray(prompts))
    store.put_many(
        {_done_key(k): {"tokens": out[i].tolist()} for i, k in enumerate(req_keys)},
        worker=worker,
        if_absent=True,
    )
    return len(reqs)
