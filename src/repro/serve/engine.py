"""Batched serving engine: prefill + decode with KV-cache management.

Requests flow through the object store (PyWren style): clients `submit`
prompts as objects; the engine leases batches, prefills, decodes with a
jitted single-token step, and publishes results atomically.  The engine
itself is a stateless function over (model version, request batch): kill it
mid-stream and a restart re-serves the batch idempotently.

Serving modes:
  * `generate`: greedy/temperature sampling for N steps (batch-synchronous
    continuous batching-lite: finished rows are masked, new rows join at
    chunk boundaries);
  * `serve_step` export for the dry-run: the one-token decode step lowered
    at (arch x decode shape).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from repro.storage import ObjectStore


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    cache_dtype: str = "float32"
    eos_id: int = -1  # -1 = never stop early


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig) -> None:
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(lambda p, t, c, l: decode_step(p, cfg, t, c, l))
        self._prefill = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))

    # ---- batch generation ------------------------------------------------
    def generate(
        self, prompts: jnp.ndarray, extras: Optional[Dict[str, jnp.ndarray]] = None
    ) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, max_new_tokens) int32."""
        B, S = prompts.shape
        scfg = self.scfg
        dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[scfg.cache_dtype]
        cache = init_cache(self.cfg, B, scfg.max_len, cache_dtype=dtype)
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, cache, clen = self._prefill(self.params, batch, cache)

        out = np.zeros((B, scfg.max_new_tokens), np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits[:, -1])
        key = jax.random.PRNGKey(0)
        for t in range(scfg.max_new_tokens):
            out[:, t] = np.where(done, 0, np.asarray(tok))
            if scfg.eos_id >= 0:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
            logits, cache = self._decode(self.params, tok[:, None], cache, clen)
            clen = clen + 1
            key = jax.random.fold_in(key, t)
            tok = self._sample(logits[:, 0], key)
        return out

    def _sample(self, logits: jnp.ndarray, key=None) -> jnp.ndarray:
        if self.scfg.temperature <= 0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)


# ---------------------------------------------------------------------------
# storage-mediated request plane (the PyWren pattern)
# ---------------------------------------------------------------------------

def submit_request(store: ObjectStore, req_id: str, prompt: List[int]) -> str:
    key = f"serve/req/{req_id}"
    store.put(key, {"prompt": prompt, "ts": time.time()})
    return key


def serve_pending(
    store: ObjectStore, engine: Engine, *, batch_size: int = 8, worker: str = "engine"
) -> int:
    """Lease pending requests, serve a batch, publish results atomically.
    Returns number served.  Idempotent: results publish with put_if_absent.

    Batched control plane end to end: one list + one ``exists_many``
    filters out already-served requests, one ``get_many`` fetches the
    batch, and the whole result set publishes in one
    ``put_many(if_absent=True)`` — per-key first-writer-wins semantics
    are unchanged, but N requests cost a handful of amortized
    round-trips instead of ~3N."""
    def _done_key(k: str) -> str:
        return k.replace("serve/req/", "serve/done/")

    all_reqs = store.list("serve/req/", worker=worker)
    served = store.exists_many([_done_key(k) for k in all_reqs], worker=worker)
    req_keys = [k for k in all_reqs if _done_key(k) not in served][:batch_size]
    if not req_keys:
        return 0
    got = store.get_many(req_keys, worker=worker, missing="error")
    reqs = [got[k] for k in req_keys]
    maxlen = max(len(r["prompt"]) for r in reqs)
    prompts = np.zeros((len(reqs), maxlen), np.int32)
    for i, r in enumerate(reqs):
        prompts[i, maxlen - len(r["prompt"]):] = r["prompt"]  # left-pad
    out = engine.generate(jnp.asarray(prompts))
    store.put_many(
        {_done_key(k): {"tokens": out[i].tolist()} for i, k in enumerate(req_keys)},
        worker=worker,
        if_absent=True,
    )
    return len(reqs)
