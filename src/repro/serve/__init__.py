"""Serving substrate: batched engine + storage-mediated request plane."""

from .engine import Engine, ServeConfig, serve_pending, submit_request

__all__ = ["Engine", "ServeConfig", "serve_pending", "submit_request"]
