"""Serving substrate: batched engine, continuous batching, request plane."""

from . import request_plane
from .continuous import ContinuousEngine, Slot
from .engine import Engine, ServeConfig, sample_tokens, serve_pending, submit_request

__all__ = [
    "ContinuousEngine",
    "Engine",
    "ServeConfig",
    "Slot",
    "request_plane",
    "sample_tokens",
    "serve_pending",
    "submit_request",
]
