"""repro: PyWren ("Occupy the Cloud") as a production JAX framework.

Subpackages: core (serverless runtime), storage (object/KV stores), models,
kernels (Pallas TPU), train, serve, data, configs, launch, analysis.
"""

__version__ = "1.0.0"
