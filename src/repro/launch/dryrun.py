import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_REMAT_POLICY", "nothing")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell we
build ShapeDtypeStruct stand-ins (zero allocation), jit with explicit
in_shardings from the rule trees, .lower().compile() against the production
mesh, and record memory_analysis / cost_analysis / parsed collective bytes
into reports/dryrun/<cell>.json for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only|--single-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --skip-done   # resume
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs import CONFIGS, applicable_shapes
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import batch_pspec, cache_pspec, state_pspec, to_shardings
from repro.models import decode_step, init_cache, init_params, prefill
from repro.train import adamw, make_train_step
from repro.train.train_step import TrainState

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def _sds(tree, shardings=None):
    if shardings is None:
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
    return jax.tree_util.tree_map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), tree, shardings
    )


def shape_adjusted_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Per-shape config tweaks that only affect table sizes, not structure."""
    kw: Dict[str, Any] = {}
    if cfg.pos_embedding == "learned" and shape.seq_len + 1 > cfg.max_target_positions:
        kw["max_target_positions"] = shape.seq_len + 1
    if cfg.moe is not None:
        # bound dispatch-tensor memory: small groups at scale
        gs = 512 if cfg.moe.num_experts >= 128 else 2048
        kw["moe"] = dataclasses.replace(cfg.moe, group_size=gs)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """ShapeDtypeStructs + shardings for every model input of this cell."""
    B = shape.global_batch
    S = shape.seq_len
    dt = jnp.bfloat16
    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        S_text = S - (cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        if cfg.frontend == "vision_stub":
            batch["prefix_embed"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), dt
            )
        if cfg.family == "encdec":
            batch["audio_frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    elif shape.kind == "prefill":
        S_text = S - (cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        if cfg.frontend == "vision_stub":
            batch["prefix_embed"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), dt
            )
        if cfg.family == "encdec":
            batch["audio_frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    else:  # decode / long_decode
        batch["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    specs = batch_pspec(mesh, batch)
    return _sds(batch, to_shardings(mesh, specs))


def _state_structs(cfg: ModelConfig, mesh, *, moment_dtype=jnp.bfloat16):
    opt = adamw(1e-4, moment_dtype=moment_dtype)

    def make():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return TrainState(params=params, opt_state=opt.init(params))

    state_shapes = jax.eval_shape(make)
    pspec = state_pspec(mesh, state_shapes)
    return _sds(state_shapes, to_shardings(mesh, pspec)), opt


def _param_structs(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspec = state_pspec(mesh, shapes)
    return _sds(shapes, to_shardings(mesh, pspec))


def _cache_structs(cfg: ModelConfig, mesh, batch: int, max_len: int, *, with_cross: bool):
    def make():
        c = init_cache(cfg, batch, max_len, cache_dtype=jnp.bfloat16)
        if with_cross and cfg.family == "encdec":
            K, hd = cfg.n_kv_heads, cfg.hd
            cross = {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, K, hd), jnp.bfloat16),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, K, hd), jnp.bfloat16),
            }
            c["decoder"] = {"self": c["decoder"]["self"], "cross": cross}
        return c

    shapes = jax.eval_shape(make)
    pspec = cache_pspec(mesh, cfg, shapes)
    return _sds(shapes, to_shardings(mesh, pspec))


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatches: int = 1,
    remat: bool = True,
    moe_group: Optional[int] = None,
) -> Tuple[Any, Any, ModelConfig, ShapeSpec]:
    """Returns (lowered, compiled, cfg, shape)."""
    from repro.configs.base import SHAPES

    cfg0 = CONFIGS[arch]
    shape = SHAPES[shape_name]
    cfg = shape_adjusted_config(cfg0, shape)
    if moe_group is not None and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, group_size=moe_group))
    mesh = make_production_mesh(multi_pod=multi_pod)

    with mesh:
        batch_structs = input_specs(cfg, shape, mesh)
        if shape.kind == "train":
            state_structs, opt = _state_structs(cfg, mesh)
            step = make_train_step(cfg, opt, remat=remat, microbatches=microbatches)
            jitted = jax.jit(step, donate_argnums=(0,))
            lowered = jitted.lower(state_structs, batch_structs)
        elif shape.kind == "prefill":
            params_structs = _param_structs(cfg, mesh)
            cache_structs = _cache_structs(
                cfg, mesh, shape.global_batch, shape.seq_len, with_cross=False
            )
            fn = lambda p, b, c: prefill(p, cfg, b, c)  # noqa: E731
            jitted = jax.jit(fn, donate_argnums=(2,))
            lowered = jitted.lower(params_structs, batch_structs, cache_structs)
        else:  # decode / long_decode
            params_structs = _param_structs(cfg, mesh)
            cache_structs = _cache_structs(
                cfg, mesh, shape.global_batch, shape.seq_len, with_cross=True
            )
            fn = lambda p, t, c, l: decode_step(p, cfg, t, c, l)  # noqa: E731
            jitted = jax.jit(fn, donate_argnums=(2,))
            lowered = jitted.lower(
                params_structs,
                batch_structs["tokens"],
                cache_structs,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        compiled = lowered.compile()
    return lowered, compiled, cfg, shape


# ---------------------------------------------------------------------------
# depth-probe cost extraction
#
# XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
# so a rolled layer-scan undercounts FLOPs/collectives by ~n_layers; a fully
# unrolled compile counts correctly but is too slow for 126-layer models and
# degrades buffer-reuse stats.  Instead: compile the FULL model rolled (the
# production program — memory stats + compile proof) plus a few *small
# unrolled depth probes*; per-stage layer costs follow from a linear solve
#     cost(probe) = outside + sum_i counts_i * body_i
# and total = outside + sum_i full_counts_i * body_i.  Exact for homogeneous
# stages (every layer in a stage lowers identically).
# ---------------------------------------------------------------------------

def probe_plans(cfg: ModelConfig):
    """Returns (probes, full_counts): probes = [(cfg_variant, counts)], where
    counts maps stage name -> #stage-units in that variant."""
    import dataclasses as dc

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p = cfg.global_every if (cfg.sliding_window and cfg.global_every) else 1
        return (
            [
                (dc.replace(cfg, n_layers=p), {"dec": 1}),
                (dc.replace(cfg, n_layers=2 * p), {"dec": 2}),
            ],
            {"dec": cfg.n_layers // p},
        )
    if fam == "moe":
        nd = cfg.moe.num_dense_layers
        if nd == 0:
            return (
                [
                    (dc.replace(cfg, n_layers=1), {"moe": 1}),
                    (dc.replace(cfg, n_layers=2), {"moe": 2}),
                ],
                {"moe": cfg.n_layers},
            )
        m1 = dc.replace(cfg.moe, num_dense_layers=1)
        m2 = dc.replace(cfg.moe, num_dense_layers=2)
        return (
            [
                (dc.replace(cfg, n_layers=2, moe=m1), {"dense": 1, "moe": 1}),
                (dc.replace(cfg, n_layers=3, moe=m2), {"dense": 2, "moe": 1}),
                (dc.replace(cfg, n_layers=3, moe=m1), {"dense": 1, "moe": 2}),
            ],
            {"dense": nd, "moe": cfg.n_layers - nd},
        )
    if fam == "hybrid":
        per = cfg.shared_attn_every
        n_super = cfg.n_layers // per
        n_tail = cfg.n_layers - n_super * per
        probes = [
            (dc.replace(cfg, n_layers=per + 2), {"super": 1, "tail": 2}),
            (dc.replace(cfg, n_layers=2 * per + 2), {"super": 2, "tail": 2}),
            (dc.replace(cfg, n_layers=per + 4), {"super": 1, "tail": 4}),
        ]
        return probes, {"super": n_super, "tail": n_tail}
    if fam == "ssm":
        per = cfg.xlstm.slstm_every
        return (
            [
                (dc.replace(cfg, n_layers=per), {"group": 1}),
                (dc.replace(cfg, n_layers=2 * per), {"group": 2}),
            ],
            {"group": cfg.n_layers // per},
        )
    if fam == "encdec":
        return (
            [
                (dc.replace(cfg, n_layers=1, n_encoder_layers=1), {"enc": 1, "dec": 1}),
                (dc.replace(cfg, n_layers=1, n_encoder_layers=2), {"enc": 2, "dec": 1}),
                (dc.replace(cfg, n_layers=2, n_encoder_layers=1), {"enc": 1, "dec": 2}),
            ],
            {"enc": cfg.n_encoder_layers, "dec": cfg.n_layers},
        )
    raise ValueError(fam)


def _lower_variant(
    cfg: ModelConfig, shape: ShapeSpec, mesh, *, microbatches=1, remat=True, compile=True
):
    """Lower (and optionally compile) one config variant for the given shape."""
    with mesh:
        batch_structs = input_specs(cfg, shape, mesh)
        if shape.kind == "train":
            state_structs, opt = _state_structs(cfg, mesh)
            step = make_train_step(cfg, opt, remat=remat, microbatches=microbatches)
            jitted = jax.jit(step, donate_argnums=(0,))
            lowered = jitted.lower(state_structs, batch_structs)
        elif shape.kind == "prefill":
            params_structs = _param_structs(cfg, mesh)
            cache_structs = _cache_structs(
                cfg, mesh, shape.global_batch, shape.seq_len, with_cross=False
            )
            fn = lambda p, b, c: prefill(p, cfg, b, c)  # noqa: E731
            jitted = jax.jit(fn, donate_argnums=(2,))
            lowered = jitted.lower(params_structs, batch_structs, cache_structs)
        else:
            params_structs = _param_structs(cfg, mesh)
            cache_structs = _cache_structs(
                cfg, mesh, shape.global_batch, shape.seq_len, with_cross=True
            )
            fn = lambda p, t, c, l: decode_step(p, cfg, t, c, l)  # noqa: E731
            jitted = jax.jit(fn, donate_argnums=(2,))
            lowered = jitted.lower(
                params_structs,
                batch_structs["tokens"],
                cache_structs,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        return lowered, (lowered.compile() if compile else None)


def _probe_metrics(variant, shape, mesh, n_dev: int, **lower_kw) -> Dict[str, float]:
    """Per-probe metrics via two lowerings:
      A) fully unrolled (layers + inner kernel scans), *lowered only* —
         cost_analysis on the unpartitioned module counts every layer and
         every kernel-scan iteration; global values are divided by n_dev;
      B) layer-unrolled / inner-rolled, *compiled* — small graph, fast CPU
         codegen; the partitioned HLO text yields collective wire bytes
         (inner kernel scans contain no collectives)."""
    os.environ["REPRO_SCAN_UNROLL"] = "full"
    os.environ["REPRO_INNER_UNROLL"] = "full"
    lowered, _ = _lower_variant(variant, shape, mesh, compile=False, **lower_kw)
    cost = lowered.cost_analysis() or {}
    out = {
        "flops": float(cost.get("flops", 0.0)) / n_dev,
        "bytes": float(cost.get("bytes accessed", 0.0)) / n_dev,
    }
    os.environ["REPRO_INNER_UNROLL"] = "1"
    _, compiled = _lower_variant(variant, shape, mesh, compile=True, **lower_kw)
    colls = rl.parse_collectives(compiled.as_text(), n_dev)
    out["coll"] = colls.wire_bytes
    for op, v in colls.by_op.items():
        out[f"coll_{op}"] = v
    return out


def solve_stage_costs(
    probe_counts, probe_metrics, full_counts
) -> Dict[str, float]:
    """Least-squares solve cost = outside + sum_i counts_i*body_i, then
    extrapolate to full depth.  Returns totals per metric key."""
    stages = sorted(full_counts)
    keys = sorted({k for m in probe_metrics for k in m})
    A = np.array(
        [[1.0] + [float(c.get(s, 0)) for s in stages] for c in probe_counts]
    )
    totals: Dict[str, float] = {}
    for key in keys:
        b = np.array([m.get(key, 0.0) for m in probe_metrics])
        x, *_ = np.linalg.lstsq(A, b, rcond=None)
        outside = max(x[0], 0.0)
        bodies = {s: max(x[1 + i], 0.0) for i, s in enumerate(stages)}
        totals[key] = outside + sum(full_counts[s] * bodies[s] for s in stages)
    return totals


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool, **kw) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = 512 if multi_pod else 256

    # 1) full model, rolled scans: the production compile (memory + proof)
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    t0 = time.time()
    lowered, compiled, cfg, shape = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    rolled_cost = compiled.cost_analysis() or {}
    hlo_lines = compiled.as_text().count("\n")

    # 2) depth probes: exact per-stage costs (see _probe_metrics)
    mesh = make_production_mesh(multi_pod=multi_pod)
    probes, full_counts = probe_plans(cfg)
    probe_counts, probe_mets = [], []
    t1 = time.time()
    probe_kw = {k: v for k, v in kw.items() if k in ("microbatches", "remat")}
    for variant, counts in probes:
        probe_counts.append(counts)
        probe_mets.append(_probe_metrics(variant, shape, mesh, n_dev, **probe_kw))
    probe_s = time.time() - t1
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    os.environ["REPRO_INNER_UNROLL"] = "1"
    totals = solve_stage_costs(probe_counts, probe_mets, full_counts)

    colls_by_op = {
        k[len("coll_"):]: v for k, v in totals.items() if k.startswith("coll_")
    }
    cost = {"flops": totals["flops"], "bytes accessed": totals["bytes"]}
    coll_total = totals["coll"]

    total_p, active_p = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind in ("train", "prefill") else 1)
    mf = rl.model_flops_per_step(total_p, active_p, tokens, "train" if shape.kind == "train" else "serve")

    roof = rl.Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        n_devices=n_dev,
        hlo_flops_per_device=float(cost.get("flops", 0.0)),
        hlo_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=coll_total,
        model_flops=mf,
        collective_by_op=colls_by_op,
        collective_counts={},
        memory_stats={
            "argument_bytes": mem.argument_size_in_bytes if mem else -1,
            "output_bytes": mem.output_size_in_bytes if mem else -1,
            "temp_bytes": mem.temp_size_in_bytes if mem else -1,
            "alias_bytes": mem.alias_size_in_bytes if mem else -1,
        },
    ).finalize()

    out = roof.to_dict()
    out["compile_s"] = compile_s
    out["probe_s"] = probe_s
    out["rolled_flops_per_device"] = float(rolled_cost.get("flops", 0.0))
    out["hlo_lines"] = hlo_lines
    out["total_params"] = total_p
    out["active_params"] = active_p
    out["tokens_per_step"] = tokens
    print(
        f"[{arch} x {shape_name} x {mesh_name}] compile={compile_s:.1f}s "
        f"flops/dev={out['hlo_flops_per_device']:.3e} bytes/dev={out['hlo_bytes_per_device']:.3e} "
        f"coll/dev={out['collective_bytes_per_device']:.3e} dominant={out['dominant']} "
        f"args={out['memory_stats']['argument_bytes']/1e9:.2f}GB temp={out['memory_stats']['temp_bytes']/1e9:.2f}GB"
    )
    print(f"  memory_analysis: {mem}")
    print(f"  terms: compute={out['compute_s']*1e3:.2f}ms memory={out['memory_s']*1e3:.2f}ms "
          f"collective={out['collective_s']*1e3:.2f}ms useful_ratio={out['useful_ratio']:.3f} "
          f"roofline_fraction={out['roofline_fraction']:.3f}")
    return out


def cell_path(arch: str, shape_name: str, mesh_name: str) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    return os.path.join(REPORT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")


def run_all(args) -> None:
    cells = []
    for arch, cfg in CONFIGS.items():
        if args.arch and arch != args.arch:
            continue
        for shape in applicable_shapes(cfg):
            if args.shape and shape.name != args.shape:
                continue
            meshes = []
            if not args.multipod_only:
                meshes.append(False)
            if not args.single_only:
                meshes.append(True)
            for mp in meshes:
                cells.append((arch, shape.name, mp))
    print(f"{len(cells)} cells to run")
    failures = []
    for arch, shape_name, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        path = cell_path(arch, shape_name, mesh_name)
        if args.skip_done and os.path.exists(path):
            print(f"skip done: {arch} x {shape_name} x {mesh_name}")
            continue
        try:
            out = analyze_cell(arch, shape_name, multi_pod=mp)
            with open(path, "w") as f:
                json.dump(out, f, indent=1)
        except Exception as e:  # noqa: BLE001
            print(f"FAILED: {arch} x {shape_name} x {mesh_name}: {e}")
            traceback.print_exc()
            failures.append((arch, shape_name, mesh_name, str(e)))
    print(f"\ndone; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f[:3])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="single cell: use 2x16x16")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    if args.all or (args.arch and not args.shape) or (args.shape and not args.arch):
        run_all(args)
    else:
        out = analyze_cell(args.arch, args.shape, multi_pod=args.multipod)
        mesh_name = "2x16x16" if args.multipod else "16x16"
        with open(cell_path(args.arch, args.shape, mesh_name), "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
