"""Production training launcher.

Composes: config registry -> mesh -> sharded train state -> stateless
step -> elastic serverless driver.  On this CPU container it runs reduced
configs end-to-end; on a real pod the same entry point drives full configs
(the dry-run proves those lower+compile on the production meshes).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 40 --seq 64 --batch 4 [--workers 2] [--microbatches 2]
"""

from __future__ import annotations

import argparse
import time


from repro.configs import CONFIGS
from repro.core import WrenExecutor
from repro.data import DataConfig, synthetic_batch
from repro.train import ElasticTrainConfig, adamw, cosine_schedule, train_elastic
from repro.train import checkpoint as ck


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(CONFIGS))
    ap.add_argument("--reduced", action="store_true", help="CPU-size config")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--steps-per-chunk", type=int, default=5)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--run", default=None)
    args = ap.parse_args()

    cfg = CONFIGS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    run = args.run or f"{args.arch}-{'r' if args.reduced else 'f'}"

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch, vocab_size=cfg.vocab_size)
    opt = adamw(cosine_schedule(args.lr, warmup=args.steps // 10 + 1, total=args.steps))
    batch_fn = lambda step: synthetic_batch(dcfg, step, cfg)  # noqa: E731

    wex = WrenExecutor(num_workers=args.workers)
    try:
        tcfg = ElasticTrainConfig(
            run=run,
            steps_per_chunk=args.steps_per_chunk,
            total_steps=args.steps,
            microbatches=args.microbatches,
        )
        t0 = time.time()
        hist = train_elastic(wex, cfg, opt, tcfg, batch_fn)
        dt = time.time() - t0
        print(f"arch={args.arch} run={run}")
        print(f"losses: {[round(h['loss'], 4) for h in hist]}")
        print(
            f"{args.steps} steps, {dt:.1f}s, "
            f"{args.steps * args.batch * args.seq / dt:.0f} tok/s, "
            f"checkpoint v{ck.latest_version(wex.store, run)}"
        )
    finally:
        wex.shutdown()


if __name__ == "__main__":
    main()
