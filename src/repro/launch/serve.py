"""Production serving launcher: engine + storage request plane.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
      --requests 12 [--batch 4] [--new-tokens 16]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import Engine, ServeConfig, serve_pending, submit_request
from repro.storage import ObjectStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(CONFIGS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = CONFIGS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(
        cfg, params,
        ServeConfig(max_len=args.max_len, max_new_tokens=args.new_tokens),
    )
    store = ObjectStore()
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16))).tolist()
        submit_request(store, f"req-{i:04d}", prompt)

    t0 = time.time()
    total = 0
    while True:
        n = serve_pending(store, engine, batch_size=args.batch)
        if n == 0:
            break
        total += n
    dt = time.time() - t0
    print(
        f"served {total} requests in {dt:.1f}s "
        f"({total * args.new_tokens / dt:.1f} tok/s decode on CPU)"
    )


if __name__ == "__main__":
    main()
