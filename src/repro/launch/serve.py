"""Serving engine worker: one continuous-batching engine over shared storage.

Each invocation is ONE stateless engine worker — the paper's scaling unit.
Point any number of them at the same ``--kv-root``/``--obj-root`` (shared
filesystem) and they cooperatively drain the ``serve/q/*`` request queues:
leases keep two engines off the same request, heartbeats keep live work
fenced, and a worker that dies mid-stream is reaped by the survivors and
its requests re-served byte-identically (per-request PRNG keys).

Worker over a shared directory (start N of these; clients submit with
``repro.serve.request_plane.submit`` against the same roots):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
      --kv-root /srv/kv --obj-root /srv/obj --engine-id e0 --idle-timeout 10

Self-contained demo (no roots -> in-memory stores, submits its own
Poisson-ish traffic and serves it):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
      --demo-requests 12

The worker prints ``READY <engine-id>`` after jit warmup so orchestrators
can wait for it before submitting, and a stats line on idle exit.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import init_params
from repro.serve import ContinuousEngine, ServeConfig
from repro.serve import request_plane as rp
from repro.storage import FileBackend, FileKVStore, KVStore, ObjectStore


def _build_engine(args) -> ContinuousEngine:
    cfg = CONFIGS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(
        max_batch=args.batch,
        max_len=args.max_len,
        max_new_tokens=args.new_tokens,
        decode_chunk=args.decode_chunk,
        n_queues=args.queues,
        lease_timeout_s=args.lease_timeout,
    )
    engine = ContinuousEngine(cfg, params, scfg)
    # compile decode + the single-request prefill shape before READY
    engine.admit([("warm", [1, 2, 3], 2)])
    while engine.n_live():
        engine.step_chunk()
    for k in engine.stats:
        engine.stats[k] = 0
    return engine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-32b", choices=sorted(CONFIGS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kv-root", help="shared FileKVStore directory (request plane)")
    ap.add_argument("--obj-root", help="shared FileBackend directory (bodies/results)")
    ap.add_argument("--engine-id", default="engine-0")
    ap.add_argument("--idle-timeout", type=float, default=5.0,
                    help="exit after the queue stays empty this long (s)")
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="decode steps between admission/stream boundaries")
    ap.add_argument("--queues", type=int, default=1, help="serve/q/ shard count")
    ap.add_argument("--lease-timeout", type=float, default=2.0)
    ap.add_argument("--demo-requests", type=int, default=0,
                    help="submit this many synthetic requests first (demo mode; "
                    "uses in-memory stores when no roots are given)")
    args = ap.parse_args()

    if bool(args.kv_root) != bool(args.obj_root):
        ap.error("--kv-root and --obj-root must be given together")
    if args.kv_root:
        kv = FileKVStore(args.kv_root, num_shards=2)
        store = ObjectStore(backend=FileBackend(args.obj_root))
    else:
        if not args.demo_requests:
            ap.error("no shared roots: give --kv-root/--obj-root, or "
                     "--demo-requests N for a self-contained in-memory demo")
        kv = KVStore(num_shards=2)
        store = ObjectStore()

    engine = _build_engine(args)
    print(f"READY {args.engine_id}", flush=True)

    if args.demo_requests:
        rng = np.random.default_rng(0)
        cfg = engine.cfg
        for i in range(args.demo_requests):
            prompt = rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(4, 16))
            ).tolist()
            rp.submit(store, kv, f"req-{i:04d}", prompt, n_queues=args.queues)
        print(f"submitted {args.demo_requests} requests", flush=True)

    t0 = time.time()
    stats = engine.run(
        store, kv, engine_id=args.engine_id, idle_timeout_s=args.idle_timeout
    )
    dt = time.time() - t0
    print(
        f"{args.engine_id}: served {stats['served']} requests, "
        f"{stats['tokens_out']} tokens in {dt:.1f}s "
        f"({stats['tokens_out'] / max(dt, 1e-9):.1f} tok/s; "
        f"{stats['mid_batch_admissions']} mid-batch admissions, "
        f"{stats['decode_steps']} decode steps)",
        flush=True,
    )


if __name__ == "__main__":
    sys.exit(main())
