"""§Perf hillclimb driver: re-analyze a dry-run cell under optimization
variants and log hypothesis → change → before/after.

Variants are environment/kwarg levers over the SAME model code:
  axis=tp_model|fsdp_all      logical axis mapping (TP16 vs pure ZeRO-3)
  sp=0|1                      Megatron sequence-parallel residual stream
  remat=nothing|dots|none     activation checkpoint policy
  mb=N                        gradient-accumulation microbatches
  moe_group=N                 MoE dispatch group size

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3-8b \
      --shape train_4k --variant axis=fsdp_all --variant sp=1
Each run writes reports/perf/<cell>__<variant-string>.json.
"""

import argparse
import json
import os

# env must be set before jax device init (dryrun sets XLA_FLAGS on import)
from repro.launch import dryrun  # noqa: E402  (imports first: sets XLA_FLAGS)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "perf")


def apply_variant(tokens):
    kw = {}
    tags = []
    for t in tokens:
        key, val = t.split("=", 1)
        if key == "axis":
            os.environ["REPRO_AXIS_MAP"] = val
        elif key == "sp":
            os.environ["REPRO_SEQ_PARALLEL"] = val
        elif key == "remat":
            os.environ["REPRO_REMAT_POLICY"] = val
        elif key == "ce":
            os.environ["REPRO_FUSED_CE"] = "1" if val == "fused" else "0"
        elif key == "pbf16":
            os.environ["REPRO_ATTN_P_BF16"] = val
        elif key == "mb":
            kw["microbatches"] = int(val)
        elif key == "moe_group":
            kw["moe_group"] = int(val)
        else:
            raise ValueError(f"unknown variant key {key}")
        tags.append(f"{key}-{val}")
    return kw, "_".join(tags) if tags else "baseline"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--variant", action="append", default=[])
    args = ap.parse_args()

    kw, tag = apply_variant(args.variant)
    out = dryrun.analyze_cell(args.arch, args.shape, multi_pod=args.multipod, **kw)
    out["variant"] = tag
    os.makedirs(REPORT_DIR, exist_ok=True)
    mesh_name = "2x16x16" if args.multipod else "16x16"
    path = os.path.join(
        REPORT_DIR, f"{args.arch}__{args.shape}__{mesh_name}__{tag}.json"
    )
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
