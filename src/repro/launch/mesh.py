"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(dp: int, tp: int, pods: int = 1):
    """Arbitrary mesh for experiments / elastic remesh."""
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_num_devices(mesh) -> int:
    return mesh.devices.size
