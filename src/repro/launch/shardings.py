"""Sharding spec trees for train state, caches, and batches (dry-run +
launchers).  Leaf-path rules mirror models/sharding.py's activation
constraints so in_shardings agree with the in-model with_sharding_constraint
calls.
"""

from __future__ import annotations

import re
from typing import Any

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.sharding import param_pspec, physical_axes


def _dp_axes(mesh: Mesh):
    return physical_axes(mesh, "dp")


def _tp_axis(mesh: Mesh):
    return physical_axes(mesh, "tp")


def _dp_size(mesh: Mesh) -> int:
    ax = _dp_axes(mesh)
    if ax is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in ax]))


def _tp_size(mesh: Mesh) -> int:
    ax = _tp_axis(mesh)
    return mesh.shape[ax] if ax else 1


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def batch_pspec(mesh: Mesh, batch_tree: Any) -> Any:
    dp = _dp_axes(mesh)

    def rule(path, leaf):
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        if leaf.shape[0] % max(_dp_size(mesh), 1) != 0:
            spec[0] = None
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspec(mesh: Mesh, cfg: ModelConfig, cache_tree: Any) -> Any:
    """KV caches / SSM states.  Trailing-dims rules by leaf name; leading
    stacking dims are replicated.  Batch==1 long-decode shards sequence over
    dp as well (see DESIGN.md)."""
    dp = _dp_axes(mesh)
    tp = _tp_axis(mesh)
    dp_n, tp_n = _dp_size(mesh), _tp_size(mesh)

    def rule(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)

        def lead(spec):
            return P(*([None] * (nd - len(spec)) + list(spec)))

        def _flat(*axes):
            out = []
            for a in axes:
                if a is None:
                    continue
                out.extend(a if isinstance(a, tuple) else (a,))
            return tuple(out) if out else None

        def _size(ax) -> int:
            if ax is None:
                return 1
            if isinstance(ax, tuple):
                return int(np.prod([mesh.shape[a] for a in ax]))
            return mesh.shape[ax]

        def _fit(dim: int, *candidates):
            """First candidate axis (or combo) whose size divides dim."""
            for c in candidates:
                if c is not None and dim % _size(c) == 0 and dim >= _size(c):
                    return c
            return None

        if re.search(r"/(k|v)$", ps):  # (B, S, K, hd)
            B, S, K, hd = shape[-4:]
            kv_tp = tp if (tp and K % tp_n == 0) else None
            if B % dp_n == 0 and B >= dp_n:
                if kv_tp:
                    return lead([dp, None, kv_tp, None])
                return lead([dp, _fit(S, tp), None, None])
            # tiny batch (long-decode): shard sequence over dp (and tp if no heads)
            if kv_tp:
                return lead([None, _fit(S, dp), kv_tp, None])
            return lead([None, _fit(S, _flat(dp, tp), dp, tp), None, None])
        if ps.endswith("c_kv") or ps.endswith("k_pe"):  # (B, S, r)
            B, S = shape[-3], shape[-2]
            if B % dp_n == 0 and B >= dp_n:
                return lead([dp, _fit(S, tp), None])
            return lead([None, _fit(S, _flat(dp, tp), dp, tp), None])
        if ps.endswith("conv"):  # (B, K-1, C)
            B, _, C = shape[-3:]
            bspec = dp if (B % dp_n == 0 and B >= dp_n) else None
            cspec = tp if C % tp_n == 0 else None
            return lead([bspec, None, cspec])
        if ps.endswith("ssm"):  # (B, H, P, N)
            B, H = shape[-4], shape[-3]
            bspec = dp if (B % dp_n == 0 and B >= dp_n) else None
            hspec = tp if H % tp_n == 0 else None
            return lead([bspec, hspec, None, None])
        m_state = re.search(r"/m/(c|n|m)$", ps)
        s_state = re.search(r"/s/(c|n|m|h)$", ps)
        if m_state or s_state:
            # xlstm states, trailing dims (B, H, ...): shard B over dp and
            # H over tp where divisible
            name = (m_state or s_state).group(1)
            rank = {"c": 4, "n": 3, "m": 2}[name] if m_state else 3
            tail = shape[-rank:]
            B, H = tail[0], tail[1]
            bspec = dp if (B % dp_n == 0 and B >= dp_n) else None
            hspec = tp if H % tp_n == 0 else None
            return lead([bspec, hspec] + [None] * (rank - 2))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def state_pspec(mesh: Mesh, state_tree: Any) -> Any:
    """TrainState(params, AdamWState(step, m, v)) — params rules applied to
    params and to each moment tree (leaf names match)."""
    return param_pspec(mesh, state_tree)


def to_shardings(mesh: Mesh, pspec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
