"""Lambda-style resource limits + the paper's §4 resource-balance heuristic.

AWS Lambda circa the paper: 300 s max runtime, 1.5 GB RAM, 512 MB local
scratch, no root.  The executor enforces these limits on every task (virtual
runtime, measured payload sizes) so workloads that "don't fit Lambda" fail
the same way they would have in PyWren, and the BSP layer is forced into the
same task-granularity decisions (e.g. >= 2500 sort tasks per stage for 1TB).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.storage.perf_model import MB


@dataclass(frozen=True)
class ResourceLimits:
    max_runtime_s: float = 300.0
    memory_bytes: int = int(1.5 * 1024 * MB)  # 1.5 GiB-ish
    local_storage_bytes: int = int(512 * MB)

    def check_payload(self, nbytes: int, what: str) -> None:
        if nbytes > self.memory_bytes:
            raise MemoryError(
                f"{what} of {nbytes/1e9:.2f} GB exceeds container memory "
                f"{self.memory_bytes/1e9:.2f} GB"
            )

    def check_runtime(self, vtime_s: float) -> None:
        if vtime_s > self.max_runtime_s:
            raise TimeoutError(
                f"task virtual runtime {vtime_s:.1f}s exceeds limit "
                f"{self.max_runtime_s:.0f}s"
            )


LAMBDA_2017 = ResourceLimits()

# A 2026-scale serverless accelerator container (the §4 'more general
# hardware support will be available in the future' row): one TPU-slice task.
TPU_TASK_2026 = ResourceLimits(
    max_runtime_s=3600.0,
    memory_bytes=int(16 * 1024 * MB),
    local_storage_bytes=int(100 * 1024 * MB),
)


def io_compute_balance(
    memory_bytes: float, storage_bw_bytes_per_s: float, max_runtime_s: float
) -> dict:
    """The paper's §4 'Resource balance' heuristic.

    'each Lambda has around 35 MB/s bandwidth to S3 and can thus fill up its
    memory of 1.5GB in around 40s. Assuming it takes 40s to write output, we
    can see that the running time of 300s is appropriately proportioned for
    around 80s of I/O and 220s of compute.'

    Returns the proportioning and, inversely, the memory capacity a target
    running time supports ('this rule can be used to automatically determine
    memory capacity given a target running time').
    """
    fill_s = memory_bytes / storage_bw_bytes_per_s
    io_s = 2 * fill_s  # read input + write output
    compute_s = max(max_runtime_s - io_s, 0.0)
    return {
        "fill_seconds": fill_s,
        "io_seconds": io_s,
        "compute_seconds": compute_s,
        "io_fraction": io_s / max_runtime_s if max_runtime_s else float("inf"),
        # inverse rule: memory a runtime budget supports at this bandwidth,
        # keeping the same (io : compute) proportion as Lambda-2017.
        "memory_for_runtime": lambda runtime_s, io_frac=io_s / max_runtime_s: (
            0.5 * io_frac * runtime_s * storage_bw_bytes_per_s
        ),
    }
