"""The paper's contribution: a serverless stateless-function runtime.

Layers: functions (serialization/idempotency) → scheduler (leases, retries,
speculation) → executor (elastic container pool) → wren (map API) → bsp /
ps (higher-level abstractions built on the single primitive).

Every layer rides the storage plane's batched contract: a map stages all
inputs in one ``put_many`` and submits all tasks in one pipelined push,
future fan-in resolves via one ``get_many``, shuffle fan-out/fan-in are
single batched calls per task (with intermediates GC'd after merge), and
parameter-server pulls are one round-trip per KV shard (pushes at most two:
block data, then version bumps).  The driver pays O(1) modeled requests per
bulk operation, not O(N).
"""

from .bsp import adopt_job, mapreduce, run_stage, terasort, verify_sorted, word_count
from .executor import FaultPlan, Worker, WorkerPool, WorkerStats
from .functions import (
    FunctionSpec,
    TaskResult,
    TaskSpec,
    run_task,
    stage_input,
    stage_inputs,
)
from .futures import ALL_COMPLETED, ANY_COMPLETED, ALWAYS, ResultFuture, get_all, wait
from .ps import ParameterServer, PSConfig, hogwild_sgd
from .resources import LAMBDA_2017, TPU_TASK_2026, ResourceLimits, io_compute_balance
from .scheduler import Scheduler, SchedulerConfig
from .wren import WrenExecutor

__all__ = [
    "WrenExecutor",
    "Scheduler",
    "SchedulerConfig",
    "WorkerPool",
    "Worker",
    "WorkerStats",
    "FaultPlan",
    "FunctionSpec",
    "TaskSpec",
    "TaskResult",
    "run_task",
    "stage_input",
    "stage_inputs",
    "ResultFuture",
    "wait",
    "get_all",
    "ALL_COMPLETED",
    "ANY_COMPLETED",
    "ALWAYS",
    "mapreduce",
    "adopt_job",
    "word_count",
    "terasort",
    "verify_sorted",
    "run_stage",
    "ParameterServer",
    "PSConfig",
    "hogwild_sgd",
    "ResourceLimits",
    "LAMBDA_2017",
    "TPU_TASK_2026",
    "io_compute_balance",
]
