"""Elastic worker pool: Lambda-container emulation with fault injection.

Each worker thread emulates one serverless container:

  * **cold start** — first task on a fresh container pays the paper's
    measured start latency (Table 2: 9.7 s start + 14.2 s setup, as virtual
    time, deterministic per worker seed); warm containers pay ~0.1 s.
    Container *reuse* across tasks is the paper's §4 caching mitigation.
  * **statelessness** — the container scratch dict is wiped between jobs;
    nothing a task leaves behind is visible to the next (paper §3.1: "none
    of the state created by the function will be retained").
  * **resource limits** — Lambda 2017 limits enforced per task.
  * **fault injection** — test hooks: die_before_publish (instance loss →
    lease expiry → retry), slowdown factors (stragglers → speculation),
    kill switches (elastic scale-down).

Workers heartbeat their lease from a side thread while the user function
runs, so long tasks are not falsely reaped, but a *dead* worker stops
heartbeating and is.

Epoch fencing threads through here: a leased ``TaskSpec`` carries the
attempt's fencing token (``task.epoch``), heartbeats are epoch-checked
extensions, and ``_execute`` hands ``run_task`` a fence callback
(``Scheduler.owns_lease``) checked immediately before the result publish —
a zombie container (reaped as dead, or superseded by a speculative
duplicate's lease) finishes its work but cannot publish over the owning
attempt's result or extend a lease it no longer holds.

The same token discipline is what makes *driver* death recoverable (PR 7):
an adopter replaying a job manifest (``core/jobs.py``, ``core/bsp.py``)
resubmits any task the dead driver had in flight, and the duplicate
attempts converge here exactly as speculative duplicates do — first
publish wins, the loser is fenced at the result boundary.

Event-driven dispatch: workers do not poll the queue.  ``Worker.run``
blocks in ``Scheduler.lease_batch`` on the *queue shard's* KV watch
condition and is woken by any producer's ``rpush`` (submit, reap requeue,
speculation duplicate) — including producers on other scheduler handles
sharing the KV — leasing tasks in small batches to amortize queue lock
traffic.  ``stop()``/``kill()`` wake any blocked lease wait via
``Scheduler.wake_workers()`` so shutdown never waits out a poll interval.  On *graceful* stop, leased-but-unstarted batch
tasks are handed back via ``Scheduler.release``; on hard kill (or injected
death) their leases are left dangling for the reaper, exactly like a lost
Lambda instance.

Note the stop flag is named ``_stop_evt``: ``threading.Thread`` has a
private ``_stop()`` *method* in CPython, and shadowing it with an Event
makes ``Thread.join()`` raise ``TypeError: 'Event' object is not
callable``.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.storage import ObjectStore

from .functions import TaskSpec, run_task
from .resources import LAMBDA_2017, ResourceLimits
from .scheduler import Scheduler

# Paper Table 2 constants (seconds, virtual).
COLD_START_MEAN_S = 9.7
COLD_SETUP_MEAN_S = 14.2
WARM_START_S = 0.1

# How long a blocked lease wait lasts before re-checking the stop flag —
# a defensive backstop only; stop/kill wake the wait explicitly.
_LEASE_WAIT_S = 0.25


@dataclass
class FaultPlan:
    """Deterministic fault-injection plan for tests/benchmarks."""

    die_before_publish_tasks: set = field(default_factory=set)  # task ids die once
    slowdown: Dict[str, float] = field(default_factory=dict)  # worker -> factor
    max_tasks_per_worker: Optional[int] = None
    _fired: set = field(default_factory=set)  # faults fire once *globally*
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def should_die(self, task_id: str) -> bool:
        with self._lock:
            if task_id in self.die_before_publish_tasks and task_id not in self._fired:
                self._fired.add(task_id)
                return True
            return False


@dataclass
class WorkerStats:
    tasks_ok: int = 0  # attempts whose result is the task's visible one
    tasks_failed: int = 0
    # Attempts that ran to completion but whose result was fenced or beaten
    # to the publish by a duplicate — the price of speculation/retries.
    # Invariant: Σ tasks_ok across workers == number of visible results.
    tasks_superseded: int = 0
    cold_starts: int = 0
    vtime_busy_s: float = 0.0


class Worker(threading.Thread):
    def __init__(
        self,
        name: str,
        store: ObjectStore,
        scheduler: Scheduler,
        limits: ResourceLimits = LAMBDA_2017,
        fault_plan: Optional[FaultPlan] = None,
        compute_time_fn: Optional[Callable[[float], float]] = None,
        seed: int = 0,
        poll_s: float = 0.002,
        lease_batch_size: int = 4,
    ) -> None:
        super().__init__(name=name, daemon=True)
        self.worker_id = name
        self.store = store
        self.scheduler = scheduler
        self.limits = limits
        self.fault_plan = fault_plan or FaultPlan()
        self.compute_time_fn = compute_time_fn
        self.rng = random.Random(seed)
        self.poll_s = poll_s  # legacy knob; only scales injected slowdowns now
        self.lease_batch_size = max(1, lease_batch_size)
        self.stats = WorkerStats()
        self._stop_evt = threading.Event()
        self._killed = False  # hard kill / injected death: leases dangle
        self._warm = False  # container temperature
        # Warm-container code cache (paper §4): func blobs are content-
        # addressed and immutable, so a reused container skips re-fetching
        # and re-deserializing the function.  User/task state is NOT cached
        # — statelessness applies to data, not immutable code.
        self._code_cache: Dict[str, Callable] = {}

    # -- lifecycle ---------------------------------------------------------
    @property
    def stop_requested(self) -> bool:
        return self._stop_evt.is_set()

    def stop(self) -> None:
        """Graceful stop: finish the current task, release unstarted leases."""
        self._stop_evt.set()
        self.scheduler.wake_workers()

    def kill(self) -> None:
        """Hard kill: stop without completing the current lease (scale-down /
        spot preemption).  The scheduler's reaper picks up the pieces."""
        self._killed = True
        self._stop_evt.set()
        self.scheduler.wake_workers()

    # -- the container loop ---------------------------------------------------
    def run(self) -> None:  # noqa: D102
        tasks_done = 0
        while not self._stop_evt.is_set():
            batch = self.scheduler.lease_batch(
                self.worker_id,
                max_n=self.lease_batch_size,
                timeout_s=_LEASE_WAIT_S,
                should_stop=self._stop_evt.is_set,
            )
            # Prefetch the whole batch's inputs in one amortized multi-get
            # (the PR-2 read-batching lesson applied to the worker): N leased
            # tasks cost one request latency, not N.  The cache holds
            # serialized BYTES, not objects — inputs are content-addressed,
            # so two tasks with equal inputs share one key, and handing both
            # the same deserialized object would let one task's mutation
            # corrupt the other's input.  Each task deserializes its own
            # copy (exactly what its own fetch would have produced).  A key
            # that vanished (job GC'd mid-flight) is simply absent and the
            # task falls back to its own fetch.
            inputs = {}
            if len(batch) > 1:
                inputs = self.store.get_many_bytes(
                    [t.input_key for t in batch], worker=self.worker_id
                )
            for i, task in enumerate(batch):
                if self._stop_evt.is_set():
                    self._drop_leases(batch[i:])
                    return
                # heartbeat covers the whole held remainder of the batch, so
                # queued-behind-current leases don't falsely expire
                self._execute(task, held=batch[i:], inputs=inputs)
                tasks_done += 1
                cap = self.fault_plan.max_tasks_per_worker
                if cap is not None and tasks_done >= cap:
                    self._drop_leases(batch[i + 1:])
                    return

    def _drop_leases(self, unstarted: List[TaskSpec]) -> None:
        """Hand unstarted leases back — unless this container is 'dead', in
        which case they dangle until lease expiry, like a real lost instance."""
        if self._killed:
            return
        for task in unstarted:
            self.scheduler.release(task, self.worker_id)

    def _execute(
        self,
        task: TaskSpec,
        held: Optional[List[TaskSpec]] = None,
        inputs: Optional[Dict[str, object]] = None,
    ) -> None:
        # cold-start accounting (virtual)
        if self._warm:
            setup_vtime = WARM_START_S
        else:
            setup_vtime = max(
                0.5,
                self.rng.gauss(COLD_START_MEAN_S, 2.0)
                + self.rng.gauss(COLD_SETUP_MEAN_S, 2.0),
            )
            self.stats.cold_starts += 1
            self._warm = True

        # heartbeat while running — covers the current task plus any
        # leased-but-unstarted batch remainder this worker still holds
        hb_stop = threading.Event()
        hb_tasks = held if held else [task]

        def _heartbeat() -> None:
            # The lease was granted with a full timeout moments ago, so the
            # first extension is only due after one interval — beating
            # immediately would add one KV transaction per task for nothing.
            while not hb_stop.wait(self.scheduler.config.heartbeat_interval_s):
                if self._killed:
                    return  # dead containers don't heartbeat; a *graceful*
                    # stop keeps the current task's lease alive to the end
                for t in hb_tasks:
                    self.scheduler.heartbeat(t, self.worker_id)

        hb = threading.Thread(target=_heartbeat, daemon=True)
        hb.start()
        t0 = time.monotonic()
        died = False
        try:
            # fault injection: die mid-task, before publishing (once per task,
            # globally — the retried attempt on another container succeeds)
            if self.fault_plan.should_die(task.task_id):
                # fetch input (burn some ledger ops) then vanish: the lease
                # must be left dangling so only expiry can recover the task
                try:
                    self.store.get_bytes(task.func_key, worker=self.worker_id)
                except KeyError:
                    pass
                died = True
                self._killed = True
                self._stop_evt.set()
                return

            slow = self.fault_plan.slowdown.get(self.worker_id, 1.0)
            if slow > 1.0:
                time.sleep(self.poll_s * slow)

            ct = self.compute_time_fn
            if slow > 1.0 and ct is not None:
                base_ct = ct
                ct = lambda s: base_ct(s) * slow  # noqa: E731

            result = run_task(
                self.store,
                task,
                worker=self.worker_id,
                setup_vtime=setup_vtime,
                compute_time_fn=ct,
                # Fence: publish only while this attempt's epoch still owns
                # the lease (zombie publishes are suppressed; scheduler.py
                # documents the protocol).
                fence=lambda: self.scheduler.owns_lease(task),
                code_cache=self._code_cache,
                input_cache=inputs,
            )
            vtotal = sum(result.phases.values())
            try:
                self.limits.check_runtime(vtotal)
            except TimeoutError:
                # Over-limit tasks fail permanently (the Lambda contract);
                # record but keep the published result (it is still correct —
                # the limit models billing, not correctness).
                result.phases["over_limit"] = vtotal
            if not result.success:
                self.stats.tasks_failed += 1
            elif result.fenced:
                self.stats.tasks_superseded += 1
            else:
                self.stats.tasks_ok += 1
            self.stats.vtime_busy_s += vtotal
        finally:
            hb_stop.set()
            if not died:
                self.scheduler.complete(task, self.worker_id, time.monotonic() - t0)


class WorkerPool:
    """Elastic pool: scale_to() adds/removes containers at any time.

    Liveness is tracked by a *not-stopped* predicate (``runnable_workers``),
    not thread aliveness alone: a killed worker may take a moment to exit,
    and a freshly constructed one may not have started yet — both were
    previously miscounted, so repeated scale up/down drifted away from the
    requested count."""

    def __init__(
        self,
        store: ObjectStore,
        scheduler: Scheduler,
        num_workers: int,
        limits: ResourceLimits = LAMBDA_2017,
        fault_plan: Optional[FaultPlan] = None,
        compute_time_fn: Optional[Callable[[float], float]] = None,
        seed: int = 0,
        lease_batch_size: int = 4,
    ) -> None:
        self.store = store
        self.scheduler = scheduler
        self.limits = limits
        self.fault_plan = fault_plan or FaultPlan()
        self.compute_time_fn = compute_time_fn
        self.seed = seed
        self.lease_batch_size = lease_batch_size
        self.workers: List[Worker] = []
        self._next_id = 0
        self._lock = threading.Lock()
        self.scale_to(num_workers)

    def runnable_workers(self) -> List[Worker]:
        """Workers that can still take tasks: not stop-requested, and either
        running or not yet started (a just-constructed thread is runnable)."""
        return [
            w
            for w in self.workers
            if not w.stop_requested and (w.ident is None or w.is_alive())
        ]

    def scale_to(self, n: int) -> None:
        """Elasticity: spin containers up or down; safe mid-job because state
        is storage-resident and tasks are idempotent.  Converges to exactly
        ``n`` runnable containers even across repeated up/down calls.

        Scale-down is a *graceful* stop, not a kill: a worker that leased a
        batch between the ``runnable_workers()`` snapshot and its stop flag
        hands every unstarted lease straight back (``Scheduler.release``,
        which burns the released epoch), so scale-down returns queue depth
        immediately instead of stranding leases until expiry — the reaper
        is for *lost* instances (``kill_worker``/fault injection), not for
        deliberate elasticity."""
        with self._lock:
            runnable = self.runnable_workers()
            while len(runnable) < n:
                w = Worker(
                    name=f"w{self._next_id:04d}",
                    store=self.store,
                    scheduler=self.scheduler,
                    limits=self.limits,
                    fault_plan=self.fault_plan,
                    compute_time_fn=self.compute_time_fn,
                    seed=self.seed + self._next_id,
                    lease_batch_size=self.lease_batch_size,
                )
                self._next_id += 1
                self.workers.append(w)
                runnable.append(w)
                w.start()
            # scale down: stop newest runnable first (graceful — releases)
            for w in reversed(runnable[n:]):
                w.stop()

    def kill_worker(self, idx: int) -> None:
        """Kill the idx-th *runnable* worker (indexing over already-dead
        workers would silently no-op the kill)."""
        with self._lock:
            runnable = self.runnable_workers()
            target = runnable[idx] if idx < len(runnable) else self.workers[idx]
        target.kill()

    def stop_all(self) -> None:
        for w in self.workers:
            w.stop()
        for w in self.workers:
            w.join(timeout=2.0)

    def stats(self) -> Dict[str, WorkerStats]:
        return {w.worker_id: w.stats for w in self.workers}

    def alive_count(self) -> int:
        return sum(1 for w in self.workers if w.is_alive())
